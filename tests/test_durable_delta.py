"""DurableStore on-disk delta chains.

Chain restores must be byte-identical to the equivalent full-snapshot
restores (the full-mode store is the oracle throughout), GC must never
delete a step dir a live chain references (and must not leak dirs once a
chain rolls past its bases), restores must read at most ``max_chain``
step dirs, and a crash between a dir's publish and the refcount-sidecar
update must heal at startup (the sidecar is rebuilt from manifests).
"""
import json
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import run_subprocess

from repro.store import DurableStore, PartnerMemoryStore, RecoveryLadder, flatten_with_paths
from repro.xfer import TransferPlane

CHUNK = 4096  # small chunks so multi-chunk states exercise sub-blocking


def _plane():
    return TransferPlane(chunk_bytes=CHUNK)


def _state(step: int, lo: float = 0.0):
    """A close-consecutive-submit stream: each step perturbs one small
    slice of a multi-chunk state, leaving most chunks byte-identical to
    the previous step (pure function of ``step`` - any two stores fed the
    same step see the same bytes)."""
    w = (np.arange(8192, dtype=np.float32) / 77.0 + lo).reshape(64, 128)
    w.reshape(-1)[(step * 97) % 7000 : (step * 97) % 7000 + 64] += step + 0.5
    mu = np.full((32, 32), step / 8.0, dtype=np.float32)
    return {"params": {"w": w, "b": np.arange(4.0) + step}, "opt": {"mu": mu}}


def _tmpl():
    return _state(0)


def _blob_equal(a, b) -> bool:
    fa, fb = flatten_with_paths(a), flatten_with_paths(b)
    return set(fa) == set(fb) and all(np.array_equal(fa[k], fb[k]) for k in fa)


def _manifest(directory, step):
    path = os.path.join(directory, f"step-{step:010d}", "manifest.json")
    with open(path) as f:
        return json.load(f)


def _dir_exists(directory, step):
    return os.path.exists(os.path.join(directory, f"step-{step:010d}"))


# ---------------------------------------------------------------------------
# chain formation + byte-identical restores
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["bf16", "int8"])
def test_chain_restore_bit_identical_to_full(tmp_path, codec):
    full_dir, delta_dir = str(tmp_path / "full"), str(tmp_path / "delta")
    full = DurableStore(full_dir, keep=0, xfer=_plane())
    delt = DurableStore(delta_dir, keep=0, delta=codec, max_chain=4,
                        xfer=_plane())
    for s in range(1, 7):
        full.submit_sync(s, _state(s))
        delt.submit_sync(s, _state(s))
    # the stream formed actual delta dirs (not just full fallbacks)
    formats = [_manifest(delta_dir, s)["format"] for s in range(1, 7)]
    assert formats == ["full", "delta", "delta", "delta", "full", "delta"]
    for s in range(1, 7):
        gf, gd = full.load(_tmpl(), step=s), delt.load(_tmpl(), step=s)
        assert gf is not None and gd is not None
        assert _blob_equal(gf[1], gd[1]), f"step {s} diverged"
    # newest-first default walk agrees too, and stays within the cap
    gf, gd = full.load(_tmpl()), delt.load(_tmpl())
    assert gf[0] == gd[0] == 6 and _blob_equal(gf[1], gd[1])
    assert delt.last_restore_dirs <= 4
    assert delt.last_restore_info.startswith("chain:")


def test_chain_cap_bounds_restore_depth(tmp_path):
    ds = DurableStore(str(tmp_path), keep=0, delta="bf16", max_chain=2,
                      xfer=_plane())
    for s in range(1, 7):
        ds.submit_sync(s, _state(s))
    formats = [_manifest(str(tmp_path), s)["format"] for s in range(1, 7)]
    assert formats == ["full", "delta"] * 3
    for s in range(1, 7):
        assert ds.load(_tmpl(), step=s) is not None
        assert ds.last_restore_dirs <= 2


def test_resubmit_of_same_step_ships_full(tmp_path):
    """Replay recrossing a checkpoint step must not delta against the dir
    it is about to replace (a self-referencing chain)."""
    ds = DurableStore(str(tmp_path), keep=0, delta="bf16", xfer=_plane())
    ds.submit_sync(1, _state(1))
    ds.submit_sync(2, _state(2))
    assert _manifest(str(tmp_path), 2)["format"] == "delta"
    ds.submit_sync(2, _state(2, lo=9.0))  # the recross, different bytes
    assert _manifest(str(tmp_path), 2)["format"] == "full"
    got = ds.load(_tmpl(), step=2)
    assert _blob_equal(got[1], _state(2, lo=9.0))


def test_chain_with_bfloat16_leaves_roundtrips(tmp_path):
    """Non-native dtypes cross the chain as raw bytes (full base dirs ship
    uint8 views + dtype tags, chunk payloads already do)."""
    import jax.numpy as jnp

    def bf_state(step):
        s = _state(step)
        s["params"]["h"] = jnp.full((32,), step / 4.0, dtype=jnp.bfloat16)
        return s

    ds = DurableStore(str(tmp_path), keep=0, delta="bf16", xfer=_plane())
    for s in (1, 2, 3):
        ds.submit_sync(s, bf_state(s))
    assert _manifest(str(tmp_path), 3)["format"] == "delta"
    for s in (1, 2, 3):
        got = ds.load(bf_state(0), step=s)
        assert got is not None
        assert got[1]["params"]["h"].dtype == jnp.bfloat16
        assert _blob_equal(got[1], bf_state(s)), s


def test_layout_change_resets_chain(tmp_path):
    ds = DurableStore(str(tmp_path), keep=0, delta="bf16", xfer=_plane())
    ds.submit_sync(1, _state(1))
    grown = _state(2)
    grown["params"]["extra"] = np.ones(512, dtype=np.float32)
    ds.submit_sync(2, grown)  # new leaf: signature mismatch, full snapshot
    assert _manifest(str(tmp_path), 2)["format"] == "full"
    assert _blob_equal(ds.load(grown, step=2)[1], grown)


# ---------------------------------------------------------------------------
# ref-counted GC
# ---------------------------------------------------------------------------


def test_keep_gc_preserves_chain_bases_then_collects(tmp_path):
    """keep=1 would have deleted every base dir a live chain needs; the
    ref closure keeps them - and collects the WHOLE chain as soon as the
    next full snapshot makes it unreachable (no leak)."""
    d = str(tmp_path)
    ds = DurableStore(d, keep=1, delta="bf16", max_chain=4, xfer=_plane())
    for s in range(1, 5):
        ds.submit_sync(s, _state(s))
    assert all(_dir_exists(d, s) for s in range(1, 5))  # chain alive
    got = ds.load(_tmpl())
    assert got[0] == 4 and _blob_equal(got[1], _state(4))
    ds.submit_sync(5, _state(5))  # chain cap: full, old chain unreachable
    assert _manifest(d, 5)["format"] == "full"
    assert ds.steps() == [5]
    assert not any(_dir_exists(d, s) for s in range(1, 5))  # no leak


def test_drop_defers_referenced_base_dir(tmp_path):
    d = str(tmp_path)
    ds = DurableStore(d, keep=0, delta="bf16", xfer=_plane())
    ds.submit_sync(1, _state(1))
    ds.submit_sync(2, _state(2))
    ds.drop(1)
    assert ds.steps() == [2]  # hidden from the walk...
    assert ds.load(_tmpl(), step=1) is None
    assert _dir_exists(d, 1)  # ...but the dir survives: step 2 needs it
    got = ds.load(_tmpl(), step=2)
    assert _blob_equal(got[1], _state(2))
    ds.drop(2)  # last referrer gone: both dirs are collectable
    assert ds.steps() == []
    assert not _dir_exists(d, 1) and not _dir_exists(d, 2)


def test_trim_keeps_chain_restorable(tmp_path):
    d = str(tmp_path)
    ds = DurableStore(d, keep=0, delta="bf16", max_chain=4, xfer=_plane())
    for s in range(1, 5):
        ds.submit_sync(s, _state(s))
    ds.trim(1)
    assert ds.steps() == [4]
    got = ds.load(_tmpl())
    assert got[0] == 4 and _blob_equal(got[1], _state(4))


# ---------------------------------------------------------------------------
# crash consistency (satellite: publish/refcount crash window)
# ---------------------------------------------------------------------------


def test_crash_between_publish_and_refcount_update_heals(tmp_path):
    """Kill between a delta dir's payload publish and the sidecar update:
    startup rebuilds the ref graph from the published manifests, so the
    restart neither frees the live base nor leaks the chain forever."""
    d = str(tmp_path)
    ds = DurableStore(d, keep=1, delta="bf16", max_chain=4, xfer=_plane())
    ds.submit_sync(1, _state(1))
    ds.submit_sync(2, _state(2))
    assert _manifest(d, 2)["format"] == "delta"
    # the crash window: dir 2 is published, the sidecar still pre-publish
    with open(os.path.join(d, "refs.json"), "w") as f:
        json.dump({"refs": {"1": []}, "refcounts": {}}, f)

    ds2 = DurableStore(d, keep=1, delta="bf16", max_chain=4, xfer=_plane())
    with open(os.path.join(d, "refs.json")) as f:
        healed = json.load(f)
    assert healed["refs"]["2"] == [1] and healed["refcounts"]["1"] == 1
    # does not free the live base: the chain still resolves
    got = ds2.load(_tmpl())
    assert got[0] == 2 and _blob_equal(got[1], _state(2))
    assert _dir_exists(d, 1)
    # does not leak: the next full rolls the chain and collects both
    ds2.submit_sync(3, _state(3))  # fresh encoder: self-contained
    assert ds2.steps() == [3]
    assert not _dir_exists(d, 1) and not _dir_exists(d, 2)


def test_missing_base_dir_falls_back_to_older_intact_step(tmp_path):
    """A base dir lost to a crash makes the referring delta dir torn, not
    the whole rung: the walk serves the next intact (full) step."""
    d = str(tmp_path)
    ds = DurableStore(d, keep=0, delta="bf16", max_chain=3, xfer=_plane())
    for s in range(1, 5):
        ds.submit_sync(s, _state(s))  # 1 full, 2-3 delta, 4 full
    assert _manifest(d, 4)["format"] == "full"
    ds.submit_sync(5, _state(5))  # delta on 4
    import shutil

    shutil.rmtree(os.path.join(d, "step-0000000004"))  # crash ate the base
    ds2 = DurableStore(d, keep=0, delta="bf16", xfer=_plane())
    got = ds2.load(_tmpl())
    assert got is not None
    assert got[0] == 3 and _blob_equal(got[1], _state(3))


# ---------------------------------------------------------------------------
# ladder integration: the L2 rung serves a chain when L1 lost coverage
# ---------------------------------------------------------------------------


def test_ladder_restores_from_delta_chain_with_detail(tmp_path):
    plane = _plane()
    ps = PartnerMemoryStore(range(4), redundancy=2)
    ds = DurableStore(str(tmp_path), keep=0, delta="bf16", max_chain=4)
    ladder = RecoveryLadder([ps, ds], xfer=plane)
    for s in range(1, 4):
        ladder.submit(s, _state(s))
    ladder.wait()
    assert _manifest(str(tmp_path), 3)["format"] == "delta"
    ladder.on_failure([0, 1, 2, 3])  # every L1 holder died with its host
    got = ladder.restore(_tmpl())
    assert got is not None and (got.level, got.step) == (2, 3)
    assert got.detail.startswith("chain:")  # surfaces in restored_from
    assert _blob_equal(got.state, _state(3))


# ---------------------------------------------------------------------------
# subprocess integration (slow): a real engine restoring THROUGH a chain
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_serving_restore_from_durable_delta_chain_bit_identical():
    """The append-only KV cache is the regime on-disk delta chains target:
    snapshot dirs past the first are delta (pages fully below the decode
    position ship as zero chunks - page_tokens=4 makes whole pages settle
    between the 4-token cadence ticks). An unmirrored slice loss must
    restore through the chain - the only rung in this ladder is the
    delta-mode DurableStore - and re-decode bit-identically to the
    failure-free run."""
    out = run_subprocess(
        """
        import json, os, tempfile
        import numpy as np
        from repro.configs.registry import smoke_config
        from repro.serving.engine import ServeEngine
        from repro.store import DurableStore, RecoveryLadder
        from repro.xfer import TransferPlane

        cfg = smoke_config("qwen2.5-3b")
        a = ServeEngine(cfg, n_slices=4, model_shards=1, rdegree=0.0,
                        max_len=64)
        ta = a.decode(12)

        ckdir = tempfile.mkdtemp()
        stores = RecoveryLadder(
            [DurableStore(ckdir, delta="bf16", max_chain=4)],
            xfer=TransferPlane(chunk_bytes=4096),
        )
        b = ServeEngine(cfg, n_slices=4, model_shards=1, rdegree=0.0,
                        max_len=64, snapshot_every=4, stores=stores,
                        page_tokens=4)
        tb = b.decode(12, failures={9: [2]})
        r = b.report

        # the newest snapshot dir is an actual delta link, not a full dir
        newest = max(int(d.split("-")[1]) for d in os.listdir(ckdir)
                     if d.startswith("step-"))
        with open(os.path.join(ckdir, f"step-{newest:010d}",
                               "manifest.json")) as f:
            man = json.load(f)
        assert man["format"] == "delta", man["format"]
        assert any(c["e"] == "zero" for c in man["chunks"]), (
            "append-only cache should ship zero chunks")

        assert r.restarts == 1 and r.promotes == 0
        assert r.restored_from == ["L2:durable@step8[chain:2]"], r.restored_from
        # streams 0,1,3 survive; their token history must match the
        # failure-free run bit-for-bit (greedy decode is deterministic)
        assert tb.shape[0] == 3 and ta.shape[0] == 4
        assert np.array_equal(tb, ta[[0, 1, 3]]), "decode state diverged"
        print("DELTA-CHAIN-SERVE-RESTORE-OK")
        """
    )
    assert "DELTA-CHAIN-SERVE-RESTORE-OK" in out


# ---------------------------------------------------------------------------
# property test: any trim/submit/drop interleaving keeps restores
# bit-identical (full-mode store as oracle), across a crash-restart
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    ops=st.lists(st.integers(0, 99), min_size=4, max_size=24),
    codec=st.sampled_from(["bf16", "int8"]),
)
def test_trim_submit_interleavings_keep_restores_bit_identical(ops, codec):
    with tempfile.TemporaryDirectory() as da, tempfile.TemporaryDirectory() as db:
        full = DurableStore(da, keep=0, xfer=_plane())
        delt = DurableStore(db, keep=0, delta=codec, max_chain=3,
                            xfer=_plane())
        step = 0
        for op in ops:
            if op < 70 or step == 0:  # submit the next close state
                step += 1
                full.submit(step, _state(step))
                delt.submit(step, _state(step))
            elif op < 85:  # trim to a small window
                k = 1 + op % 3
                full.trim(k)
                delt.trim(k)
            else:  # drop a pseudo-random known step
                s = 1 + op % step
                full.drop(s)
                delt.drop(s)
            full.wait()
            delt.wait()
            assert full.steps() == delt.steps()
            for s in delt.steps():
                gf, gd = full.load(_tmpl(), step=s), delt.load(_tmpl(), step=s)
                assert gf is not None and gd is not None
                assert _blob_equal(gf[1], gd[1]), (op, s)
        # the crash-restart: fresh stores on the same dirs must agree too
        full2 = DurableStore(da, keep=0, xfer=_plane())
        delt2 = DurableStore(db, keep=0, delta=codec, xfer=_plane())
        assert full2.steps() == delt2.steps()
        gf, gd = full2.load(_tmpl()), delt2.load(_tmpl())
        assert (gf is None) == (gd is None)
        if gf is not None:
            assert gf[0] == gd[0] and _blob_equal(gf[1], gd[1])
