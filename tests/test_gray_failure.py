"""Gray-failure resilience: suspicion-scoring detection, deadline-bounded
recovery, chaos injection, and the serving stall sentinel.

Unit tests run in-process on the injectable-clock APIs; the slow tests
drive full chaos scenarios in subprocesses (the flagship bit-identity
proofs: a hang and a fail-slow peer are detected by the liveness layer
alone - no ``report_failure`` - quarantined, and recovered with the
trajectory bit-identical to failure-free, while a flap never shrinks).
"""
import threading
import time
import types

import numpy as np
import pytest

from conftest import run_subprocess

from repro.core.control_plane import (
    CommunicatorRevoked,
    ControlPlane,
    ProcessFailed,
)
from repro.core.fault_injector import (
    ChaosEvent,
    ChaosLatency,
    ChaosSchedule,
    ChaosState,
)
from repro.serving.gateway.registry import StallSentinel
from repro.xfer import Deadline, DeadlineExceeded, backoff_delays
from repro.xfer.plane import AsyncStager


# ---------------------------------------------------------------------------
# suspicion-scoring detection (hang vs crash, windows, fencing)
# ---------------------------------------------------------------------------


def _plane(window=4.0, **kw):
    t = [0.0]
    cp = ControlPlane(heartbeat_timeout=window, clock=lambda: t[0], **kw)
    return cp, t


def test_silence_vs_stall_suspicion_distinguished():
    """A crashed slice (no beats) reads as silence; a hung slice (beats
    without progress while the frontier advances) reads as stall."""
    cp, t = _plane()
    for s in (0, 1, 2):
        cp.register(s, progress=0.0)
    for step in range(1, 8):
        t[0] = float(step)
        cp.heartbeat(0, progress=float(step))  # healthy
        cp.heartbeat(1)                        # hung: beats, no progress
        # slice 2: crashed - no beats at all
    sus = {s.slice_id: s for s in cp.suspects()}
    assert sus[1].reason == "stall" and sus[1].stalled_for == 7.0
    assert sus[2].reason == "silence" and sus[2].silent_for == 7.0
    assert 0 not in sus
    assert cp.detect() == {1, 2}


def test_frontier_relative_stall_spares_victims():
    """When the world blocks on one hung member, only the slice BEHIND
    the progress frontier accrues stall suspicion - the blocked healthy
    slices (pinned AT the frontier) stay clean, so attribution names the
    culprit, not its victims."""
    cp, t = _plane()
    for s in (0, 1):
        cp.register(s, progress=0.0)
    # slice 0 reached step 3 then the world wedged on slice 1; both keep
    # beating, neither advances further
    for step in range(1, 4):
        t[0] = float(step)
        cp.heartbeat(0, progress=float(step))
        cp.heartbeat(1, progress=0.0)
    for step in range(4, 12):
        t[0] = float(step)
        cp.heartbeat(0, progress=3.0)
        cp.heartbeat(1, progress=0.0)
    assert cp.detect() == {1}
    sus = {s.slice_id for s in cp.suspects()}
    assert sus == {1}, "the frontier slice must not be suspected"


def test_expiry_boundary_exactly_at_window_is_alive():
    """Strict-> semantics: silent for EXACTLY the window is still alive;
    strictly past it is expired (mirrors Deadline.exceeded)."""
    cp, t = _plane(window=5.0)
    cp.register(0)
    t[0] = 5.0
    assert cp.detect() == set()
    cp.check(0)  # guard agrees: not failed yet
    t[0] = 5.0 + 1e-9
    assert cp.detect() == {0}
    with pytest.raises(ProcessFailed) as ei:
        cp.check(0)
    assert ei.value.failed == {0}


def test_check_folds_liveness_expiry_into_guard():
    """The dispatch guard raises on suspicion expiry WITHOUT any
    report_failure - the hung-world fix (a pure-timeout conviction)."""
    cp, t = _plane(window=3.0)
    cp.register(0, progress=0.0)
    cp.register(1, progress=0.0)
    t[0] = 2.0
    cp.heartbeat(0, progress=2.0)
    cp.heartbeat(1, progress=2.0)
    cp.check(0)  # everyone within window
    t[0] = 6.0
    cp.heartbeat(0, progress=6.0)  # 1 now silent for 4 > 3
    with pytest.raises(ProcessFailed) as ei:
        cp.check(0)
    assert ei.value.failed == {1}
    # revocation still outranks the failed set
    cp.revoke()
    with pytest.raises(CommunicatorRevoked):
        cp.check(0)


def test_flap_soft_suspect_then_recovery_clears():
    """A short drop enters the soft-suspect band (score in
    [suspect_fraction, 1.0)) but resuming beats clears it - the
    false-positive path costs nothing."""
    cp, t = _plane(window=6.0, suspect_fraction=0.5)
    cp.register(0, progress=0.0)
    cp.register(1, progress=0.0)
    for step in range(1, 5):  # slice 1 silent for 4 of window 6
        t[0] = float(step)
        cp.heartbeat(0, progress=float(step))
    sus = {s.slice_id: s for s in cp.suspects()}
    assert 1 in sus and 0.5 <= sus[1].score < 1.0
    assert cp.detect() == set()  # soft suspect, NOT failed
    t[0] = 5.0
    cp.heartbeat(1, progress=5.0)  # the flap ends
    t[0] = 6.0
    cp.heartbeat(0, progress=6.0)
    cp.heartbeat(1, progress=6.0)
    assert cp.suspects() == []
    cp.check(0)  # never raised, never shrank


def test_zombie_fencing_rejects_stale_generation():
    """After shrink_complete, a late heartbeat/register stamped at (or
    before) the generation that shrank the slice out is dropped; only a
    stamp from a strictly NEWER generation re-admits it."""
    cp, t = _plane(window=2.0)
    cp.register(0, generation=0, progress=0.0)
    cp.register(1, generation=0, progress=0.0)
    t[0] = 5.0
    cp.heartbeat(0, progress=5.0, generation=0)
    assert 1 in cp.detect()
    gen = cp.revoke()  # the fence generation
    failed = cp.agree()
    cp.shrink_complete(failed)
    assert not cp.heartbeat(1, progress=99.0, generation=0)  # zombie beat
    assert not cp.register(1, generation=gen)  # a zombie OF the shrink gen
    assert cp.detect() == set(), "a fenced zombie must not re-enter detect()"
    assert cp.register(1, generation=gen + 1, progress=6.0)  # re-admitted
    t[0] = 6.0
    assert cp.heartbeat(1, progress=6.0, generation=gen + 1)


def test_reregister_expired_slice_before_generation_bump():
    """Regression: a slice that was reported AND liveness-expired, then
    re-registered with a pre-shrink generation stamp while the recovery
    window is still open, must not re-enter detect() after the shrink."""
    cp, t = _plane(window=2.0)
    cp.register(3, generation=0)
    t[0] = 10.0  # expired
    cp.report_failure(3)  # also explicitly reported
    assert cp.detect() == {3}
    cp.revoke()
    failed = cp.agree()
    cp.shrink_complete(failed)  # fence at the bumped generation
    # the zombie races its re-register with the old stamp
    assert not cp.register(3, generation=0)
    assert cp.detect() == set()
    cp.check(cp.generation)  # dispatch resumes clean


def test_register_and_heartbeat_monotonic_progress():
    cp, t = _plane(window=100.0)
    cp.register(0, progress=5.0)
    t[0] = 1.0
    cp.heartbeat(0, progress=3.0)  # stale mark: kept, not regressed
    assert cp._last_progress[0] == 5.0
    cp.heartbeat(0, progress=7.0)
    assert cp._last_progress[0] == 7.0


# ---------------------------------------------------------------------------
# chaos plane (injector + state)
# ---------------------------------------------------------------------------


def test_chaos_event_validation():
    with pytest.raises(ValueError):
        ChaosEvent(1, "melt", 0)
    with pytest.raises(ValueError):
        ChaosEvent(1, "hang", 0, duration=0.0)
    with pytest.raises(ValueError):
        ChaosEvent(1, "slow", 0, factor=0.0)
    e = ChaosEvent(1, "slow", 0, duration=float("inf"), factor=50.0)
    assert e.factor == 50.0


def test_chaos_schedule_parse_take_and_copy():
    cs = ChaosSchedule.parse("5:hang:2,5:drop:1,10:slow:3:20:50,30:flap:0")
    assert cs.pending() == 4
    flap = cs.take(30)[0]
    assert flap.kind == "flap" and flap.duration == 2.0  # the flap default
    evs = cs.take(5)
    assert {e.kind for e in evs} == {"hang", "drop"}
    assert cs.take(5) == []  # consumed: a replay never re-injects
    slow = cs.take(10)[0]
    assert (slow.duration, slow.factor) == (20.0, 50.0)
    assert not cs
    # constructor copies: consuming the copy leaves the source intact
    src = ChaosSchedule.parse("1:hang:0")
    copy = ChaosSchedule(src)
    copy.take(1)
    assert src.pending() == 1 and copy.pending() == 0
    with pytest.raises(ValueError):
        ChaosSchedule.parse("5:hang")  # missing victim
    with pytest.raises(ValueError):
        ChaosSchedule.parse("5:melt:1")


def test_chaos_state_lifecycle_and_latency():
    st = ChaosState()
    st.activate(ChaosEvent(0, "hang", 2, duration=3.0), now=10.0)
    st.activate(ChaosEvent(0, "flap", 1, duration=2.0), now=10.0)
    st.activate(ChaosEvent(0, "slow", 4, duration=float("inf"), factor=40.0),
                now=10.0)
    assert st.hung(11.0) == {2}
    assert st.dropped(11.0) == {1}  # a flap IS a short drop
    assert st.slow_factor(4, 11.0) == 40.0
    assert st.slow_factor(2, 11.0) == 1.0
    assert st.hung(13.0) == set() and st.dropped(12.5) == set()  # aged out
    assert st.slow_factor(4, 1e9) == 40.0  # inf never ages out
    assert st.start_time(2) == 10.0 and st.start_time(9) is None
    lat = ChaosLatency(st, clock=lambda: 11.0, base_s=0.05)
    assert lat.read_delay(4) == pytest.approx(2.0)  # 0.05 * 40
    assert lat.read_delay(2) == 0.0


# ---------------------------------------------------------------------------
# deadlines + backoff (the GASPI-FT timeout pattern)
# ---------------------------------------------------------------------------


def test_deadline_algebra():
    t = [0.0]
    dl = Deadline(2.0, clock=lambda: t[0])
    assert not dl.exceeded() and dl.remaining() == 2.0
    dl.charge(2.0)
    assert not dl.exceeded(), "exactly-at-budget is NOT exceeded"
    assert dl.would_exceed(0.001)
    dl.charge(0.5)
    assert dl.exceeded() and dl.remaining() == pytest.approx(-0.5)
    t[0] = 1.0  # real elapsed time counts too
    assert dl.elapsed() == pytest.approx(3.5)
    with pytest.raises(ValueError):
        dl.charge(-1.0)
    with pytest.raises(ValueError):
        Deadline(0.0)


def test_deadline_would_exceed_preserves_budget():
    """would_exceed lets a gather abort BEFORE paying a slow peer's cost:
    the budget survives for retries against healthy holders."""
    dl = Deadline(1.0, clock=lambda: 0.0)
    assert dl.would_exceed(5.0)
    assert not dl.exceeded()  # nothing was committed
    assert not dl.would_exceed(0.9)
    dl.charge(0.9)
    assert not dl.exceeded()


def test_backoff_delays():
    d = backoff_delays(5, base_s=0.001, factor=2.0, cap_s=0.005)
    assert d == [0.001, 0.002, 0.004, 0.005]  # capped, len attempts-1
    assert backoff_delays(1) == []
    with pytest.raises(ValueError):
        backoff_delays(0)


# ---------------------------------------------------------------------------
# bounded stager drain (a wedged background submit can't eat the window)
# ---------------------------------------------------------------------------


def test_stager_drain_timeout_returns_false_on_wedged_submit():
    stager = AsyncStager()
    release = threading.Event()
    stager.submit(release.wait)
    t0 = time.monotonic()
    assert stager.drain(timeout=0.05) is False
    assert time.monotonic() - t0 < 5.0
    release.set()
    assert stager.drain(timeout=5.0) is True
    assert stager.drain() is True  # unbounded on an idle stager


def test_stager_drain_unbounded_still_raises_submit_errors():
    stager = AsyncStager()

    def boom():
        raise RuntimeError("torn submit")

    stager.submit(boom)
    with pytest.raises(RuntimeError, match="torn submit"):
        stager.drain()


# ---------------------------------------------------------------------------
# partner-store quarantine + ladder rung deadlines (pure numpy, no jax)
# ---------------------------------------------------------------------------


def _template():
    return {"w": np.arange(64, dtype=np.float32),
            "b": np.ones((8,), dtype=np.float32)}


class _FixedLatency:
    def __init__(self, delays):
        self.delays = delays

    def read_delay(self, peer):
        return self.delays.get(peer, 0.0)


def test_partner_slow_peer_avoided_when_coholders_healthy():
    """K=2 redundancy: the latency-aware holder pick routes every chunk
    fetch around the slow peer - L1 serves the restore with ZERO
    quarantines (quarantine is for peers we cannot route around)."""
    from repro.store import PartnerMemoryStore

    ps = PartnerMemoryStore(range(4), redundancy=2)
    ps.submit(3, _template())
    ps.set_latency(_FixedLatency({1: 5.0}))
    ps.set_deadline(Deadline(0.5, clock=lambda: 0.0))
    got = ps.load(_template())
    ps.set_deadline(None)
    assert got is not None and got[0] == 3
    assert ps.quarantined == {}
    np.testing.assert_array_equal(got[1]["w"], _template()["w"])


def test_partner_sole_slow_holder_quarantined():
    """When the slow peer is the ONLY holder of some chunk, the deadline
    aborts before paying its cost, the peer is quarantined (purged like a
    death, but recorded as alive), and the restore step fails - the
    ladder's next rung takes over."""
    from repro.store import PartnerMemoryStore

    ps = PartnerMemoryStore(range(2), redundancy=2)  # K=2 over 2 peers:
    ps.submit(3, _template())                        # peer 1 co-holds all
    ps.on_failure([0])  # peer 0 dies -> peer 1 becomes the sole holder
    ps.set_latency(_FixedLatency({1: 5.0}))
    ps.set_deadline(Deadline(0.5, clock=lambda: 0.0))
    try:
        with pytest.raises(DeadlineExceeded) as ei:
            ps.load(_template())
    finally:
        ps.set_deadline(None)
    assert ei.value.culprits == [1]
    assert 1 in ps.quarantined and "fail-slow" in ps.quarantined[1]
    # dead trumps slow; re-admission forgives
    ps.register_peers([1])
    assert ps.quarantined == {}


def test_ladder_rung_deadline_falls_through_to_next_level():
    """A stalled L1 gather burns its per-rung budget and the walk falls
    to L2 within the deadline instead of wedging the recovery window."""
    import tempfile

    from repro.store import DurableStore, PartnerMemoryStore, RecoveryLadder

    ps = PartnerMemoryStore(range(2), redundancy=2)
    ladder = RecoveryLadder(
        [ps, DurableStore(tempfile.mkdtemp())], rung_deadline_s=0.5)
    ladder.submit(3, _template())
    ladder.drain()
    ps.on_failure([0])
    ps.set_latency(_FixedLatency({1: 5.0}))  # sole holder, 10x the budget
    got = ladder.restore(_template())
    assert got is not None and got.level == 2 and got.step == 3
    np.testing.assert_array_equal(got.state["w"], _template()["w"])
    l1, l2 = ladder.attempts
    assert not l1.ok and "DeadlineExceeded" in l1.error
    assert "quarantined:[1]" in l1.detail
    assert l2.ok
    assert 1 in ps.quarantined


# ---------------------------------------------------------------------------
# serving stall sentinel
# ---------------------------------------------------------------------------


def test_stall_sentinel_convicts_frozen_role():
    sen = StallSentinel(window=2)
    assert sen.observe({0: 5, 1: 5}) == []
    assert sen.observe({0: 6, 1: 5}) == []   # 1 frozen for 1 obs
    assert sen.observe({0: 7, 1: 5}) == []   # frozen for 2 == window: alive
    assert sen.observe({0: 8, 1: 5}) == [1]  # 3 > window: convicted
    # conviction re-arms: no re-report until another full window elapses
    assert sen.observe({0: 9, 1: 5}) == []
    assert sen.observe({0: 10, 1: 5}) == []
    assert sen.observe({0: 11, 1: 5}) == [1]


def test_stall_sentinel_idle_and_reset():
    sen = StallSentinel(window=1)
    sen.observe({0: 3})
    sen.observe({})      # role 0 released its slots: forgotten, not stalled
    sen.observe({0: 3})  # re-bound at the same mark: the clock restarts
    assert sen.observe({0: 3}) == []
    assert sen.observe({0: 3}) == [0]
    sen.reset()
    assert sen.observe({0: 3}) == []  # post-recovery: marks are stale
    with pytest.raises(ValueError):
        StallSentinel(0)


def test_gateway_observe_stalls_reports_physical_slice():
    """The gateway wiring: a convicted cmp role is reported to the
    control plane as its PHYSICAL slice, so the ordinary recovery window
    (shrink/backfill/requeue) evicts the gray worker."""
    from repro.serving.gateway.gateway import GatewayStats, ServeGateway

    gw = ServeGateway.__new__(ServeGateway)  # wiring test: skip the ctor
    gw.sentinel = StallSentinel(window=1)
    gw.stats = GatewayStats()
    st0 = types.SimpleNamespace(slot=(0, 0), fed=3)
    st1 = types.SimpleNamespace(slot=(1, 0), fed=7)
    gw.batcher = types.SimpleNamespace(states={10: st0, 11: st1})
    reported = []
    gw.session = types.SimpleNamespace(
        control=types.SimpleNamespace(report_failure=reported.append))
    gw.engine = types.SimpleNamespace(
        world=types.SimpleNamespace(assignment={0: 4, 1: 6}))
    for _ in range(3):
        gw._observe_stalls()
        st1.fed += 1  # role 1 advances; role 0 is wedged
    assert reported == [4]
    assert gw.stats.stall_evictions == 1


# ---------------------------------------------------------------------------
# flagship chaos scenarios (slow, subprocess): detection by liveness alone,
# recovery bit-identical to failure-free
# ---------------------------------------------------------------------------

_CHAOS_CHILD = """
    import jax, numpy as np, tempfile
    from repro.configs.registry import smoke_config
    from repro.core.simulator import SimCluster
    from repro.store import DurableStore, PartnerMemoryStore, RecoveryLadder

    CFG = smoke_config("qwen2.5-3b")
    STEPS = 6
    WINDOW = 4.0

    def cluster(stores=None, ckpt_dir=None, rung_deadline=0.0, live=True):
        return SimCluster(
            CFG, n_slices=6, model_shards=1, rdegree=1.0, spares=2,
            heal="eager", seq_len=32, stores=stores,
            checkpoint_dir=ckpt_dir,
            checkpoint_every=0 if (stores is None and ckpt_dir is None) else 2,
            suspicion_window=WINDOW if live else 0.0,
            rung_deadline_s=rung_deadline,
        )

    ref = cluster(live=False)
    ref_rep = ref.run(STEPS)
    ref_leaves = jax.tree.leaves(ref.params_replica())

    def bitwise(sim, rep, cell):
        diff = max(
            float(np.max(np.abs(a - b)))
            for a, b in zip(ref_leaves, jax.tree.leaves(sim.params_replica()))
        )
        assert diff == 0.0, f"{cell}: diverged by {diff}"
        assert rep.losses[-1] == ref_rep.losses[-1], f"{cell}: loss"
        assert sim.world.topo.n_comp == ref.world.topo.n_comp, cell
"""


@pytest.mark.slow
def test_chaos_hang_detected_and_recovered_bitwise():
    """A hung slice (beating, zero progress, no report_failure) is
    convicted by the stall detector within the suspicion window, shrunk
    out through the ordinary promote path, and the trajectory stays
    bit-identical to failure-free."""
    out = run_subprocess(_CHAOS_CHILD + """
    sim = cluster()
    rep = sim.run(STEPS, chaos="3:hang:3")
    assert rep.failures == 1 and rep.restarts == 0, (rep.failures, rep.restarts)
    assert len(rep.detections) == 1 and rep.detections[0].startswith("hang:")
    assert 0 < rep.detect_latency[0] <= WINDOW + 1, rep.detect_latency
    assert rep.stalled_units > 0  # the world really did wedge first
    bitwise(sim, rep, "hang")
    print("HANG-OK", rep.detections, rep.detect_latency)
    """, devices=6)
    assert "HANG-OK" in out


@pytest.mark.slow
def test_chaos_drop_detected_as_silence_bitwise():
    """A dropped-heartbeat slice is convicted on pure silence (the
    crash-shaped path) and recovered bit-identically."""
    out = run_subprocess(_CHAOS_CHILD + """
    sim = cluster()
    rep = sim.run(STEPS, chaos="1:drop:2")  # early: silence must outlive
                                            # the window within STEPS ticks
    assert rep.failures == 1, rep.failures
    assert rep.detections == ["silence:2"], rep.detections
    assert 0 < rep.detect_latency[0] <= WINDOW + 1, rep.detect_latency
    bitwise(sim, rep, "drop")
    print("DROP-OK", rep.detections)
    """, devices=6)
    assert "DROP-OK" in out


@pytest.mark.slow
def test_chaos_flap_never_shrinks():
    """A flap (drop shorter than the suspicion window) enters the
    soft-suspect band and recovers: zero failures, zero shrinks, and the
    trajectory is untouched."""
    out = run_subprocess(_CHAOS_CHILD + """
    sim = cluster()
    rep = sim.run(STEPS, chaos="2:flap:1:3")
    assert rep.flaps == 1, rep.flaps
    assert rep.failures == 0 and rep.restarts == 0 and rep.promotes == 0
    assert rep.detections == [], rep.detections
    bitwise(sim, rep, "flap")
    print("FLAP-OK")
    """, devices=6)
    assert "FLAP-OK" in out


@pytest.mark.slow
def test_chaos_fail_slow_peer_routed_around_then_quarantined():
    """The flagship fail-slow cells. (1) K=2 partner redundancy + a slow
    peer with healthy co-holders: the latency-aware pick serves L1 with
    no quarantine. (2) The slow peer left as SOLE holder of a dead
    pair's chunks: quarantined mid-restore within the rung deadline, L1
    fails, L2 serves - both bit-identical to failure-free."""
    out = run_subprocess(_CHAOS_CHILD + """
    # (1) routed around: kill the mirrored pair {1,3}; peer 5 is slow but
    # every chunk has a healthy co-holder
    ps = PartnerMemoryStore(range(6), redundancy=2)
    sim = cluster(stores=RecoveryLadder([ps], rung_deadline_s=0.5),
                  rung_deadline=0.5)
    rep = sim.run(STEPS, failures={3: [1, 3]}, chaos="2:slow:5")
    assert rep.restored_from and rep.restored_from[0].startswith("L1"), rep.restored_from
    assert not rep.quarantines, rep.quarantines
    bitwise(sim, rep, "slow-routed")
    print("SLOW-ROUTED-OK", rep.restored_from)

    # (2) sole holder: kill {0,2} with peer 1 slow -> peer 1 alone holds
    # some chunks -> quarantine within the 0.5s rung budget -> L2 serves
    ps = PartnerMemoryStore(range(6), redundancy=2)
    ladder = RecoveryLadder(
        [ps, DurableStore(tempfile.mkdtemp())], rung_deadline_s=0.5)
    sim = cluster(stores=ladder, rung_deadline=0.5)
    rep = sim.run(STEPS, failures={3: [0, 2]}, chaos="2:slow:1")
    assert rep.restored_from and rep.restored_from[0].startswith("L2"), rep.restored_from
    assert len(rep.quarantines) == 1 and "fail-slow" in rep.quarantines[0], rep.quarantines
    l1 = ladder.attempts[0]
    assert not l1.ok and "quarantined:[1]" in l1.detail, ladder.attempts
    bitwise(sim, rep, "slow-quarantined")
    print("SLOW-QUARANTINE-OK", rep.quarantines)
    """, devices=6)
    assert "SLOW-ROUTED-OK" in out and "SLOW-QUARANTINE-OK" in out
