"""Unit tests: control plane, recovery logs, MTTI model, fault injector,
state transfer, elastic helpers, optimizer, schedules, compression,
data pipeline determinism."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import smoke_config
from repro.core.control_plane import (
    CommunicatorRevoked,
    ControlPlane,
    ProcessFailed,
)
from repro.core.elastic import rebalance_batch
from repro.core.fault_injector import FaultInjector, SDCEvent, SDCSchedule
from repro.core.mtti import (
    daly_interval,
    efficiency,
    expected_failures_to_interruption,
    mtti_montecarlo,
)
from repro.core.recovery import ReplayPlan, StepLog, StepRecord, min_completed_step, replay_plan
from repro.core.replication import ReplicaTopology, WorldState
from repro.core.state_transfer import HostState, clone_state
from repro.data.pipeline import TokenPipeline
from repro.optim.adamw import adamw
from repro.optim.compression import roundtrip
from repro.optim.schedules import warmup_cosine


# ---------------------------------------------------------------------------
# control plane (ULFM semantics)
# ---------------------------------------------------------------------------


def test_control_plane_revoke_propagates():
    cp = ControlPlane(heartbeat_timeout=1e9)
    cp.check(0)  # fine
    cp.report_failure(3)
    with pytest.raises(ProcessFailed):
        cp.check(0)
    gen = cp.revoke()
    with pytest.raises(CommunicatorRevoked):
        cp.check(0)
    failed = cp.agree()
    assert failed == {3}
    cp.shrink_complete(failed)
    cp.check(gen)  # new generation dispatches again


def test_heartbeat_timeout_detection():
    t = [0.0]
    cp = ControlPlane(heartbeat_timeout=5.0, clock=lambda: t[0])
    cp.register(0)
    cp.register(1)
    t[0] = 3.0
    cp.heartbeat(0)
    t[0] = 7.0  # slice 1 last beat at 0 -> expired
    assert cp.detect() == {1}


# ---------------------------------------------------------------------------
# recovery logs
# ---------------------------------------------------------------------------


def _log(role, upto):
    log = StepLog(role)
    for s in range(upto + 1):
        log.record(StepRecord(s, s * 10, s * 10 + 10, s))
    return log


def test_min_completed_and_replay():
    logs = [_log(0, 5), _log(1, 5), _log(2, 4)]  # role 2 lagging
    assert min_completed_step(logs) == 4
    plan = replay_plan(logs, target_step=6)
    assert plan.start_step == 5
    # roles that already applied step 5 must suppress the duplicate
    assert plan.skip == {0: [5], 1: [5]}


def test_replay_plan_restart_path():
    logs = [_log(0, 9)]
    plan = replay_plan(logs, target_step=10, restored_step=6)
    assert plan.start_step == 7 and not plan.skip


def test_log_trim():
    log = _log(0, 9)
    log.trim(5)
    assert min(r.step for r in log.records) == 6


# ---------------------------------------------------------------------------
# MTTI model
# ---------------------------------------------------------------------------


def test_mtti_increases_with_replication():
    """The paper's Fig 9(b): MTTI grows with replication degree."""
    base = mtti_montecarlo(ReplicaTopology.create(16, 0.0), 100.0, trials=400)
    half = mtti_montecarlo(ReplicaTopology.create(16, 0.5), 100.0, trials=400)
    full = mtti_montecarlo(ReplicaTopology.create(16, 1.0), 100.0, trials=400)
    assert base < half < full
    assert full > 2.5 * base  # full replication multiplies MTTI


def test_full_replication_failure_count_birthday():
    """With n mirrored pairs, E[#failures to interruption] ~ sqrt(pi*n/2)+...
    (Ferreira et al.) - must exceed 2 and grow with n."""
    e8 = expected_failures_to_interruption(ReplicaTopology.create(8, 1.0), 500)
    e32 = expected_failures_to_interruption(ReplicaTopology.create(32, 1.0), 500)
    assert 2.0 < e8 < e32


def test_daly_interval_monotone():
    assert daly_interval(100.0, 1.0) < daly_interval(10000.0, 1.0)


def test_efficiency_report_fields():
    out = efficiency(ReplicaTopology.create(8, 0.5), 50.0, 1.0, 2.0, trials=200)
    assert 0 < out["efficiency"] <= 1
    assert out["resource_factor"] == pytest.approx(
        ReplicaTopology.create(8, 0.5).n_comp / 8
    )


def test_fault_injector_deterministic():
    a = FaultInjector(8, scale=10, seed=42).schedule(100.0, list(range(8)))
    b = FaultInjector(8, scale=10, seed=42).schedule(100.0, list(range(8)))
    assert a == b and len(a) > 0


def test_fault_injector_rejects_degenerate_params():
    with pytest.raises(ValueError):
        FaultInjector(8, scale=0.0)
    with pytest.raises(ValueError):
        FaultInjector(8, scale=-10.0)
    with pytest.raises(ValueError):
        FaultInjector(8, shape=0.0)


def test_fault_injector_schedule_bounded_against_spin():
    """A draw stream that stops advancing time must raise instead of
    spinning forever (max_events is the loop bound)."""
    inj = FaultInjector(8, scale=1e-12, shape=0.7, seed=0)
    with pytest.raises(RuntimeError, match="degenerate fault schedule"):
        inj.schedule(100.0, list(range(8)), max_events=1000)


def test_sdc_schedule_duplicate_step_rejected_both_paths():
    """One pending corruption per step is the schedule's contract: a
    duplicate raises from BOTH construction paths (events list and CLI
    parse) - and survives ``python -O``, unlike the old bare assert."""
    with pytest.raises(ValueError, match="duplicate SDC event at step 5"):
        SDCSchedule([SDCEvent(5, 2), SDCEvent(5, 3)])
    with pytest.raises(ValueError, match="duplicate SDC event at step 5"):
        SDCSchedule.parse("5:2,5:3")
    # non-duplicates still construct through both paths
    assert SDCSchedule([SDCEvent(5, 2), SDCEvent(6, 2)]).pending() == 2
    assert SDCSchedule.parse("5:2,6:3").pending() == 2


# ---------------------------------------------------------------------------
# state transfer (3-phase clone)
# ---------------------------------------------------------------------------


def test_clone_state_phases_and_verify():
    params = {"w": jnp.ones((32, 32)), "b": jnp.zeros((32,))}
    opt = {"mu": jnp.zeros((32, 32))}
    host = HostState(step=7, rng_seed=1, data_cursor=70, collective_seq=7, generation=0)
    p2, o2, h2, rep = clone_state(params, opt, host)
    assert rep.verified
    assert set(rep.bytes_by_phase) == {
        "data_segment(params)",
        "heap_segment(optimizer)",
        "stack_segment(host)",
    }
    assert h2.step == 7
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) == 0.0


# ---------------------------------------------------------------------------
# optimizer / schedules / compression
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    opt = adamw(0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"x": jnp.asarray(5.0)}
    state = opt.init(params)
    for _ in range(200):
        g = {"x": 2 * params["x"]}
        params, state, _ = opt.update(g, state, params)
    assert abs(float(params["x"])) < 1e-2


def test_grad_clip_bounds_update():
    opt = adamw(1.0, grad_clip=1.0, weight_decay=0.0)
    p = {"x": jnp.zeros(4)}
    s = opt.init(p)
    _, _, stats = opt.update({"x": jnp.full(4, 1e6)}, s, p)
    assert float(stats["grad_norm"]) > 1e5  # reported raw


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, 10, 100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(jnp.asarray(100))) < 2e-4


@pytest.mark.parametrize("codec", ["none", "bf16", "int8"])
def test_compression_roundtrip_error(codec):
    g = {"w": jnp.linspace(-1, 1, 128)}
    out = roundtrip(g, codec)
    err = float(jnp.max(jnp.abs(out["w"].astype(jnp.float32) - g["w"])))
    bound = {"none": 0.0, "bf16": 6e-3, "int8": 1.2e-2}[codec]
    assert err <= bound


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_pipeline_deterministic_and_seekable():
    cfg = smoke_config("qwen2.5-3b")
    p = TokenPipeline(cfg, seq_len=32, per_slice_batch=2, seed=7)
    a = p.shard(5, 1)["tokens"]
    b = p.shard(5, 1)["tokens"]
    assert np.array_equal(a, b)
    assert not np.array_equal(a, p.shard(6, 1)["tokens"])
    assert not np.array_equal(a, p.shard(5, 2)["tokens"])


def test_pipeline_mirrors_replicas():
    cfg = smoke_config("qwen2.5-3b")
    world = WorldState.create(4, 1.0)  # roles: cmp {0,1}, rep {2<-0, 3<-1}
    p = TokenPipeline(cfg, seq_len=16, per_slice_batch=2, seed=0)
    g = p.global_batch(3, world)["tokens"].reshape(4, 2, 16)
    order = world.roles_in_mesh_order()
    by_role = {r: g[i] for i, r in enumerate(order)}
    assert np.array_equal(by_role[0], by_role[2])
    assert np.array_equal(by_role[1], by_role[3])
    assert not np.array_equal(by_role[0], by_role[1])


def test_rebalance_batch():
    per, pad = rebalance_batch(256, 13)
    assert per * 13 >= 256 and pad == per * 13 - 256
