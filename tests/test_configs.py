"""Config registry + assigned-architecture invariants."""
import pytest

from repro.configs.base import SHAPES, shape_applicable, reduced
from repro.configs.registry import ARCHS, get_arch, get_shape, all_cells

EXPECTED = {
    "command-r-35b": dict(L=40, d=8192, H=64, kv=8, ff=22528, V=256000),
    "gemma3-12b": dict(L=48, d=3840, H=16, kv=8, ff=15360, V=262144),
    "qwen2.5-3b": dict(L=36, d=2048, H=16, kv=2, ff=11008, V=151936),
    "nemotron-4-15b": dict(L=32, d=6144, H=48, kv=8, ff=24576, V=256000),
    "qwen2-vl-2b": dict(L=28, d=1536, H=12, kv=2, ff=8960, V=151936),
    "phi3.5-moe-42b-a6.6b": dict(L=32, d=4096, H=32, kv=8, ff=6400, V=32064),
    "mixtral-8x7b": dict(L=32, d=4096, H=32, kv=8, ff=14336, V=32000),
    "mamba2-2.7b": dict(L=64, d=2560, H=0, kv=0, ff=0, V=50280),
    "hymba-1.5b": dict(L=32, d=1600, H=25, kv=5, ff=5504, V=32001),
    "seamless-m4t-medium": dict(L=12, d=1024, H=16, kv=16, ff=4096, V=256206),
}

# published sizes the param-count formula must land near (absolute, in B)
PARAM_BOUNDS = {
    "mixtral-8x7b": (45.0, 48.5),
    "phi3.5-moe-42b-a6.6b": (40.0, 43.5),
    "mamba2-2.7b": (2.5, 2.9),
    "qwen2.5-3b": (2.8, 3.4),
    "gemma3-12b": (11.0, 12.8),
}


def test_all_archs_present():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("name", list(EXPECTED))
def test_exact_assigned_config(name):
    cfg = get_arch(name)
    e = EXPECTED[name]
    assert cfg.n_layers == e["L"]
    assert cfg.d_model == e["d"]
    assert cfg.n_heads == e["H"]
    assert cfg.n_kv_heads == e["kv"]
    assert cfg.d_ff == e["ff"]
    assert cfg.vocab_size == e["V"]


@pytest.mark.parametrize("name,bounds", list(PARAM_BOUNDS.items()))
def test_param_counts_near_published(name, bounds):
    count = get_arch(name).param_count() / 1e9
    assert bounds[0] <= count <= bounds[1], count


def test_moe_active_params():
    phi = get_arch("phi3.5-moe-42b-a6.6b")
    assert 6.0e9 < phi.active_param_count() < 7.3e9  # "a6.6b"


def test_cell_count_is_40():
    cells = list(all_cells())
    assert len(cells) == 40
    skipped = [c for c in cells if not c[2]]
    # 6 pure-full-attention archs skip long_500k
    assert len(skipped) == 6
    assert all(s[1].name == "long_500k" for s in skipped)


def test_long_context_archs_run_long_500k():
    for name in ("gemma3-12b", "mixtral-8x7b", "mamba2-2.7b", "hymba-1.5b"):
        ok, _ = shape_applicable(get_arch(name), get_shape("long_500k"))
        assert ok, name


def test_reduced_configs_are_small_same_family():
    for name, cfg in ARCHS.items():
        r = reduced(cfg)
        assert r.family == cfg.family
        assert r.d_model <= 64 and r.vocab_size <= 256
        if cfg.moe:
            assert r.moe and r.moe.n_experts <= 4
        if cfg.ssm:
            assert r.ssm and r.ssm.d_state <= 16


def test_padded_vocab_divides_model_axis():
    for cfg in ARCHS.values():
        assert cfg.padded_vocab() % 16 == 0
        assert cfg.padded_vocab() >= cfg.vocab_size
