"""repro.scrub: the online SDC scrubbing plane.

Fast units run in-process (1 device): the sign-blindness regression the
old sum-of-squares checksum provably missed, the symmetric digest
tolerance, digest edge cases across the streaming rewrite, the in-graph
bit-flip port, the majority vote, the deterministic injector, and the
chunk-addressed partner reads + digest-guided partial restore.

The slow subprocess integration drives the whole lifecycle through
``SimCluster.run``: a single injected bit flip is detected within one
step, the vote names the victim, the repair moves only the poisoned
chunks, and the trajectory stays bit-identical to a failure-free run.
"""
import numpy as np
import pytest

from conftest import run_subprocess


# ---------------------------------------------------------------------------
# satellite 1: the sign-blindness bugfix
# ---------------------------------------------------------------------------


def test_sign_flip_regression_old_formula_blind_new_digest_not():
    """``sum(x**2)`` is invariant under ``x -> -x`` of any element (the
    old sdc_check scalar) - a flipped sign bit sailed through. The
    [abs-sum, sum] rows catch it: the sum column moves by 2|x| while the
    abs-sum column stays pinned."""
    from repro.scrub.digest import leaf_digest_matrix

    x = np.linspace(0.5, 2.0, 256).astype(np.float32)
    flipped = x.copy()
    flipped[37] *= -1.0  # exactly the sign bit: |x| unchanged

    # the OLD formula: bitwise identical on the corrupted copy
    old_a = np.sum(x * x)
    old_b = np.sum(flipped * flipped)
    assert old_a == old_b, "old sum-of-squares must miss (that's the bug)"

    da = np.asarray(leaf_digest_matrix({"w": x}, 128))
    db = np.asarray(leaf_digest_matrix({"w": flipped}, 128))
    assert da.shape == (2, 2)
    row = 37 // 128
    assert da[row, 0] == db[row, 0], "abs-sum column pinned under sign flip"
    assert abs(da[row, 1] - db[row, 1]) == pytest.approx(
        2.0 * abs(x[37]), rel=1e-5
    )
    # and the other chunk is untouched (localization)
    assert np.array_equal(da[1 - row], db[1 - row])


def test_xfer_digest_sign_column_catches_sign_flip():
    """Same regression through the fused-kernel xfer path."""
    from repro.xfer.digest import tree_digests, verify_tree

    a = {"w": np.linspace(0.5, 2.0, 256).astype(np.float32)}
    b = {"w": a["w"].copy()}
    b["w"][37] *= -1.0
    da, db = tree_digests(a), tree_digests(b)
    assert np.array_equal(da[:, 0], db[:, 0])  # abs-sum blind here...
    assert not np.array_equal(da[:, 1], db[:, 1])  # ...sum column is not
    assert not verify_tree(a, b)


# ---------------------------------------------------------------------------
# satellite 2: symmetric digest tolerance
# ---------------------------------------------------------------------------


def test_digest_tolerance_symmetric_in_arguments():
    """The old tolerance scaled by |a| only, so verify(src, dst) and
    verify(dst, src) could disagree when one side sat just past the
    other's boundary. The scale is now max(|a|, |b|) - pinned here by an
    asymmetric pair that the a-scaled bound accepts one way and rejects
    the other."""
    from repro.xfer.digest import digest_tolerance, digests_match

    a = np.array([[1e8, 1e8]], np.float32)
    b = a * (1.0 + 5e-7)  # within 1e-6 relative of max(|a|,|b|)
    # the old a-scaled bound: tol(a) accepts, tol(b) would too, but an
    # a-scaled bound with a the SMALLER side shrinks: make it asymmetric
    small = np.array([[1.0, 1.0]], np.float32)
    big = np.array([[1.0 + 3e-6, 1.0 + 3e-6]], np.float32) * 1e7
    t_ab = digest_tolerance(small * 1e7, big)
    t_ba = digest_tolerance(big, small * 1e7)
    assert np.array_equal(t_ab, t_ba), "tolerance must be symmetric"
    assert digests_match(a, b) and digests_match(b, a)
    assert not digests_match(small * 1e7, big)
    assert not digests_match(big, small * 1e7)  # same verdict both ways


def test_digests_match_shape_guard_and_empty():
    from repro.xfer.digest import digests_match

    z = np.zeros((0, 2), np.float32)
    assert digests_match(z, z)
    assert not digests_match(z, np.zeros((1, 2), np.float32))


# ---------------------------------------------------------------------------
# satellite 4: digest edge cases, bit-stable across the streaming rewrite
# ---------------------------------------------------------------------------


def _reference_digests(tree, chunk_elems):
    """The pre-rewrite semantics: ONE concatenate of the whole fp32
    stream, digested in a single kernel feed."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.checksum_ops import chunk_digests
    from repro.xfer.digest import _chunk_elems

    leaves = [x for x in jax.tree.leaves(tree) if hasattr(x, "dtype")]
    n = sum(int(np.prod(x.shape)) for x in leaves)
    if n == 0:
        return np.zeros((0, 2), np.float32)
    flat = jnp.concatenate([jnp.ravel(x).astype(jnp.float32) for x in leaves])
    return np.asarray(chunk_digests(flat, chunk_elems=_chunk_elems(n, chunk_elems)))


@pytest.mark.parametrize("segment_chunks", [1, 2, 64])
def test_tree_digests_segmented_bit_identical_to_concat(segment_chunks):
    """Segment boundaries are chunk-aligned, so the streaming rewrite is
    bit-identical to the old full-concat pass for ANY segment size -
    including segments that straddle leaf boundaries."""
    from repro.xfer.digest import tree_digests

    rng = np.random.default_rng(0)
    # 200 + 100 + 31 elements with 128-elem chunks: chunk 1 straddles the
    # a/b leaf boundary, chunk 2 straddles b/c and is a partial tail
    tree = {
        "a": rng.standard_normal(200).astype(np.float32),
        "b": rng.standard_normal(100).astype(np.float32) * 50.0,
        "c": rng.standard_normal(31).astype(np.float32),
    }
    ref = _reference_digests(tree, 128)
    got = tree_digests(tree, chunk_elems=128, segment_chunks=segment_chunks)
    assert got.shape == ref.shape == (3, 2)
    assert np.array_equal(got, ref), "streaming must be bit-identical"


def test_tree_digests_mixed_dtypes_and_small_trees():
    """bf16 / int8 / bool leaves, an empty pytree, and a tree smaller
    than one segment all digest without crashing and stay bit-stable
    across segment sizes."""
    import jax.numpy as jnp

    from repro.xfer.digest import tree_digests

    tree = {
        "bf16": jnp.asarray(np.arange(40, dtype=np.float32), jnp.bfloat16),
        "i8": np.arange(-8, 8, dtype=np.int8),
        "flag": np.array([True, False, True]),
        "f32": np.linspace(-1, 1, 300, dtype=np.float32),
    }
    d1 = tree_digests(tree, chunk_elems=128, segment_chunks=1)
    d64 = tree_digests(tree, chunk_elems=128, segment_chunks=64)
    assert d1.shape[1] == 2 and d1.shape[0] >= 1
    assert np.array_equal(d1, d64)
    assert np.array_equal(d1, _reference_digests(tree, 128))

    assert tree_digests({}).shape == (0, 2)
    assert tree_digests({"e": np.zeros((0,), np.float32)}).shape == (0, 2)
    # scalar / sub-chunk tree: the chunk shrinks, one row comes back
    tiny = tree_digests({"s": np.float32(3.0)})
    assert tiny.shape == (1, 2) and tiny[0, 1] == 3.0


def test_scrub_digest_chunks_never_straddle_leaves():
    """The scrub-space chunking pads each leaf to whole chunks, so a
    poisoned chunk names its leaf exactly; non-float leaves are skipped
    (they are replicated control state, not compute output)."""
    from repro.scrub.digest import (
        chunk_leaf_map,
        leaf_digest_matrix,
        n_scrub_chunks,
    )

    tree = {
        "a": np.ones(200, np.float32),
        "flags": np.array([1, 2], np.int8),
        "z": np.ones((2, 70), np.float32),
    }
    # leaves order: a, flags, z -> float leaves at full-tree idx 0 and 2
    assert n_scrub_chunks(tree, 128) == 2 + 2
    assert chunk_leaf_map(tree, 128).tolist() == [0, 0, 2, 2]
    d = np.asarray(leaf_digest_matrix(tree, 128))
    assert d.shape == (4, 2)
    # padded tail chunk of "a" holds elements 128..199 -> abs-sum 72
    assert d[1, 0] == 72.0
    assert np.asarray(leaf_digest_matrix({}, 128)).shape == (0, 2)
    assert np.asarray(
        leaf_digest_matrix({"i": np.ones(4, np.int32)}, 128)
    ).shape == (0, 2)


# ---------------------------------------------------------------------------
# the in-graph bit-flip port
# ---------------------------------------------------------------------------


def test_inject_bitflip_gated_on_slice_target_and_armed():
    import jax.numpy as jnp

    from repro.scrub.digest import (
        NULL_SPEC,
        TARGET_GRAD,
        TARGET_PARAM,
        encode_spec,
        inject_bitflip,
    )

    tree = {"w": np.linspace(0.5, 2.0, 64).astype(np.float32)}
    spec = jnp.asarray(encode_spec(victim=2, target="param", leaf=0, elem=5, bit=31))

    hit = inject_bitflip(tree, spec, jnp.int32(2), TARGET_PARAM)
    miss_slice = inject_bitflip(tree, spec, jnp.int32(1), TARGET_PARAM)
    miss_target = inject_bitflip(tree, spec, jnp.int32(2), TARGET_GRAD)
    disarmed = inject_bitflip(tree, jnp.asarray(NULL_SPEC), jnp.int32(2), TARGET_PARAM)

    want = tree["w"].copy()
    want[5] *= -1.0  # bit 31 IS the sign bit
    assert np.array_equal(np.asarray(hit["w"]), want)
    for t in (miss_slice, miss_target, disarmed):
        assert np.array_equal(np.asarray(t["w"]), tree["w"])


def test_inject_bitflip_clamps_out_of_range_spec():
    import jax.numpy as jnp

    from repro.scrub.digest import TARGET_PARAM, encode_spec, inject_bitflip

    tree = {"w": np.ones(8, np.float32)}
    spec = jnp.asarray(encode_spec(0, "param", leaf=0, elem=10_000, bit=99))
    out = inject_bitflip(tree, spec, jnp.int32(0), TARGET_PARAM)
    # clamped to last element / bit 31: exactly one element changed
    w = np.asarray(out["w"])
    assert (w != tree["w"]).sum() == 1 and w[7] == -1.0


# ---------------------------------------------------------------------------
# the majority vote
# ---------------------------------------------------------------------------


def _table(rows):
    return np.asarray(rows, np.float32)


def test_mismatched_pairs_and_rows_differ():
    from repro.scrub.vote import mismatched_pairs, rows_differ

    good = [[4.0, 1.0], [8.0, 2.0]]
    bad = [[4.0, 1.5], [8.0, 2.0]]
    table = _table([good, bad, good, good])
    assert rows_differ(_table(good), _table(bad)).tolist() == [True, False]
    assert mismatched_pairs(table, [(0, 1), (2, 3)]) == [(0, 1)]
    assert mismatched_pairs(table, [(0, 2), (3,)]) == []  # singleton skipped


def test_majority_vote_names_victim_and_poisoned_chunks():
    from repro.scrub.vote import majority_vote

    good = [[4.0, 1.0], [8.0, 2.0]]
    bad = [[4.0, 1.5], [8.0, 2.0]]
    table = _table([good, bad, good, good])
    v = majority_vote(table, (0, 1))
    assert v.conclusive and v.victim == 1
    assert v.poisoned_chunks.tolist() == [0]
    assert v.holders == 2


def test_majority_vote_two_slice_tie_broken_by_reference():
    """A mirrored pair alone cannot name the victim (RedMPI's blind
    spot): without a third holder the vote is inconclusive; the scrub
    plane's last-submit reference breaks the tie - under a relative
    tolerance, because host and in-step reductions may associate
    differently."""
    from repro.scrub.vote import majority_vote

    good = np.asarray([[4.0, 1.0]], np.float32)
    bad = np.asarray([[4.0, 1.5]], np.float32)
    table = np.stack([good, bad])
    v = majority_vote(table, (0, 1))
    assert not v.conclusive and v.victim is None

    # reference a last-ulp off the good row still votes for slice 0
    ref = good * (1.0 + 1e-7)
    v = majority_vote(table, (0, 1), reference=ref)
    assert v.conclusive and v.victim == 1 and v.holders == 1

    # a reference of the wrong shape (layout drift) is ignored
    v = majority_vote(table, (0, 1), reference=np.zeros((3, 2), np.float32))
    assert not v.conclusive


# ---------------------------------------------------------------------------
# satellite 3: deterministic injection scheduling
# ---------------------------------------------------------------------------


def test_sdc_schedule_parse():
    from repro.core.fault_injector import SDCSchedule

    s = SDCSchedule.parse("3:1, 7:0:grad, 9:2:param:4:100:31")
    assert s.pending() == 3
    e = s.take(3)
    assert (e.victim, e.target, e.resolved) == (1, "param", False)
    e = s.take(7)
    assert (e.victim, e.target) == (0, "grad")
    e = s.take(9)
    assert (e.leaf, e.elem, e.bit) == (4, 100, 31) and e.resolved
    assert s.take(9) is None  # consumed
    assert not SDCSchedule.parse("")
    for bad in ("5", "5:1:oops", "5:1:param:2", "x:y"):
        with pytest.raises(ValueError):
            SDCSchedule.parse(bad)


def test_sdc_injector_deterministic_and_respects_given_fields():
    from repro.core.fault_injector import SDCEvent, SDCInjector

    sizes = [(0, 1000), (3, 4096)]
    a = SDCInjector(seed=7).resolve(SDCEvent(5, 1), sizes)
    b = SDCInjector(seed=7).resolve(SDCEvent(5, 1), sizes)
    assert (a.leaf, a.elem, a.bit) == (b.leaf, b.elem, b.bit)
    assert a.leaf in (0, 3) and 0 <= a.bit < 32
    assert a.elem < dict(sizes)[a.leaf]
    c = SDCInjector(seed=7).resolve(SDCEvent(5, 1, "grad", leaf=3, bit=31), sizes)
    assert c.leaf == 3 and c.bit == 31 and c.elem < 4096
    with pytest.raises(AssertionError):
        SDCInjector().resolve(SDCEvent(5, 1, leaf=2), sizes)  # not flippable


# ---------------------------------------------------------------------------
# chunk-addressed partner reads + digest-guided partial restore
# ---------------------------------------------------------------------------


def _state(scale=1.0):
    return {
        "w": (np.arange(4096, dtype=np.float32) * scale),
        "b": (np.ones(1024, np.float32) * scale),
    }


def _ladder(**plane_kw):
    from repro.store import PartnerMemoryStore, RecoveryLadder
    from repro.xfer import TransferPlane

    plane_kw.setdefault("chunk_bytes", 4096)
    plane_kw.setdefault("pipeline", False)
    return RecoveryLadder(
        [PartnerMemoryStore(range(4))], xfer=TransferPlane(**plane_kw)
    )


def test_partner_chunk_manifest_and_load_chunks():
    lad = _ladder()
    lad.submit(2, _state(), {"step": 2})
    store = lad.store(1)
    got = store.chunk_manifest()
    assert got is not None
    step, entry = got
    assert step == 2 and len(entry["crcs"]) == entry["n_chunks"]
    fetched = store.load_chunks(2, [0, 3])
    assert set(fetched) == {0, 3}
    assert all(r.nbytes == 4096 for r in fetched.values())
    # exact bytes: chunk 0 is the first 1024 floats of "b" (path order)
    assert store.load_chunks(2, [entry["n_chunks"]]) is None  # out of range
    assert store.load_chunks(99, [0]) is None  # unknown step
    # entries without fingerprints (pre-crc submits) opt out of partial
    store._manifest[2]["crcs"] = None
    assert store.chunk_manifest() is None


def test_restore_partial_moves_only_stale_chunks():
    lad = _ladder()
    clean = _state()
    lad.submit(2, clean, {"step": 2})

    current = {k: v.copy() for k, v in clean.items()}
    current["w"][100] *= -1.0  # one poisoned element -> one stale chunk
    got = lad.restore_partial(current)
    assert got is not None and got.step == 2
    assert got.moved_chunks == 1 and got.n_chunks == got.total_bytes // 4096
    assert got.moved_bytes == 4096 < got.total_bytes
    for k in clean:
        assert np.array_equal(got.state[k], clean[k]), k

    # an uncorrupted view moves NOTHING
    got = lad.restore_partial({k: v.copy() for k, v in clean.items()})
    assert got.moved_chunks == 0 and got.moved_bytes == 0

    # layout drift (shape change since the submit) -> None (full-walk
    # fallback is the caller's job)
    assert lad.restore_partial({"w": np.ones(8, np.float32)}) is None


def test_restore_partial_through_delta_encoded_submits():
    """Fingerprints are recorded on the PRE-encode raw chunks, so partial
    restore stays byte-exact when the partner level delta-encodes."""
    lad = _ladder(delta="bf16")
    lad.submit(2, _state(1.0), {"step": 2})
    lad.submit(4, _state(1.001), {"step": 4})
    want = _state(1.001)
    current = {k: v.copy() for k, v in want.items()}
    current["b"][5] += 7.0
    got = lad.restore_partial(current)
    assert got is not None and got.step == 4
    assert 1 <= got.moved_chunks < got.n_chunks
    for k in want:
        assert np.array_equal(got.state[k], want[k]), k


# ---------------------------------------------------------------------------
# the full lifecycle: detect -> vote -> partial restore -> bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sdc_lifecycle_detect_vote_partial_restore_bit_identity():
    out = run_subprocess(
        """
        import numpy as np, jax
        from repro.configs.registry import smoke_config
        from repro.core.fault_injector import SDCEvent, SDCSchedule
        from repro.core.simulator import SimCluster

        model = smoke_config("qwen2.5-3b")
        KW = dict(n_slices=4, model_shards=2, rdegree=1.0, seq_len=16,
                  per_slice_batch=2, checkpoint_every=2,
                  chunk_bytes=64 * 1024, sdc_check=True)

        def tree_eq(a, b):
            return all(np.array_equal(np.asarray(x), np.asarray(y))
                       for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

        base = SimCluster(model, **KW)
        rb = base.run(6)
        assert rb.sdc_detected == 0, "healthy mirrors must scrub clean"
        base_params = base.params_replica()

        # persistent param-space flip one step after a checkpoint: the
        # vote must name physical slice 2 and the repair must move less
        # than the blob
        sim = SimCluster(model, sdc_inject=True, **KW)
        rep = sim.run(6, sdc=SDCSchedule(
            [SDCEvent(step=3, victim=2, target="param")]))
        assert rep.sdc_detected == 1 and rep.sdc_repairs == 1, (
            rep.sdc_detected, rep.sdc_repairs)
        assert rep.restarts == 0, "partial restore must serve this"
        assert 0 < rep.sdc_bytes_moved < 0.5 * rep.sdc_bytes_full, (
            rep.sdc_bytes_moved, rep.sdc_bytes_full)
        assert any("[partial:" in s for s in rep.restored_from), rep.restored_from
        assert any("victim=" in e for e in rep.events), rep.events
        assert rep.losses == rb.losses
        assert tree_eq(sim.params_replica(), base_params)

        # transient grad-space sign flip: param tables stay clean, one
        # retry resolves it, nothing is restored
        sim2 = SimCluster(model, sdc_inject=True, **KW)
        r2 = sim2.run(6, sdc=SDCSchedule(
            [SDCEvent(step=3, victim=1, target="grad", bit=31)]))
        assert r2.sdc_detected == 1 and r2.sdc_transient == 1, (
            r2.sdc_detected, r2.sdc_transient)
        assert r2.sdc_repairs == 0 and r2.restarts == 0 and not r2.restored_from
        assert r2.losses == rb.losses
        assert tree_eq(sim2.params_replica(), base_params)
        print("SCRUB_LIFECYCLE_OK")
        """,
        devices=8,
    )
    assert "SCRUB_LIFECYCLE_OK" in out
