"""Serving-engine unit logic (host-side, no devices needed): cache row
repacking across failover, token mirroring, and decode-cache layouts."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import smoke_config
from repro.core.replication import WorldState
from repro.models import model as M


def test_repack_moves_promoted_cache_rows():
    """After promote, the new mesh order must draw each role's cache rows
    from the physical slice that now owns the role (replica keeps its own
    rows). Mirrors ServeEngine._failover's repack."""
    old = WorldState.create(4, 1.0)  # cmp {0,1} reps {2<-0, 3<-1}
    new, rep = old.repair([0])  # phys 2 promoted into role 0
    assert rep["promoted"] == [(0, 2)]
    b = 2  # per-slice batch
    # cache arr: (L=1, B_total=8, F=1), row value = physical slice id
    arr = np.repeat(np.arange(4), b).reshape(1, 8, 1).astype(np.float32)

    old_pos = old.mesh_position()
    new_order = new.roles_in_mesh_order()
    rows = []
    for r in new_order:
        phys = new.assignment[r]
        src = old_pos[phys]
        rows.append(arr[:, src * b : (src + 1) * b])
    packed = np.concatenate(rows, axis=1)
    # live physicals sorted: [1, 2, 3] -> roles [1, 0, rep(1)]
    live = new.live_physicals()
    assert live == [1, 2, 3]
    for i, phys in enumerate(live):
        assert (packed[:, i * b : (i + 1) * b] == phys).all()


def test_mirror_source_after_repair():
    w = WorldState.create(6, 0.5)  # 4 cmp + 2 rep
    w2, _ = w.repair([0])
    src = w2.topo.mirror_source()
    # every replica consumes a live computational shard
    for j, c in enumerate(w2.topo.replica_map):
        assert src[w2.topo.n_comp + j] == c < w2.topo.n_comp


@pytest.mark.parametrize("name", ["gemma3-12b", "mamba2-2.7b", "seamless-m4t-medium"])
def test_cache_layout_by_family(name):
    cfg = smoke_config(name)
    cache = M.init_cache(cfg, batch=3, max_len=32, enc_len=8, dtype=jnp.float32)
    leaves = jax.tree.leaves(cache)
    assert all(l.dtype in (jnp.float32, jnp.int32) for l in leaves)
    if name == "mamba2-2.7b":
        assert set(cache["blocks"].keys()) == {"conv_x", "conv_bc", "ssm"}
        L, B = cache["blocks"]["ssm"].shape[:2]
        assert (L, B) == (cfg.n_layers, 3)
    if name == "seamless-m4t-medium":
        assert "cross" in cache
        assert cache["cross"]["k"].shape[2] == 8  # enc_len
    if name == "gemma3-12b":
        # grouped: local windows are capped at the window size
        loc = cache["groups"]["local"]["k"]
        glob = cache["groups"]["global"]["k"]
        assert loc.shape[3] == min(cfg.window, 32)
        assert glob.shape[2] == 32


def test_decode_cache_window_ring_buffer():
    """A sliding-window ring cache must match full attention while pos <
    window (prefix fits), by construction of the modular slot."""
    cfg = smoke_config("mixtral-8x7b")
    assert cfg.window == 32
    cache = M.init_cache(cfg, 2, max_len=32, dtype=jnp.float32)
    k = cache["blocks"]["k"]
    assert k.shape[2] == 32  # ring size = window
