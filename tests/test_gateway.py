"""repro.serving.gateway - request lifecycle, continuous batching, and
failover-transparent requeue.

Fast tests drive the REAL gateway/queue/registry/batcher code over a
FakeEngine (a deterministic pure-function decoder that honors the
ServeEngine slot contract, including the repack accounting and the
"backfilled rows are garbage" property of a real host loss) and the real
WorldState repair/heal algebra. Slow tests run the real engine in
subprocesses: the flagship asserts every client stream is bit-identical
across an unmirrored mid-decode kill + spare backfill, with bounded TTFT
and no more serve steps than the fixed-batch baseline.
"""
from __future__ import annotations

import os
import subprocess
import sys
import types

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import SRC, run_subprocess
from repro.core.replication import WorldState
from repro.serving.gateway import (
    AdmissionQueue,
    ContinuousBatcher,
    QueueFull,
    Request,
    RequestStream,
    ServeGateway,
    WorkerRegistry,
    validate_bounds,
)

# ---------------------------------------------------------------------------
# FakeEngine: the ServeEngine slot contract without devices
# ---------------------------------------------------------------------------


class FakeEngine:
    """Deterministic per-slot decoder honoring the slot-granular engine
    contract: ``step_slots`` appends the fed token to each slot's private
    history and emits a pure function of it; ``reset_slots`` makes a slot
    a fresh sequence; ``repack`` mirrors ``ServeEngine.repack_state``'s
    renumbering + live-slot requeue accounting, and fills a BACKFILLED
    role's history with garbage (a real spare adopts none of the dead
    host's memory) - so a gateway that forgets to reset + requeue those
    slots diverges loudly."""

    slot_granular = True
    GARBAGE = 10_000

    def __init__(self, world, lanes=2, max_len=64, vocab=50):
        self.session = types.SimpleNamespace(
            world=world, ladder=[], program=None, last_repair={},
            healer=types.SimpleNamespace(on_capacity=None),
        )
        self.per_slice_batch = lanes
        self.max_len = max_len
        self.vocab = vocab
        self.report = types.SimpleNamespace(requeued_requests=0, promotes=0,
                                            tokens_decoded=0)
        self.slot_active = np.zeros((world.topo.n_comp, lanes), bool)
        self.hist = {}  # (cmp_role, lane) -> fed tokens

    @property
    def world(self):
        return self.session.world

    @property
    def n_lanes(self):
        return self.per_slice_batch

    def _next(self, seq):
        return (seq[-1] * 31 + 7 * len(seq) + sum(seq)) % (self.vocab - 1) + 1

    def step_slots(self, fed):
        out = np.zeros(fed.shape, np.int32)
        for r in range(fed.shape[0]):
            for lane in range(fed.shape[1]):
                h = self.hist.setdefault((r, lane), [])
                h.append(int(fed[r, lane]))
                out[r, lane] = self._next(h)
        self.report.tokens_decoded += int(self.slot_active.sum())
        return out

    def reset_slots(self, slots):
        for s in slots:
            self.hist[tuple(s)] = []

    def repack(self, old_world, new_world, rep):
        lost = rep["lost_cmp"]
        self.report.requeued_requests += int(self.slot_active[lost].sum())
        self.report.promotes += len(rep["promoted"])
        backfilled = {r for r, _ in rep["backfilled"]}
        n = new_world.topo.n_comp
        hist, active = {}, np.zeros((n, self.per_slice_batch), bool)
        for r in range(n):
            old = rep["role_map"][r]
            for lane in range(self.per_slice_batch):
                if r in backfilled:
                    hist[(r, lane)] = [self.GARBAGE] * 3
                else:
                    hist[(r, lane)] = self.hist.get((old, lane), [])
                # stale for backfilled roles too - clearing it is the
                # gateway's job (mirrors the real repack)
                active[r, lane] = self.slot_active[old, lane]
        self.hist, self.slot_active = hist, active
        self.session.last_repair = rep


def fake_gateway(n_slices=3, rdegree=0.0, spares=1, lanes=2, max_queue=64,
                 **kw):
    # n_slices = serving slices; spares ride on top (WorldState.create's
    # n_slices counts the whole physical pool)
    world = WorldState.create(n_slices + spares, rdegree, n_spares=spares)
    return ServeGateway(FakeEngine(world, lanes=lanes), max_queue=max_queue,
                        **kw)


def fake_kill(gw, victims, heal=True):
    """The FTSession.recover window over the real WorldState algebra:
    repair -> heal -> engine repack -> capacity callback -> on_recover.
    Returns False when the kill is skipped (dead/unknown victims, or it
    would leave no computational roles)."""
    eng = gw.engine
    old = eng.world
    live = set(old.assignment) | set(old.spares)
    victims = sorted(set(victims) & live)
    if not victims:
        return False
    use_spares = heal and bool(old.spares)
    new_world, rep = old.repair(victims, use_spares=use_spares)
    if new_world.topo.n_comp == 0:
        return False
    hplan = None
    if heal and new_world.spares:
        healed, hplan = new_world.heal()
        if hplan:
            new_world = healed
    eng.repack(old, new_world, rep)
    eng.session.world = new_world
    fresh = [p for _, p in rep["backfilled"]]
    if hplan:
        fresh += [a.spare for a in hplan.actions]
    if fresh and eng.session.healer.on_capacity is not None:
        eng.session.healer.on_capacity(new_world, hplan, fresh)
    gw.on_recover(old, new_world, rep, plan=None)
    return True


# ---------------------------------------------------------------------------
# queue / stream / registry / bounds units
# ---------------------------------------------------------------------------


def _req(rid, prompt=(1, 2), max_new=4, eos_id=None):
    return Request(rid=rid, prompt=tuple(prompt), max_new=max_new,
                   eos_id=eos_id, stream=RequestStream(rid, submitted_step=0))


def test_queue_fifo_and_backpressure():
    q = AdmissionQueue(max_queue=2)
    q.admit(_req(0))
    q.admit(_req(1))
    with pytest.raises(QueueFull):
        q.admit(_req(2))
    assert (q.admitted, q.rejected, len(q)) == (2, 1, 2)
    assert [q.pop().rid, q.pop().rid] == [0, 1]
    assert q.pop() is None and not q


def test_queue_requeue_bypasses_bound_and_goes_front():
    q = AdmissionQueue(max_queue=1)
    q.admit(_req(0))
    q.requeue(_req(7))  # at capacity - still accepted, at the FRONT
    q.requeue(_req(8))
    assert [r.rid for r in q] == [8, 7, 0]
    assert q.requeued == 2 and q.rejected == 0


def test_stream_cursor_and_ttft():
    s = RequestStream(0, submitted_step=3)
    assert s.cursor == 0 and s.ttft_steps() is None
    s.emit(11, step=5)
    s.emit(12, step=6)
    assert s.tokens == [11, 12] and s.cursor == 2
    assert s.ttft_steps() == 2 and s.first_token_step == 5
    s.finish("eos", step=6)
    assert s.done and s.finish_reason == "eos"
    with pytest.raises(AssertionError):
        s.emit(13, step=7)


def test_validate_bounds_edges():
    validate_bounds(1, None)
    validate_bounds(1, 1)
    for mq, ms in [(0, None), (-3, None), (1, 0), (1, -1)]:
        with pytest.raises(ValueError):
            validate_bounds(mq, ms)


def test_registry_sync_bind_and_bijection():
    world = WorldState.create(5, 1.0, n_spares=1)  # 2 cmp + 2 rep + 1 spare
    reg = WorkerRegistry(lanes=2)
    reg.sync(world)
    assert reg.n_comp == 2 and reg.n_slots == 4
    kinds = sorted(w.kind for w in reg.workers.values())
    assert kinds == ["cmp", "cmp", "replica", "replica", "spare"]
    reg.bind((0, 0), 10)
    reg.bind((1, 1), 11)
    assert (0, 0) not in reg.free_slots() and len(reg.free_slots()) == 2
    with pytest.raises(AssertionError):
        reg.bind((0, 0), 12)  # slot already bound
    reg.check()
    assert reg.release((0, 0)) == 10
    # rebind after a repair-style renumbering revalidates everything
    reg.rebind({(0, 1): 11})
    reg.check()
    with pytest.raises(AssertionError):
        reg.rebind({(5, 0): 1})  # dead role


# ---------------------------------------------------------------------------
# batcher over the FakeEngine
# ---------------------------------------------------------------------------


def drive(gw, steps, kills=None, start=0):
    kills = dict(kills or {})
    for t in range(start, start + steps):
        for v in kills.pop(t, []):
            fake_kill(gw, [v])
        gw.run_step(t)
    return gw


def test_batcher_prefill_stream_and_slot_refill():
    gw = fake_gateway(n_slices=1, spares=0, lanes=1, max_queue=8)
    a = gw.submit([5, 6, 7], max_new=3)
    b = gw.submit([9], max_new=2)  # waits: the single slot is taken
    drive(gw, 20)
    assert a.done and a.finish_reason == "max_new" and len(a.tokens) == 3
    assert b.done and len(b.tokens) == 2
    # prefill feeds the prompt token-by-token: the last prompt feed (step
    # plen-1) predicts the first generated token
    assert a.ttft_steps() == 2
    # b bound only after a finished (continuous refill on the freed slot)
    assert gw.streams[1].first_token_step > gw.streams[0].finished_step
    # the fake decoder is a pure function of the sequence - the oracle
    eng = FakeEngine(WorldState.create(1, 0.0, n_spares=0), lanes=1)
    seq = [5, 6, 7]
    for _ in range(3):
        seq.append(eng._next(seq))
    assert a.tokens == seq[3:]
    assert gw.stats.completed == 2 and gw.queue.admitted == 2


def test_batcher_eos_finish_frees_slot():
    gw = fake_gateway(n_slices=1, spares=0, lanes=1)
    eng = gw.engine
    # find the first generated token for prompt [3] and use it as eos
    probe = [3]
    eos = eng._next(probe)
    s = gw.submit([3], max_new=10, eos_id=eos)
    drive(gw, 5)
    assert s.done and s.finish_reason == "eos" and s.tokens == [eos]
    assert gw.registry.free_slots() == [(0, 0)]
    assert not eng.slot_active.any()


def test_batcher_replay_suppression_pins_streamed_prefix():
    """A requeued request re-prefills prompt + streamed tokens; outputs
    below the cursor are verified re-generations, never re-emitted."""
    gw = fake_gateway(n_slices=1, spares=0, lanes=1)
    s = gw.submit([5, 6], max_new=6)
    drive(gw, 4)  # 2 prompt feeds, then 3 generated (last feed emits)
    assert s.cursor == 3 and not s.done
    seen = list(s.tokens)
    # simulate the failover path: evict, zero the slot, requeue
    req = gw.batcher.evict_roles({0})[0]
    gw.registry.rebind({})
    gw.engine.reset_slots([(0, 0)])
    gw.engine.slot_active[(0, 0)] = False
    gw.queue.requeue(req)
    drive(gw, 20, start=4)
    assert s.done and len(s.tokens) == 6
    assert s.tokens[:3] == seen, "replay duplicated or rewrote streamed tokens"


def test_gateway_submit_validation_and_scheduled_rejection():
    gw = fake_gateway(max_queue=1)
    with pytest.raises(ValueError):
        gw.submit([], max_new=2)
    with pytest.raises(ValueError):
        gw.submit([1], max_new=0)
    with pytest.raises(ValueError):
        gw.submit([1] * 60, max_new=10)  # exceeds max_len=64
    # deferred arrivals that meet a full queue finish as "rejected"
    gw2 = fake_gateway(n_slices=1, spares=0, lanes=1, max_queue=1)
    keep = gw2.submit([1, 2], max_new=8)
    blocked = [gw2.submit([3], max_new=2, at_step=1) for _ in range(2)]
    drive(gw2, 2)
    reasons = sorted(b.finish_reason or "" for b in blocked)
    assert "rejected" in reasons and gw2.queue.rejected >= 1
    assert not keep.done


def test_fake_kill_backfill_requeues_and_streams_match_oracle():
    def run(kills):
        gw = fake_gateway(n_slices=3, rdegree=0.0, spares=1, lanes=2)
        streams = [gw.submit([2 + i, 3], max_new=4 + i % 3, at_step=i // 3)
                   for i in range(8)]
        drive(gw, 60, kills=kills)
        return gw, streams

    ga, sa = run({})
    gb, sb = run({4: [1]})  # unmirrored role dies mid-decode
    assert all(s.done for s in sa) and all(s.done for s in sb)
    for x, y in zip(sa, sb):
        assert x.tokens == y.tokens, (y.rid, x.tokens, y.tokens)
    assert gb.stats.requeues >= 1
    assert gb.engine.report.requeued_requests == gb.stats.requeues
    assert gb.queue.requeued == gb.stats.requeues
    assert gb.registry.events, "capacity callback never fired"


def test_fake_kill_promote_is_invisible():
    def run(kills):
        gw = fake_gateway(n_slices=4, rdegree=1.0, spares=0, lanes=2)
        streams = [gw.submit([2 + i], max_new=5) for i in range(4)]
        drive(gw, 40, kills=kills)
        return gw, streams

    ga, sa = run({})
    gb, sb = run({3: [0]})  # cmp 0 dies; its replica promotes
    for x, y in zip(sa, sb):
        assert y.done and x.tokens == y.tokens
    assert gb.stats.requeues == 0 and gb.engine.report.promotes == 1


# ---------------------------------------------------------------------------
# the property suite (satellite: arbitrary kills x admissions)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**9))
def test_property_random_kills_never_lose_or_corrupt_requests(seed):
    """Arbitrary FailureSchedule-style kills interleaved with admissions:
    no request is ever lost or duplicated, the slot assignment stays
    bijective onto live roles, and every completed stream is bitwise
    equal to the failure-free oracle."""
    rng = np.random.default_rng(seed)
    n_slices = int(rng.integers(2, 6))
    rdegree = float(rng.choice([0.0, 0.5, 1.0]))
    spares = int(rng.integers(0, 3))
    lanes = int(rng.integers(1, 3))
    heal = bool(rng.integers(0, 2))
    n_req = int(rng.integers(4, 14))
    reqs = [
        (rng.integers(1, 40, size=int(rng.integers(1, 5))).tolist(),
         int(rng.integers(1, 7)), int(rng.integers(0, n_req // 2 + 1)))
        for _ in range(n_req)
    ]
    n_phys = n_slices + spares
    kills = {}
    for _ in range(int(rng.integers(0, 4))):
        kills.setdefault(int(rng.integers(1, 25)), []).append(
            int(rng.integers(0, n_phys))
        )

    def run(kill_plan, do_heal):
        gw = fake_gateway(n_slices=n_slices, rdegree=rdegree, spares=spares,
                          lanes=lanes, max_queue=2 * n_req + 4)
        streams = [gw.submit(p, max_new=m, at_step=a) for p, m, a in reqs]
        plan = {t: list(v) for t, v in kill_plan.items()}
        for t in range(400):
            for v in plan.pop(t, []):
                fake_kill(gw, [v], heal=do_heal)
            gw.run_step(t)
            gw.registry.check()  # bijection onto live roles, every step
            if not gw.pending() and not plan:
                break
        return gw, streams

    oracle_gw, oracle = run({}, do_heal=heal)
    gw, streams = run(kills, do_heal=heal)

    # nothing lost: every submitted request reached a terminal state
    assert len(gw.streams) == n_req
    assert all(s.done for s in oracle)
    assert all(s.done for s in streams), [
        (s.rid, s.cursor) for s in streams if not s.done
    ]
    # nothing duplicated or corrupted: bitwise equal to the oracle
    for x, y in zip(oracle, streams):
        assert y.tokens == x.tokens, (seed, y.rid, x.tokens, y.tokens)
        assert y.finish_reason == x.finish_reason
    # requeue bookkeeping agrees across queue / gateway / engine report
    assert gw.stats.requeues == gw.queue.requeued
    assert gw.engine.report.requeued_requests == gw.stats.requeues
    assert gw.stats.completed == n_req


# ---------------------------------------------------------------------------
# real-engine integration (subprocess, slow)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-2.7b"])
def test_gateway_flagship_bit_identical_streams_across_kill(arch):
    """The flagship: N streaming requests through the real engine, an
    unmirrored role killed mid-decode, heal backfills from a spare -
    every client stream is bit-identical to the failure-free run, TTFT
    across the kill stays bounded, and continuous batching needs no more
    serve steps than the fixed-batch baseline. mamba2 exercises the
    recurrent-state (SSM) slot-reset path where attention masking alone
    could not hide a previous occupant."""
    out = run_subprocess(
        f"""
        import numpy as np
        from repro.configs.registry import smoke_config
        from repro.serving.engine import ServeEngine
        from repro.serving.gateway import ServeGateway

        cfg = smoke_config({arch!r})

        def mk():
            eng = ServeEngine(cfg, n_slices=3, model_shards=1, rdegree=0.0,
                              spares=1, heal="eager", max_len=64,
                              slot_granular=True)
            return ServeGateway(eng, max_queue=64)

        def workload(gw):
            rng = np.random.default_rng(0)
            return [gw.submit(rng.integers(1, 50, size=2 + i % 3),
                              max_new=4 + i % 5, at_step=i // 4)
                    for i in range(12)]

        ga = mk(); sa = workload(ga); ga.serve(max_steps=10_000)
        gb = mk(); sb = workload(gb)
        gb.serve(max_steps=10_000, failures={{6: [1]}})

        assert all(s.done for s in sa) and all(s.done for s in sb)
        for x, y in zip(sa, sb):
            assert x.tokens == y.tokens, (y.rid, x.tokens, y.tokens)
        assert gb.stats.requeues >= 1, "kill missed every in-flight slot"
        assert gb.engine.report.requeued_requests == gb.stats.requeues
        p99 = gb.summary()["ttft_p99_steps"]
        assert 0 < p99 <= 40, f"TTFT blew up across the kill: {{p99}}"

        # fixed-batch baseline: full waves, turnover only when the LAST
        # sequence of a wave finishes
        gc = mk()
        rng = np.random.default_rng(0)
        reqs = [(rng.integers(1, 50, size=2 + i % 3), 4 + i % 5)
                for i in range(12)]
        B = gc.registry.n_slots
        for w in range(0, 12, B):
            for p, m in reqs[w : w + B]:
                gc.submit(p, max_new=m)
            gc.serve(max_steps=10_000)
        assert ga.stats.steps <= gc.stats.steps, (
            ga.stats.steps, gc.stats.steps)
        print("FLAGSHIP-OK", ga.stats.steps, gc.stats.steps,
              gb.stats.requeues)
        """,
        devices=4,
    )
    assert "FLAGSHIP-OK" in out


@pytest.mark.slow
def test_requeue_accounting_counts_only_live_slots():
    """Regression (the ServeEngine accounting fix): a killed role whose
    lane already FINISHED its request must not be charged as a requeue -
    only live (unfinished) slots re-enter the queue."""
    out = run_subprocess(
        """
        import numpy as np
        from repro.configs.registry import smoke_config
        from repro.serving.engine import ServeEngine
        from repro.serving.gateway import ServeGateway

        cfg = smoke_config("qwen2.5-3b")

        def run(failures=None):
            eng = ServeEngine(cfg, n_slices=3, model_shards=1, rdegree=0.0,
                              spares=0, heal="none", max_len=64,
                              slot_granular=True)
            gw = ServeGateway(eng, max_queue=16)
            # bind order is rid->slot: 0->(0,0) 1->(0,1) 2->(1,0) 3->(1,1)...
            maxn = [8, 8, 2, 12, 8, 8]
            streams = [gw.submit([5 + i, 3], max_new=maxn[i])
                       for i in range(6)]
            gw.serve(max_steps=200, failures=failures)
            return gw, streams

        ga, sa = run()
        # rid2 (slot (1,0), max_new=2) finishes after ~4 steps; kill
        # phys 1 at step 8: only rid3 (slot (1,1)) is still in flight
        gb, sb = run(failures={8: [1]})
        assert sb[2].done and sb[2].finished_step < 8
        r = gb.engine.report
        assert r.requeued_requests == 1, (
            f"charged finished slots too: {r.requeued_requests}")
        assert gb.stats.requeues == 1
        for x, y in zip(sa, sb):
            assert y.done and x.tokens == y.tokens
        print("ACCOUNTING-OK")
        """,
        devices=3,
    )
    assert "ACCOUNTING-OK" in out


@pytest.mark.slow
def test_serve_cli_gateway_bounds_rejected():
    """--gateway rejects zero/negative --max-queue / --max-batch-slots."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    for flags, msg in [
        (["--max-queue", "0"], "--max-queue"),
        (["--max-queue", "-2"], "--max-queue"),
        (["--max-batch-slots", "-1"], "--max-batch-slots"),
    ]:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--gateway",
             "--slices", "2", "--model-shards", "1"] + flags,
            capture_output=True, text=True, env=env, timeout=300,
        )
        assert proc.returncode != 0, flags
        assert msg in proc.stderr, (flags, proc.stderr[-500:])
