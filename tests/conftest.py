"""Shared test helpers.

NOTE: no XLA_FLAGS here - tests in the main process see 1 CPU device.
Multi-device integration tests launch subprocesses with
``--xla_force_host_platform_device_count`` via ``run_subprocess``.

When the real ``hypothesis`` package is absent (the offline container),
a minimal deterministic stand-in is registered so the property tests
still execute: ``@given`` draws ``max_examples`` samples from a
fixed-seed RNG instead of shrinking counterexamples.
"""
import os
import random
import subprocess
import sys
import textwrap
import types

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _install_hypothesis_stub() -> None:
    try:
        import hypothesis  # noqa: F401

        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    st = types.ModuleType("hypothesis.strategies")
    st.integers = lambda lo, hi: _Strategy(lambda r: r.randint(lo, hi))
    st.sampled_from = lambda xs: _Strategy(
        lambda r, xs=list(xs): xs[r.randrange(len(xs))]
    )
    st.booleans = lambda: _Strategy(lambda r: r.random() < 0.5)
    st.floats = lambda lo, hi, **kw: _Strategy(
        lambda r: lo + (hi - lo) * r.random()
    )
    st.lists = lambda elem, min_size=0, max_size=10: _Strategy(
        lambda r: [elem.draw(r) for _ in range(r.randint(min_size, max_size))]
    )

    def settings(max_examples=10, deadline=None, **kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            def run():
                rng = random.Random(0)
                for _ in range(getattr(run, "_stub_max_examples", 10)):
                    draws = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**draws)

            # keep the collected name/doc, but NOT the wrapped signature -
            # pytest would read the strategy kwargs as fixture requests
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run

        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_stub()


def run_subprocess(code: str, devices: int = 8, timeout: int = 560) -> str:
    """Run ``code`` in a fresh python with N fake CPU devices; returns stdout.
    Raises on nonzero exit (assertion failures inside the child propagate)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n--- stdout ---\n"
            f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout
