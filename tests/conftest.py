"""Shared test helpers.

NOTE: no XLA_FLAGS here - tests in the main process see 1 CPU device.
Multi-device integration tests launch subprocesses with
``--xla_force_host_platform_device_count`` via ``run_subprocess``.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, devices: int = 8, timeout: int = 560) -> str:
    """Run ``code`` in a fresh python with N fake CPU devices; returns stdout.
    Raises on nonzero exit (assertion failures inside the child propagate)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n--- stdout ---\n"
            f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout
