"""Per-architecture smoke tests (assigned deliverable f).

Each assigned arch instantiates a REDUCED same-family config and runs one
forward + one train step + one decode step on CPU, asserting output shapes
and absence of NaNs. Full configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, smoke_config
from repro.models import model as M
from repro.optim.adamw import adamw
from repro.optim.schedules import constant

B, S = 2, 64


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_prefix_embeds, cfg.d_model)
        )
    if cfg.enc_layers:
        batch["frames"] = jax.random.normal(key, (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", list(ARCHS))
def test_forward_and_train_step(name):
    cfg = smoke_config(name)
    key = jax.random.PRNGKey(0)
    params = M.init(key, cfg)
    batch = _batch(cfg, key)

    logits, aux = M.forward(params, batch, cfg, impl="chunked")
    S_out = S + (cfg.n_prefix_embeds if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.padded_vocab())
    assert not bool(jnp.any(jnp.isnan(logits)))

    # one full train step (loss + grad + adamw update)
    opt = adamw(constant(1e-3))
    state = opt.init(params)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, batch, cfg, impl="chunked"), has_aux=True
    )(params)
    assert np.isfinite(float(loss))
    new_params, state, stats = opt.update(grads, state, params)
    assert np.isfinite(float(stats["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(params))
    )
    assert moved


@pytest.mark.parametrize("name", list(ARCHS))
def test_decode_step(name):
    cfg = smoke_config(name)
    key = jax.random.PRNGKey(1)
    params = M.init(key, cfg)
    cache = M.init_cache(cfg, B, max_len=S, enc_len=16, dtype=jnp.float32)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, new_cache = M.decode_step(params, cache, tok, jnp.int32(0), cfg)
    assert logits.shape == (B, 1, cfg.padded_vocab())
    assert not bool(jnp.any(jnp.isnan(logits)))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize(
    "name",
    ["qwen2.5-3b", "gemma3-12b", "mixtral-8x7b", "mamba2-2.7b", "hymba-1.5b"],
)
def test_decode_matches_prefill(name):
    """Decoding token-by-token must reproduce the full-sequence forward
    logits (catches cache/rope/ring-buffer bugs). Run on a short prefix."""
    import dataclasses

    cfg = smoke_config(name)
    if cfg.moe is not None:
        # capacity-dropping MoE routes prefill tokens jointly; drops make
        # decode legitimately differ. Disable drops for the equivalence test.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0)
        )
    key = jax.random.PRNGKey(2)
    params = M.init(key, cfg)
    T = 16
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    full_logits, _ = M.forward(params, batch, cfg, impl="naive")

    cache = M.init_cache(cfg, B, max_len=32, dtype=jnp.float32)
    outs = []
    for t in range(T):
        lg, cache = M.decode_step(params, cache, tokens[:, t : t + 1], jnp.int32(t), cfg)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec_logits - full_logits)))
    assert err < 2e-2, f"decode/prefill mismatch: {err}"
