"""Distributed integration tests - run in subprocesses with fake devices
(main test process keeps 1 device per the dry-run isolation rule).

These exercise the paper's machinery end-to-end with REAL collectives:
- the three gradient-reduction modes agree on identical data;
- replica gradients are bit-identical to partners (SDC check == 0);
- promote-path recovery reproduces the failure-free trajectory bitwise;
- unreplicated failures restart from the checkpoint and finish;
- serving failover preserves the token stream exactly.
"""
import pytest

from conftest import run_subprocess


@pytest.mark.slow
def test_collective_modes_agree():
    out = run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import set_mesh
        from repro.configs.registry import smoke_config
        from repro.configs.base import ReplicationConfig, TrainConfig
        from repro.core.replication import WorldState
        from repro.core import data_plane as DP
        from repro.models import model as M
        from repro.optim.adamw import adamw
        from repro.optim.schedules import constant
        from repro.dist.sharding import param_shardings
        from repro.launch.mesh import make_mesh

        mesh = make_mesh(4, 2)
        cfg = smoke_config("qwen2.5-3b")
        world = WorldState.create(4, 1.0)
        opt = adamw(constant(1e-3))
        params0 = M.init(jax.random.PRNGKey(0), cfg)

        def make_batch(step, topo):
            r = np.random.default_rng(step)
            base = r.integers(0, cfg.vocab_size, (topo.n_comp, 2, 32)).astype(np.int32)
            full = np.stack([base[s] for s in topo.mirror_source()]).reshape(-1, 32)
            return {"tokens": jnp.asarray(full)}

        results = {}
        with set_mesh(mesh):
            pshard = param_shardings(params0, mesh, cfg)
            for mode in ["paper", "fused", "branch"]:
                repl = ReplicationConfig(rdegree=1.0, collective_mode=mode,
                                         sdc_check=True)
                step_fn = DP.build_train_step(cfg, TrainConfig(), repl, mesh,
                                              world, opt, donate=False)
                p = jax.device_put(params0, pshard); o = opt.init(p)
                for i in range(3):
                    p, o, m = step_fn(p, o, make_batch(i, world.topo))
                assert float(m["sdc"]) == 0.0, "replica gradients must mirror"
                results[mode] = p
        pa = jax.tree.leaves(results["paper"])
        fu = jax.tree.leaves(results["fused"])
        br = jax.tree.leaves(results["branch"])
        d_pf = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(pa, fu))
        d_pb = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(pa, br))
        assert d_pf == 0.0, f"paper vs fused: {d_pf}"
        assert d_pb < 1e-3, f"paper vs branch: {d_pb}"
        print("MODES-AGREE-OK")
        """
    )
    assert "MODES-AGREE-OK" in out


@pytest.mark.slow
def test_promote_recovery_bitwise_trajectory():
    out = run_subprocess(
        """
        import jax, numpy as np
        from repro.configs.registry import smoke_config
        from repro.core.simulator import SimCluster

        cfg = smoke_config("qwen2.5-3b")
        ref = SimCluster(cfg, n_slices=4, model_shards=2, rdegree=1.0, seq_len=32)
        ref.run(6)
        ft = SimCluster(cfg, n_slices=4, model_shards=2, rdegree=1.0, seq_len=32)
        rep = ft.run(6, failures={3: [0]})
        assert rep.promotes == 1 and rep.restarts == 0
        diff = max(
            float(np.max(np.abs(a.astype(np.float32) - b.astype(np.float32))))
            for a, b in zip(
                jax.tree.leaves(ref.params_replica()),
                jax.tree.leaves(ft.params_replica()),
            )
        )
        assert diff == 0.0, f"trajectory diverged: {diff}"
        assert ref.report.losses == rep.losses
        print("PROMOTE-BITWISE-OK")
        """
    )
    assert "PROMOTE-BITWISE-OK" in out


@pytest.mark.slow
def test_unreplicated_failure_restarts_from_checkpoint():
    out = run_subprocess(
        """
        import numpy as np, tempfile
        from repro.configs.registry import smoke_config
        from repro.core.simulator import SimCluster

        cfg = smoke_config("mamba2-2.7b")
        sim = SimCluster(cfg, n_slices=4, model_shards=2, rdegree=0.34,
                         seq_len=32, checkpoint_dir=tempfile.mkdtemp(),
                         checkpoint_every=2)
        rep = sim.run(8, failures={5: [2]})
        assert rep.restarts == 1 and rep.interruptions == [5]
        assert rep.steps_completed == 8
        assert np.isfinite(rep.losses[-1])
        assert sim.world.topo.n_comp == 2  # elastic shrink happened
        print("RESTART-OK")
        """
    )
    assert "RESTART-OK" in out


@pytest.mark.slow
def test_serving_failover_token_exact():
    out = run_subprocess(
        """
        import numpy as np
        from repro.configs.registry import smoke_config
        from repro.serving.engine import ServeEngine

        cfg = smoke_config("hymba-1.5b")
        a = ServeEngine(cfg, n_slices=4, model_shards=2, rdegree=1.0, max_len=64)
        ta = a.decode(16)
        b = ServeEngine(cfg, n_slices=4, model_shards=2, rdegree=1.0, max_len=64)
        tb = b.decode(16, failures={7: [1]})
        assert b.report.promotes == 1
        assert np.array_equal(ta, tb), "token stream must survive failover"
        print("SERVE-FAILOVER-OK")
        """
    )
    assert "SERVE-FAILOVER-OK" in out


@pytest.mark.slow
def test_multi_pod_axes_and_groups():
    """(pod, data) flattened slice space: groups + intercomm work across
    the pod boundary (the multi-pod dry-run's collective semantics)."""
    out = run_subprocess(
        """
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, set_mesh, shard_map
        mesh = make_mesh((2, 4, 1), ("pod", "data", "model"))
        cmp_groups = [list(range(6)), [6, 7]]
        pairs = [(0, 6), (1, 7)]
        def f(x):
            g = jax.lax.psum(x, ("pod", "data"), axis_index_groups=cmp_groups)
            gr = jax.lax.ppermute(g, ("pod", "data"), pairs)
            return g, gr
        sm = shard_map(f, mesh=mesh, in_specs=P(("pod", "data")),
                       out_specs=(P(("pod", "data")),) * 2,
                       axis_names={"pod", "data"}, check_vma=False)
        x = jnp.arange(8.0)
        with set_mesh(mesh):
            g, gr = jax.jit(sm)(x)
        assert float(g[0]) == 15.0 and float(g[6]) == 13.0
        assert float(gr[6]) == 15.0 and float(gr[7]) == 15.0
        print("MULTIPOD-GROUPS-OK")
        """,
        devices=8,
    )
    assert "MULTIPOD-GROUPS-OK" in out


@pytest.mark.slow
def test_elastic_shrink_preserves_model_function():
    out = run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import smoke_config
        from repro.core.simulator import SimCluster

        # no replication: ANY failure forces elastic shrink + restart path;
        # without checkpoints it restarts from init and still finishes
        cfg = smoke_config("mixtral-8x7b")
        sim = SimCluster(cfg, n_slices=4, model_shards=2, rdegree=0.0, seq_len=32)
        rep = sim.run(5, failures={2: [1]})
        assert rep.restarts == 1
        assert sim.world.n_live == 3
        assert sim.mesh.devices.shape == (3, 2)
        assert np.isfinite(rep.losses[-1])
        print("ELASTIC-OK")
        """
    )
    assert "ELASTIC-OK" in out
