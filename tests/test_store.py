"""The repro.store recovery-state plane.

Host-only units: K-way sharded partner memory (ReStore-style redundancy),
the RecoveryLadder walk, the live-clone level, bit-exact transfer
verification, and StepLog.trim bounding the applied set.

Subprocess integration (slow): a mirrored-pair double failure restoring
from sharded redundancy, a durable restore onto a SHRUNK world, and the
serving engine re-decoding from a KV-cache snapshot after an unmirrored
slice loss.
"""
import numpy as np
import pytest

from conftest import run_subprocess

from repro.core.recovery import StepLog, StepRecord
from repro.core.state_transfer import clone_pytree, verify_clone
from repro.store import (
    DurableStore,
    LiveCloneStore,
    PartnerMemoryStore,
    RecoveryLadder,
    flatten_with_paths,
)


def _state(v: float):
    return {
        "params": {"w": np.full((16, 16), v), "b": np.arange(4.0)},
        "opt": {"mu": np.full((8, 8), v / 2), "nu": np.full((8, 8), v / 4)},
    }


def _tmpl():
    return _state(0.0)


# ---------------------------------------------------------------------------
# PartnerMemoryStore: K-way sharded redundancy
# ---------------------------------------------------------------------------


def test_partner_roundtrip_and_steps():
    ps = PartnerMemoryStore(range(8), redundancy=2)
    ps.submit(3, _state(3.0), {"tag": "a"})
    ps.submit(5, _state(5.0), {"tag": "b"})
    assert ps.steps() == [3, 5] and ps.latest_step() == 5
    step, state, meta = ps.load(_tmpl())
    assert step == 5 and meta["tag"] == "b"
    assert float(state["params"]["w"][0, 0]) == 5.0
    step, state, _ = ps.load(_tmpl(), step=3)
    assert step == 3 and float(state["params"]["w"][0, 0]) == 3.0


def test_partner_survives_mirrored_pair_death():
    """The old single-partner store lost everything when a cmp slice and
    its partner died together; K-way sharding keeps every shard alive on
    another host (pair (0, 4) never co-holds a shard's only copies)."""
    ps = PartnerMemoryStore(range(8), redundancy=2)
    ps.submit(7, _state(7.0), {"n": 1})
    ps.on_failure([0, 4])  # the mirrored pair of cmp role 0 at rdegree=1.0
    assert ps.recoverable(7)
    step, state, meta = ps.load(_tmpl())
    assert step == 7 and meta["n"] == 1
    assert float(state["opt"]["mu"][0, 0]) == 3.5


def test_partner_shard_loss_returns_none():
    """Adjacent peers co-hold a shard at K=2: killing both loses it and
    load reports None (the ladder then falls to the durable level)."""
    ps = PartnerMemoryStore(range(8), redundancy=2)
    ps.submit(1, _state(1.0))
    ps.on_failure([2, 3])  # shard 2's two copies lived on peers 2 and 3
    assert not ps.recoverable(1)
    assert ps.load(_tmpl()) is None


def test_partner_higher_redundancy_survives_adjacent_deaths():
    ps = PartnerMemoryStore(range(8), redundancy=3)
    ps.submit(1, _state(1.0))
    ps.on_failure([2, 3])  # K=3 keeps a copy of every shard elsewhere
    assert ps.recoverable(1)


def test_partner_trim_drop_and_keep():
    ps = PartnerMemoryStore(range(4), redundancy=2, keep=2)
    for s in (1, 2, 3, 4):
        ps.submit(s, _state(float(s)))
    assert ps.steps() == [3, 4]  # keep-based GC on submit
    ps.drop(4)
    assert ps.steps() == [3]
    ps.trim(0)
    assert ps.steps() == [3]  # trim(0) keeps everything (0 = unbounded)


def test_partner_resubmit_after_shrink_purges_stale_shards():
    """Replay can resubmit a step after the peer ring shrank; the old
    placement's shards must be purged or the gather mixes stale data."""
    ps = PartnerMemoryStore(range(8), redundancy=2, keep=4)
    ps.submit(6, _state(1.0))
    ps.on_failure([0])
    ps.submit(6, _state(2.0))  # recrossed step 6 on the 7-peer ring
    step, state, _ = ps.load(_tmpl())
    assert step == 6
    assert float(state["params"]["w"][0, 0]) == 2.0
    assert float(state["opt"]["nu"][0, 0]) == 0.5  # no stale 1.0-era shard


def test_partner_newer_unrecoverable_falls_back_to_older():
    """A newer snapshot with a lost shard must not mask an older complete
    one."""
    ps = PartnerMemoryStore(range(4), redundancy=1, keep=4)
    ps.submit(1, _state(1.0))
    # peers shrink, then a newer snapshot lands only on survivors
    ps.on_failure([3])
    ps.submit(2, _state(2.0))
    ps.on_failure([0])  # K=1: some shard of BOTH steps may die with peer 0
    got = ps.load(_tmpl())
    if got is not None:  # whichever step kept full coverage must win
        assert float(got[1]["params"]["w"][0, 0]) == float(got[0])


def test_flatten_copies_numpy_leaves():
    """submit's capture-before-return contract: numpy leaves must be
    copied, not aliased, or in-place mutation corrupts old snapshots."""
    src = {"a": np.zeros(4)}
    blob = flatten_with_paths(src)
    src["a"][:] = 7.0
    assert blob["a"][0] == 0.0


def test_durable_same_step_resubmit_consistent(tmp_path):
    """Replay can recross a checkpoint step while the original write is
    still in flight; the resubmit must not tear the shared tmp dir."""
    ds = DurableStore(str(tmp_path))
    ds.submit(2, _state(1.0))
    ds.submit(2, _state(2.0))
    step, state, _ = ds.load(_state(0.0))
    assert step == 2 and float(state["params"]["w"][0, 0]) == 2.0
    assert ds.steps() == [2]


# ---------------------------------------------------------------------------
# LiveCloneStore (level 0)
# ---------------------------------------------------------------------------


def test_liveclone_roundtrip_keep_and_report():
    lc = LiveCloneStore(keep=2, bit_exact=True)
    for s in (1, 2, 3):
        lc.submit(s, _state(float(s)), {"s": s})
    assert lc.steps() == [2, 3]  # keep=2
    step, state, meta = lc.load(_tmpl())
    assert step == 3 and meta["s"] == 3
    assert float(state["params"]["w"][0, 0]) == 3.0
    rep = lc.report_for(3)
    assert rep.verified and rep.bit_exact and rep.total_bytes > 0


def test_liveclone_dies_with_its_host():
    lc = LiveCloneStore(host=2)
    lc.submit(1, _state(1.0))
    lc.on_failure([0])
    assert lc.steps() == [1]  # some other host died: clones intact
    lc.on_failure([2])
    assert lc.steps() == [] and lc.load(_tmpl()) is None


# ---------------------------------------------------------------------------
# RecoveryLadder
# ---------------------------------------------------------------------------


def test_ladder_orders_by_level_and_records_attempts(tmp_path):
    ds = DurableStore(str(tmp_path))
    ps = PartnerMemoryStore(range(4))
    lc = LiveCloneStore()
    ladder = RecoveryLadder([ds, ps, lc])  # construction order scrambled
    assert ladder.levels() == [0, 1, 2]
    ladder.submit(4, _state(4.0), {"m": 1})
    ladder.wait()

    # level 0 is cheapest and serves first
    got = ladder.restore(_tmpl())
    assert (got.level, got.step, got.meta["m"]) == (0, 4, 1)

    # level 0 gone -> level 1; walk records the failed rung
    lc.drop(4)
    got = ladder.restore(_tmpl())
    assert (got.level, got.store) == (1, "partner[k2]")
    assert [(a.level, a.ok) for a in got.attempts] == [(0, False), (1, True)]

    # levels 0+1 gone -> durable
    ladder.on_failure([0, 1])  # kills shard coverage at K=2 over 4 peers
    assert ps.load(_tmpl()) is None
    got = ladder.restore(_tmpl())
    assert (got.level, got.store) == (2, "durable")
    assert float(got.state["params"]["w"][0, 0]) == 4.0

    # everything empty -> None (the caller's fresh-init of last resort)
    for s in ds.steps():
        ds.drop(s)
    assert ladder.restore(_tmpl()) is None
    assert [a.ok for a in ladder.attempts] == [False, False, False]


def test_ladder_submit_level_filter(tmp_path):
    ds = DurableStore(str(tmp_path))
    ps = PartnerMemoryStore(range(4))
    ladder = RecoveryLadder([ps, ds])
    ladder.submit(1, _state(1.0), levels=[1])  # partner-only cadence
    ladder.wait()
    assert ps.steps() == [1] and ds.steps() == []


def test_ladder_shares_one_staging_pass(tmp_path):
    """Blob-consuming levels must receive the SAME staged blob - one
    device->host pass feeds partner memory and the durable writer."""
    seen = []

    class Spy(PartnerMemoryStore):
        def submit_blob(self, step, blob, meta=None):
            seen.append(blob)
            super().submit_blob(step, blob, meta)

    class Spy2(Spy):
        level = 3
        name = "partner-deep"

    ladder = RecoveryLadder([Spy(range(4)), Spy2(range(4))])
    ladder.submit(1, _state(1.0))
    assert len(seen) == 2 and seen[0] is seen[1]
    assert ladder.restore(_tmpl()).step == 1


def test_clone_pytree_preserves_nonstring_keys():
    state = {0: np.ones(3), "x": np.zeros(2)}
    clone, rep = clone_pytree(state)
    assert set(clone) == {0, "x"}
    assert np.array_equal(clone[0], state[0]) and rep.verified


def test_ladder_rejects_duplicate_levels():
    with pytest.raises(AssertionError):
        RecoveryLadder([PartnerMemoryStore(range(2)), PartnerMemoryStore(range(2))])


def test_ladder_torn_rung_does_not_mask_deeper_levels(tmp_path):
    class Torn(PartnerMemoryStore):
        def load(self, template, step=None):
            raise IOError("torn snapshot")

    torn = Torn(range(4))
    torn.submit(2, _state(2.0))
    ds = DurableStore(str(tmp_path))
    ds.submit_sync(1, _state(1.0))
    got = RecoveryLadder([torn, ds]).restore(_tmpl())
    assert got.level == 2 and got.step == 1
    assert "torn" in got.attempts[0].error


# ---------------------------------------------------------------------------
# transfer verification (satellite: bit-exact per-leaf check)
# ---------------------------------------------------------------------------


def test_checksum_blind_to_swap_bit_exact_catches_it():
    """The abs-sum checksum passes when two same-sized leaves are swapped
    (the corruption it is blind to); the per-leaf bit-exact check fails."""
    src = {"a": np.arange(16.0).reshape(4, 4), "b": np.arange(16.0)[::-1].reshape(4, 4)}
    swapped = {"a": src["b"].copy(), "b": src["a"].copy()}
    assert verify_clone(src, swapped, bit_exact=False)  # fooled
    assert not verify_clone(src, swapped, bit_exact=True)  # caught
    assert verify_clone(src, {k: v.copy() for k, v in src.items()}, bit_exact=True)


def test_clone_pytree_generic_phases_and_report():
    state = {"params": {"w": np.ones((8, 8))}, "cursor": {"c": np.arange(3)}}
    clone, rep = clone_pytree(state, bit_exact=True)
    assert set(rep.seconds_by_phase) == {"params", "cursor"}
    assert rep.verified and rep.verified_by_phase == {"params": True, "cursor": True}
    assert np.array_equal(clone["params"]["w"], state["params"]["w"])


# ---------------------------------------------------------------------------
# StepLog.trim (satellite: applied set must not grow unbounded)
# ---------------------------------------------------------------------------


def test_steplog_trim_bounds_applied_set():
    log = StepLog(0)
    for s in range(20):
        log.record(StepRecord(s, s * 10, s * 10 + 10, s))
    log.trim(14)
    assert min(r.step for r in log.records) == 15
    assert log.applied == set(range(15, 20))  # trimmed alongside records
    assert not log.has_applied(3) and log.has_applied(17)


# ---------------------------------------------------------------------------
# subprocess integration (slow)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_kway_partner_restore_survives_pair_double_failure():
    """Acceptance scenario: BOTH members of a mirrored pair die in the
    same step. Replication cannot mask it (the replica died too) and the
    old single-partner level would have lost its only copy - the K-way
    sharded store restores from the surviving slices' shards."""
    out = run_subprocess(
        """
        import numpy as np
        from repro.configs.registry import smoke_config
        from repro.core.simulator import SimCluster

        cfg = smoke_config("qwen2.5-3b")
        sim = SimCluster(cfg, n_slices=8, model_shards=1, rdegree=1.0,
                         seq_len=32, checkpoint_every=2)
        # physical 4 hosts the replica of cmp role 0: kill the whole pair
        rep = sim.run(6, failures={3: [0, 4]})
        assert rep.restarts == 1 and rep.promotes == 0
        assert rep.restored_from == ["L1:partner[k2]@step2"], rep.restored_from
        assert rep.steps_completed == 6
        assert np.isfinite(rep.losses[-1])
        assert sim.world.topo.n_comp == 3  # pair gone, world shrunk
        print("PAIR-DOUBLE-FAILURE-OK")
        """
    )
    assert "PAIR-DOUBLE-FAILURE-OK" in out


@pytest.mark.slow
def test_durable_restore_onto_shrunk_world():
    """A durable snapshot written by a 4-slice job restores into a 3-slice
    job (state is replicated over the data axis, so elastic re-placement
    is just a re-shard onto the smaller mesh)."""
    out = run_subprocess(
        """
        import numpy as np, tempfile
        from repro.configs.registry import smoke_config
        from repro.core.simulator import SimCluster

        ckdir = tempfile.mkdtemp()
        cfg = smoke_config("qwen2.5-3b")
        one = SimCluster(cfg, n_slices=4, model_shards=1, rdegree=0.0,
                         seq_len=32, checkpoint_dir=ckdir, checkpoint_every=2)
        one.run(5)
        one.ladder.wait()  # drain the double-buffered durable writers

        # the 'restart on a smaller allocation' path: fresh job, 3 slices
        two = SimCluster(cfg, n_slices=3, model_shards=1, rdegree=0.0,
                         seq_len=32, checkpoint_dir=ckdir)
        template, _ = two.snapshot()
        got = two.ladder.restore(template)
        assert got is not None and got.level == 2, got
        assert got.step == 4 and got.meta["step"] == 4
        two.restore(got.state, got.meta)
        two.session._regenerate()  # re-place restored state on the 3-mesh
        rep = two.run(7)
        assert np.isfinite(rep.losses[-1])
        print("SHRUNK-WORLD-RESTORE-OK")
        """
    )
    assert "SHRUNK-WORLD-RESTORE-OK" in out


@pytest.mark.slow
def test_serving_snapshot_restore_after_unmirrored_loss():
    """rdegree=0: no replica can mask the loss. With KV snapshots in the
    sharded partner store the engine rewinds to the last snapshot and
    re-decodes - surviving request streams are bit-identical to the
    failure-free run instead of losing decode state."""
    out = run_subprocess(
        """
        import numpy as np
        from repro.configs.registry import smoke_config
        from repro.serving.engine import ServeEngine

        cfg = smoke_config("qwen2.5-3b")
        a = ServeEngine(cfg, n_slices=4, model_shards=1, rdegree=0.0,
                        max_len=64)
        ta = a.decode(12)

        b = ServeEngine(cfg, n_slices=4, model_shards=1, rdegree=0.0,
                        max_len=64, snapshot_every=4)
        tb = b.decode(12, failures={9: [2]})
        r = b.report
        assert r.restarts == 1 and r.promotes == 0
        assert r.restored_from == ["L1:partner[k2]@step8"], r.restored_from
        assert r.requeued_requests == 2  # the dead slice's batch rows
        # streams 0,1,3 survive (stream 2 died with its slice); their
        # full token history must match the failure-free run bit-for-bit
        assert tb.shape[0] == 3 and ta.shape[0] == 4
        assert np.array_equal(tb, ta[[0, 1, 3]]), "decode state cold-started"
        print("SERVE-SNAPSHOT-RESTORE-OK")
        """
    )
    assert "SERVE-SNAPSHOT-RESTORE-OK" in out
