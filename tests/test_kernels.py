"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests
against the pure-jnp ref oracles (kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.checksum_ops import chunk_digests
from repro.kernels.checksum_ref import checksum_ref
from repro.kernels.flash_attention_ops import flash_attention
from repro.kernels.flash_attention_ref import flash_attention_ref
from repro.kernels.rmsnorm_ops import rmsnorm
from repro.kernels.rmsnorm_ref import rmsnorm_ref
from repro.kernels.ssd_scan_ops import ssd_scan
from repro.kernels.ssd_scan_ref import ssd_ref
from repro.models.ssd import ssd_chunked


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # B, S, H, KV, hd, causal, window, softcap, dtype
    (2, 256, 4, 2, 64, True, 0, 0.0, jnp.float32),
    (1, 128, 4, 4, 32, True, 0, 50.0, jnp.float32),
    (2, 256, 8, 2, 64, True, 64, 0.0, jnp.float32),
    (1, 256, 4, 2, 64, False, 0, 0.0, jnp.float32),
    (1, 200, 4, 2, 64, True, 0, 0.0, jnp.float32),  # non-multiple of block
    (1, 128, 2, 1, 128, True, 32, 0.0, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,KV,hd,causal,window,cap,dtype", ATTN_CASES)
def test_flash_attention_vs_ref(B, S, H, KV, hd, causal, window, cap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < tol, err


@settings(max_examples=10, deadline=None)
@given(
    s_blocks=st.integers(1, 4),
    heads=st.sampled_from([(4, 1), (4, 2), (4, 4), (8, 2)]),
    hd=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
)
def test_flash_attention_property(s_blocks, heads, hd, causal):
    H, KV = heads
    S = 64 * s_blocks
    ks = jax.random.split(jax.random.PRNGKey(S * H + hd), 3)
    q = jax.random.normal(ks[0], (1, S, H, hd))
    k = jax.random.normal(ks[1], (1, S, KV, hd))
    v = jax.random.normal(ks[2], (1, S, KV, hd))
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=causal)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5
    # softmax convexity: outputs lie within V's row-wise range
    vmin, vmax = float(jnp.min(v)), float(jnp.max(v))
    assert float(jnp.min(out)) >= vmin - 1e-4
    assert float(jnp.max(out)) <= vmax + 1e-4


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # B, S, nh, hd, ds, chunk
    (2, 128, 8, 32, 64, 32),
    (1, 96, 4, 16, 32, 32),
    (2, 64, 16, 64, 128, 16),
    (1, 100, 4, 16, 32, 32),  # padding path
]


def _ssd_inputs(key, B, S, nh, hd, ds):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, ds)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, ds)) * 0.5
    D = jnp.ones((nh,)) * 0.5
    return x, dt, A, Bm, Cm, D


@pytest.mark.parametrize("B,S,nh,hd,ds,chunk", SSD_CASES)
def test_ssd_kernel_vs_ref(B, S, nh, hd, ds, chunk):
    x, dt, A, Bm, Cm, D = _ssd_inputs(jax.random.PRNGKey(1), B, S, nh, hd, ds)
    y_ref, s_ref = ssd_ref(x, dt, A, Bm, Cm, D)
    y_pal, s_pal = ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, h_blk=4)
    assert float(jnp.max(jnp.abs(y_ref - y_pal))) < 2e-3
    assert float(jnp.max(jnp.abs(s_ref - s_pal))) < 2e-3


@settings(max_examples=8, deadline=None)
@given(
    chunks=st.integers(2, 4),
    nh=st.sampled_from([4, 8]),
    ds=st.sampled_from([16, 32]),
)
def test_ssd_chunked_matches_sequential(chunks, nh, ds):
    """Property: the chunked (parallel) SSD equals the sequential
    recurrence for any chunking - the state-space duality itself."""
    S = 32 * chunks
    x, dt, A, Bm, Cm, D = _ssd_inputs(jax.random.PRNGKey(S + nh), 1, S, nh, 16, ds)
    y_ref, s_ref = ssd_ref(x, dt, A, Bm, Cm, D)
    y_chk, s_chk = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=32)
    assert float(jnp.max(jnp.abs(y_ref - y_chk))) < 2e-3
    assert float(jnp.max(jnp.abs(s_ref - s_chk))) < 2e-3


def test_ssd_decay_monotonicity():
    """With very negative A (fast decay), early tokens must not influence
    late outputs: y depends only on the recent past."""
    B, S, nh, hd, ds = 1, 64, 2, 8, 8
    x, dt, A, Bm, Cm, D = _ssd_inputs(jax.random.PRNGKey(9), B, S, nh, hd, ds)
    A = jnp.full((nh,), -50.0)  # near-total decay per step
    y1, _ = ssd_ref(x, dt, A, Bm, Cm, D)
    x2 = x.at[:, 0].set(100.0)  # perturb the distant past
    y2, _ = ssd_ref(x2, dt, A, Bm, Cm, D)
    assert float(jnp.max(jnp.abs(y1[:, -1] - y2[:, -1]))) < 1e-3


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(4, 37, 256), (2, 128), (1, 5, 7, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_vs_ref(shape, dtype):
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, shape, dtype)
    s = jax.random.normal(key, (shape[-1],)) * 0.1
    out = rmsnorm(x, s)
    ref = rmsnorm_ref(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))) < tol


# ---------------------------------------------------------------------------
# per-chunk checksum digest (the repro.xfer verification kernel)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,chunk_elems", [
    (1, 128),          # single padded chunk
    (128, 128),        # exact fit
    (1000, 128),       # ragged tail chunk
    (4096, 256),       # many chunks, multiple kernel grid steps
    (77, 512),         # chunk larger than the stream
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_checksum_kernel_vs_ref(n, chunk_elems, dtype):
    key = jax.random.PRNGKey(n + chunk_elems)
    x = (jax.random.normal(key, (n,)) * 10).astype(dtype)
    out = chunk_digests(x, chunk_elems=chunk_elems)
    xf = x.astype(jnp.float32)
    pad = (-n) % chunk_elems
    ref = checksum_ref(jnp.pad(xf, (0, pad)).reshape(-1, chunk_elems))
    assert out.shape == (-(-n // chunk_elems), 2)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3


@settings(max_examples=10, deadline=None)
@given(chunks=st.integers(1, 6), ce=st.sampled_from([128, 256]))
def test_checksum_digest_properties(chunks, ce):
    """Property: column 0 is the per-chunk abs-sum (>= |column 1|), and a
    single-element perturbation moves exactly one digest row."""
    n = chunks * ce
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    d = chunk_digests(x, chunk_elems=ce)
    assert bool(jnp.all(d[:, 0] >= jnp.abs(d[:, 1]) - 1e-4))
    hit = (chunks - 1) * ce  # first element of the last chunk
    d2 = chunk_digests(x.at[hit].add(1.0), chunk_elems=ce)
    changed = jnp.any(jnp.abs(d - d2) > 1e-5, axis=1)
    assert int(changed.sum()) == 1 and bool(changed[chunks - 1])


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 300), d=st.sampled_from([128, 256]))
def test_rmsnorm_scale_invariance(rows, d):
    """Property: rmsnorm(c*x) == rmsnorm(x) for c > 0."""
    key = jax.random.PRNGKey(rows * d)
    x = jax.random.normal(key, (rows, d))
    s = jnp.zeros((d,))
    a = rmsnorm(x, s)
    b = rmsnorm(3.7 * x, s)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-4
