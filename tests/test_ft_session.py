"""Unit suite for the repro.ft session API.

Host-side behaviours (FailureSchedule, report adapters) run in-process;
session lifecycle tests (generation bump on revoke, promote vs lost-cmp
restore paths, multi-level restore ordering, replay bookkeeping) run a
device-free fake program in a subprocess with fake devices (FTSession
builds a real mesh even when the program never jits anything).

The companion parity test - the refactored SimCluster reproducing the
failure-free loss trajectory bit-for-bit through a promote-path recovery -
is ``test_distributed.py::test_promote_recovery_bitwise_trajectory``.
"""
import pytest

from conftest import run_subprocess

from repro.ft import FailureSchedule, FTReport
from repro.core.simulator import SimReport
from repro.serving.engine import ServeReport


# ---------------------------------------------------------------------------
# FailureSchedule (host-only)
# ---------------------------------------------------------------------------


def test_failure_schedule_never_mutates_caller():
    src = {3: [0, 1], 5: [2]}
    sched = FailureSchedule(src)
    assert sched.take(3) == [0, 1]
    assert sched.take(3) == []  # consumed
    assert src == {3: [0, 1], 5: [2]}  # caller's dict untouched
    assert sched.pending() == 1
    # a schedule can seed another schedule (copy, not view)
    sched2 = FailureSchedule(sched)
    assert sched2.take(5) == [2]
    assert sched.take(5) == [2]


def test_failure_schedule_parse():
    sched = FailureSchedule.parse("5:0,5:1,9:3")
    assert sched.take(5) == [0, 1]
    assert sched.take(9) == [3]
    assert not FailureSchedule.parse("")


def test_failure_schedule_parse_empty_and_blank_items():
    """Empty specs and blank items (trailing / doubled commas, pure
    whitespace between commas) are no failures, not errors."""
    assert not FailureSchedule.parse("")
    assert not FailureSchedule.parse("   ")
    sched = FailureSchedule.parse("5:0,,9:1,")
    assert sched.take(5) == [0] and sched.take(9) == [1]


def test_failure_schedule_parse_whitespace_tolerant():
    sched = FailureSchedule.parse(" 5:0 , 9 : 1 ")
    assert sched.take(5) == [0]
    assert sched.take(9) == [1]


def test_failure_schedule_duplicate_steps_merge_and_victims_dedupe():
    """Duplicate step entries merge into one kill list; a victim repeated
    within a step is ONE failure (repeats used to double-count in
    FTReport.failures)."""
    sched = FailureSchedule.parse("3:0,3:1,3:0")
    assert sched.take(3) == [0, 1]
    # same dedupe through the dict constructor
    sched2 = FailureSchedule({4: [2, 2, 2, 5]})
    assert sched2.take(4) == [2, 5]
    assert sched2.pending() == 0


def test_failure_schedule_parse_rejects_malformed_items():
    for bad in ("5", "5:", ":1", "a:1", "5:b", "5:0:1"):
        with pytest.raises(ValueError, match="bad failure injection"):
            FailureSchedule.parse(bad)


# ---------------------------------------------------------------------------
# unified report adapters (host-only)
# ---------------------------------------------------------------------------


def test_reports_extend_ftreport():
    sim, serve = SimReport(), ServeReport()
    assert isinstance(sim, FTReport) and isinstance(serve, FTReport)
    assert sim.losses == []
    serve.app_seconds, serve.handler_seconds = 1.5, 0.25
    assert serve.decode_seconds == 1.5  # serving names alias the unified split
    assert serve.failover_seconds == 0.25


# ---------------------------------------------------------------------------
# session lifecycle (fake program, subprocess for the device pool)
# ---------------------------------------------------------------------------

_FAKE = """
        import numpy as np
        from repro.ft import FailureSchedule, FTSession, ResilientProgram
        from repro.store import PartnerMemoryStore, RecoveryLadder

        class Fake(ResilientProgram):
            def __init__(self):
                self.value = np.zeros(2)
                self.builds = 0
                self.calls = []
                self.restored_meta = None
                self.fresh_inits = 0
            def build_step(self, mesh, world):
                self.builds += 1
            def run_step(self, step):
                self.calls.append(step)
                self.value = self.value + 1
            def sample_range(self, step, cmp_role):
                return (step * 10, step * 10 + 10)
            def snapshot(self):
                return {"v": self.value}, {"tag": "fake"}
            def restore(self, state, meta):
                self.value = state["v"]
                self.restored_meta = dict(meta)
            def init_fresh(self):
                self.value = np.zeros(2)
                self.fresh_inits += 1
"""


@pytest.mark.slow
def test_session_generation_bump_and_promote_path():
    out = run_subprocess(
        _FAKE
        + """
        prog = Fake()
        s = FTSession(prog, n_slices=4, rdegree=1.0, replay="log")
        assert s.generation == 0 and prog.builds == 1
        rep = s.run(5, {2: [0]})
        # revoke bumped the generation; shrink cleared the revocation
        assert s.generation == 1, s.generation
        s.control.check(s.generation)  # dispatches again at the new gen
        assert rep.failures == 1 and rep.promotes == 1 and rep.restarts == 0
        assert s.world.topo.n_comp == 2 and s.world.n_live == 3
        assert prog.builds == 2  # re-lowered once after repair
        # promote path: every survivor completed step 1, so the in-flight
        # step 2 is dispatched exactly once after recovery - no duplicates
        assert prog.calls == [0, 1, 2, 3, 4], prog.calls
        assert rep.replayed_steps == 0 and rep.steps_completed == 5
        # duplicate suppression bookkeeping: pre-recovery steps were marked
        # applied in the re-keyed logs, replayed steps recorded on top
        assert all(
            log.has_applied(i) for log in s.logs.values() for i in range(5)
        )
        assert "promote" in rep.events[0]
        print("PROMOTE-PATH-OK")
        """
    )
    assert "PROMOTE-PATH-OK" in out


@pytest.mark.slow
def test_session_lost_cmp_restores_from_partner_then_replays():
    out = run_subprocess(
        _FAKE
        + """
        prog = Fake()
        s = FTSession(prog, n_slices=4, rdegree=0.0,
                      stores=[PartnerMemoryStore(range(4), redundancy=2)],
                      checkpoint_every=3, replay="log")
        rep = s.run(6, {5: [1]})
        # unreplicated loss at step 5 -> restore from the step-3 partner
        # snapshot (K-way sharded: peer 1's shards die with it, the
        # redundant copies serve the load), replay step 4, then run 5
        assert rep.restarts == 1 and rep.interruptions == [5]
        assert prog.restored_meta == {"step": 3, "tag": "fake"}
        assert prog.fresh_inits == 0
        assert prog.calls == [0, 1, 2, 3, 4, 4, 5], prog.calls
        assert rep.replayed_steps == 1
        assert rep.restored_from == ["L1:partner[k2]@step3"], rep.restored_from
        assert s.world.topo.n_comp == 3  # elastic shrink
        print("PARTNER-RESTORE-OK")
        """
    )
    assert "PARTNER-RESTORE-OK" in out


@pytest.mark.slow
def test_session_lost_cmp_fresh_init_when_no_checkpoint():
    out = run_subprocess(
        _FAKE
        + """
        prog = Fake()
        s = FTSession(prog, n_slices=4, rdegree=0.0, replay="log")
        rep = s.run(4, {2: [3]})
        # nothing to restore from: restart from scratch and replay 0..1
        assert prog.fresh_inits == 1 and prog.restored_meta is None
        assert prog.calls == [0, 1, 0, 1, 2, 3], prog.calls
        assert rep.replayed_steps == 2 and rep.restarts == 1
        print("FRESH-INIT-OK")
        """
    )
    assert "FRESH-INIT-OK" in out


@pytest.mark.slow
def test_session_resume_in_place_policy():
    out = run_subprocess(
        _FAKE
        + """
        repacks = []
        class Server(Fake):
            def repack_state(self, old_world, new_world):
                repacks.append((old_world.topo.n_comp, new_world.topo.n_comp))
        prog = Server()
        s = FTSession(prog, n_slices=4, rdegree=1.0, replay="none")
        rep = s.run(4, {1: [0]})
        # replay='none': the interrupted unit reruns in place, nothing else
        assert prog.calls == [0, 1, 2, 3], prog.calls
        assert repacks == [(2, 2)]  # promote kept the role count
        assert rep.replayed_steps == 0 and rep.promotes == 1
        assert "resume in place" in rep.events[0]
        print("RESUME-IN-PLACE-OK")
        """
    )
    assert "RESUME-IN-PLACE-OK" in out
