"""Layer-level unit + property tests: attention impl agreement, RoPE,
M-RoPE, MoE dispatch, cross-entropy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.configs.registry import smoke_config
from repro.models import layers as L


def _qkv(key, B, S, H, KV, hd):
    ks = jax.random.split(key, 3)
    return (
        jax.random.normal(ks[0], (B, S, H, hd)),
        jax.random.normal(ks[1], (B, S, KV, hd)),
        jax.random.normal(ks[2], (B, S, KV, hd)),
    )


def test_chunked_matches_naive_causal():
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 128, 4, 2, 32)
    a = L.attn_naive(q, k, v, causal=True)
    b = L.attn_chunked(q, k, v, causal=True, block_q=32, block_k=32)
    assert float(jnp.max(jnp.abs(a - b))) < 2e-5


def test_banded_matches_naive_sliding():
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 256, 4, 2, 32)
    a = L.attn_naive(q, k, v, causal=True, window=64)
    b = L.attn_banded(q, k, v, window=64, block_q=64)
    assert float(jnp.max(jnp.abs(a - b))) < 2e-5


def test_decode_matches_naive_last_row():
    B, S, H, KV, hd = 2, 64, 4, 2, 32
    q, k, v = _qkv(jax.random.PRNGKey(2), B, S, H, KV, hd)
    full = L.attn_naive(q, k, v, causal=True)
    out = L.attn_decode(q[:, -1:], k, v, jnp.int32(S - 1), block_k=16)
    assert float(jnp.max(jnp.abs(out[:, 0] - full[:, -1]))) < 2e-5


@settings(max_examples=8, deadline=None)
@given(window=st.sampled_from([16, 32, 64]), s_mult=st.integers(2, 4))
def test_window_equals_full_when_wide(window, s_mult):
    """Property: a window >= S is exactly full causal attention."""
    S = 16 * s_mult
    q, k, v = _qkv(jax.random.PRNGKey(window + S), 1, S, 2, 2, 16)
    a = L.attn_naive(q, k, v, causal=True, window=0)
    b = L.attn_naive(q, k, v, causal=True, window=max(window, S))
    if max(window, S) >= S:
        assert float(jnp.max(jnp.abs(a - b))) < 1e-6


def test_rope_relative_shift_invariance():
    """RoPE scores depend only on relative positions: shifting all positions
    by a constant leaves q.k products unchanged."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (1, 8, 2, 32))
    p0 = jnp.arange(8)
    q0 = L.apply_rope(x, p0, 10000.0)
    k0 = L.apply_rope(x, p0, 10000.0)
    s0 = jnp.einsum("bqhd,bkhd->bhqk", q0, k0)
    q1 = L.apply_rope(x, p0 + 100, 10000.0)
    k1 = L.apply_rope(x, p0 + 100, 10000.0)
    s1 = jnp.einsum("bqhd,bkhd->bhqk", q1, k1)
    assert float(jnp.max(jnp.abs(s0 - s1))) < 1e-3


def test_mrope_positions_layout():
    pos = L.mrope_positions(2, 20, 16)  # 4x4 grid prefix + 4 text
    t, h, w = np.asarray(pos)[:, 0, :], np.asarray(pos)[1, 0, :], np.asarray(pos)[2, 0, :]
    pos = np.asarray(pos)
    assert (pos[0, 0, :16] == 0).all()  # temporal frozen over the image
    assert pos[2, 0, 1] == 1  # width walks the grid
    assert (np.diff(pos[0, 0, 16:]) == 1).all()  # text advances t


def test_softmax_xent_matches_manual():
    key = jax.random.PRNGKey(4)
    logits = jax.random.normal(key, (3, 5, 17))
    labels = jax.random.randint(key, (3, 5), 0, 17)
    ours = L.softmax_xent(logits, labels)
    ref = -jnp.mean(
        jnp.take_along_axis(jax.nn.log_softmax(logits), labels[..., None], axis=-1)
    )
    assert float(jnp.abs(ours - ref)) < 1e-5


def test_moe_forward_routes_and_balances():
    cfg = smoke_config("mixtral-8x7b")
    key = jax.random.PRNGKey(5)
    p = L.moe_params_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    out, aux = L.moe_forward(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux) > 0  # load-balance loss active
    # zero input -> zero expert output (router softmax still fires but
    # experts see zeros and swiglu(0)=0)
    out0, _ = L.moe_forward(p, jnp.zeros_like(x), cfg)
    assert float(jnp.max(jnp.abs(out0))) < 1e-5


def test_moe_capacity_drops_overflow():
    cfg = smoke_config("phi3.5-moe-42b-a6.6b")
    # capacity factor so tiny that most tokens drop -> output much smaller
    import dataclasses
    tight = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.01)
    )
    key = jax.random.PRNGKey(6)
    p = L.moe_params_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 64, cfg.d_model))
    full, _ = L.moe_forward(p, x, cfg)
    dropped, _ = L.moe_forward(p, x, tight)
    assert float(jnp.mean(jnp.abs(dropped))) < float(jnp.mean(jnp.abs(full)))


def test_gqa_repeat_kv():
    k = jnp.arange(2 * 4 * 2 * 3, dtype=jnp.float32).reshape(2, 4, 2, 3)
    r = L.repeat_kv(k, 2)
    assert r.shape == (2, 4, 4, 3)
    assert jnp.array_equal(r[:, :, 0], r[:, :, 1])
