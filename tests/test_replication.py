"""Property tests for the replica topology + world repair (the paper's
communicator algebra). These invariants are what keep the
axis_index_groups handed to XLA well-formed through arbitrary failure
sequences."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.replication import ReplicaTopology, WorldState, split_comp_rep

PAPER_RDEGREES = [0.0, 0.0625, 0.125, 0.25, 0.5, 1.0]


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32, 512])
@pytest.mark.parametrize("r", PAPER_RDEGREES)
def test_topology_wellformed(n, r):
    topo = ReplicaTopology.create(n, r)
    topo.validate()
    assert topo.n_slices == n
    if r == 0:
        assert topo.n_rep == 0
    if r == 1.0 and n % 2 == 0:
        assert topo.n_comp == topo.n_rep == n // 2


@pytest.mark.parametrize("n,r", [(16, 0.25), (16, 1.0), (8, 0.5)])
def test_six_communicators(n, r):
    topo = ReplicaTopology.create(n, r)
    # COMM_CMP + inert group partitions the axis
    flat = sorted(i for g in topo.comm_cmp_groups() for i in g)
    assert flat == list(range(n))
    # intercomm pairs bridge cmp -> its replica
    for c, rr in topo.intercomm_perm():
        assert topo.replica_of(rr) == c
        assert topo.partner_of(c) == rr
    # CMP_NO_REP = computational ranks without replicas
    no_rep = topo.cmp_no_rep()
    assert all(topo.partner_of(c) is None for c in no_rep)
    # mirror source maps replicas onto their partner's shard
    src = topo.mirror_source()
    assert src[: topo.n_comp] == list(range(topo.n_comp))
    for j, c in enumerate(topo.replica_map):
        assert src[topo.n_comp + j] == c


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(2, 24),
    r=st.sampled_from(PAPER_RDEGREES),
    kills=st.lists(st.integers(0, 23), min_size=1, max_size=6),
)
def test_repair_invariants(n, r, kills):
    """After ANY failure sequence: groups still partition the live world,
    replica maps stay injective and in-range, dead slices never appear."""
    world = WorldState.create(n, r)
    for k in kills:
        victim = k % world.n_physical
        world, report = world.repair([victim])
        topo = world.topo
        if topo.n_comp == 0:
            return  # whole computational capacity lost - nothing to check
        topo.validate()
        # assignment references only live physicals
        assert all(p not in world.dead for p in world.assignment)
        assert len(set(world.assignment)) == len(world.assignment)
        # mesh-space groups partition the shrunk mesh
        groups = world.physical_groups(topo.comm_cmp_groups())
        flat = sorted(i for g in groups for i in g)
        assert flat == list(range(world.n_live))
        # generation strictly increases
        assert world.generation >= 1


def test_promote_moves_replica_into_role():
    world = WorldState.create(4, 1.0)  # cmp {0,1}, reps {2:0, 3:1}
    new, rep = world.repair([0])
    assert rep["promoted"] == [(0, 2)]
    assert new.topo.n_comp == 2
    assert new.assignment[0] == 2  # replica's physical now plays cmp role 0
    assert new.topo.replica_map == (1,)  # only cmp 1 keeps a replica


def test_double_failure_of_pair_is_interruption():
    world = WorldState.create(4, 1.0)
    world, rep1 = world.repair([0])  # promote 2 into role 0
    world, rep2 = world.repair([2])  # the promoted slice dies too
    assert rep2["lost_cmp"] == [0]
    assert world.topo.n_comp == 1  # shrunk


def test_replica_failure_is_dropped_silently():
    world = WorldState.create(4, 0.5)  # nComp=3? -> check
    topo = world.topo
    rep_phys = world.assignment[topo.n_comp]
    world, rep = world.repair([rep_phys])
    assert rep["dropped_reps"] and not rep["lost_cmp"] and not rep["promoted"]


@pytest.mark.parametrize("n,r", [(16, 0.25), (12, 0.5)])
def test_paper_rdegree_split(n, r):
    n_comp, n_rep = split_comp_rep(n, r)
    assert n_comp + n_rep == n
    assert abs(n_rep / n_comp - r) < 0.25  # integer rounding tolerance


@pytest.mark.parametrize("n", [1, 2, 3, 7, 16])
@pytest.mark.parametrize("r", [1.5, 2.0, 10.0, 1e9])
def test_split_comp_rep_rdegree_above_one_caps_at_dual(n, r):
    """rdegree > 1 cannot be realized (at most one replica per cmp role):
    the split caps at dual redundancy and still covers the whole pool."""
    n_comp, n_rep = split_comp_rep(n, r)
    assert n_comp + n_rep == n
    assert 0 <= n_rep <= n_comp  # never more replicas than cmp roles
    topo = ReplicaTopology.create(n, r)
    topo.validate()
    assert topo.n_slices == n


@pytest.mark.parametrize("r", [0.0, 0.5, 1.0, 3.0])
def test_split_comp_rep_single_slice(r):
    """n_slices=1 always yields one unreplicated computational slice (a
    replica would leave zero compute)."""
    assert split_comp_rep(1, r) == (1, 0)
    topo = ReplicaTopology.create(1, r)
    topo.validate()
    assert topo.n_comp == 1 and topo.n_rep == 0
    assert topo.comm_cmp_groups() == [[0]]


def test_split_comp_rep_negative_and_zero_rdegree():
    assert split_comp_rep(8, 0.0) == (8, 0)
    assert split_comp_rep(8, -1.0) == (8, 0)  # clamped, not an error
