"""The repro.heal plane - elastic re-replication after failures.

Host-only units: spare-pool topology algebra (spare-aware repair, the
``heal`` transition, most-exposed-first ordering, target capping, spare
backfill), the HealPolicy grammar, Healer execution (3-phase clone +
partner pair re-registration + shard re-placement), and the
property-based invariant suite over arbitrary failure/heal sequences.

Subprocess integration (slow): the fault-scenario matrix - a grid of
(rdegree, heal policy, failure schedule incl. back-to-back and
mirrored-pair kills, store stack) cells each asserting the final state is
bit-identical to the failure-free run - plus the flagship post-heal
mirrored-pair kill and the serving engine warming a healed replica's KV
cache from its partner.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import run_subprocess

from repro.core.replication import ReplicaTopology, WorldState
from repro.heal import HealPolicy, Healer
from repro.store import PartnerMemoryStore


# ---------------------------------------------------------------------------
# HealPolicy grammar
# ---------------------------------------------------------------------------


def test_policy_parse_grammar():
    assert HealPolicy.parse("none") == HealPolicy("none")
    assert HealPolicy.parse("eager").enabled
    assert not HealPolicy.parse("none").enabled
    assert HealPolicy.parse("deferred:3") == HealPolicy("deferred", 3)
    assert HealPolicy.parse("deferred(2)") == HealPolicy("deferred", 2)
    assert HealPolicy.parse(" Eager ") == HealPolicy("eager")
    assert HealPolicy.parse(HealPolicy("eager")) == HealPolicy("eager")
    assert HealPolicy.parse("") == HealPolicy("none")  # default
    with pytest.raises(ValueError):
        HealPolicy.parse("sometimes")
    with pytest.raises(ValueError):
        HealPolicy.parse("deferred:x")


def test_policy_wants_heal():
    assert not HealPolicy("none").wants_heal(5)
    assert HealPolicy("eager").wants_heal(1)
    assert not HealPolicy("eager").wants_heal(0)
    d2 = HealPolicy("deferred", 2)
    assert not d2.wants_heal(1) and d2.wants_heal(2) and d2.wants_heal(3)


# ---------------------------------------------------------------------------
# spare-pool topology algebra
# ---------------------------------------------------------------------------


def test_create_with_spares():
    w = WorldState.create(6, 1.0, n_spares=2)
    assert w.topo.n_comp == 2 and w.topo.n_rep == 2
    assert w.spares == (4, 5) and w.target_n_rep == 2
    assert w.replica_deficit() == 0
    w.validate()
    # spares sit OUTSIDE the shrunk mesh until healed
    assert w.live_physicals() == [0, 1, 2, 3]


def test_replica_death_exposes_then_eager_heal_restores():
    w = WorldState.create(6, 1.0, n_spares=2)
    w1, rep = w.repair([3])  # replica of cmp role 1
    assert rep["dropped_reps"] == [1] and not rep["promoted"]
    assert w1.replica_deficit() == 1 and w1.exposed == ((1, 1),)
    healed, plan = w1.heal()
    healed.validate()
    assert [(a.cmp_role, a.spare) for a in plan.actions] == [(1, 4)]
    assert plan.actions[0].exposed_since == 1
    assert healed.topo.replica_map == (0, 1) and healed.replica_deficit() == 0
    assert healed.spares == (5,) and healed.exposed == ()
    # heal does NOT bump the generation (it runs inside the repair window)
    assert healed.generation == w1.generation
    # the healed groups still partition the live mesh
    flat = sorted(
        i for g in healed.physical_groups(healed.topo.comm_cmp_groups()) for i in g
    )
    assert flat == list(range(healed.n_live))


def test_promote_consumes_mirror_then_heal_re_mirrors():
    w = WorldState.create(6, 1.0, n_spares=2)
    w1, rep = w.repair([0])  # cmp role 0 dies, replica promoted
    assert rep["promoted"] == [(0, 2)]
    assert w1.unmirrored_cmp_roles() == [0]
    healed, plan = w1.heal()
    assert [(a.cmp_role, a.spare) for a in plan.actions] == [(0, 4)]
    assert healed.topo.replica_map == (0, 1)
    assert healed.assignment[healed.topo.partner_of(0)] == 4
    healed.validate()


def test_heal_most_exposed_first_and_stable():
    """Roles that lost mirrors earliest heal first; ties break by role id;
    the order is stable across repeated failures with no spare available."""
    w = WorldState.create(10, 1.0, n_spares=2)  # 4 cmp, 4 rep, 2 spares
    w1, _ = w.repair([w.assignment[w.topo.n_comp + 2]])  # rep of cmp 2 @g1
    w2, _ = w1.repair([w1.assignment[w1.topo.n_comp]])  # rep of cmp 0 @g2
    assert w2.unmirrored_cmp_roles() == [2, 0]  # exposure age, not role id
    healed, plan = w2.heal(max_new=1)
    assert plan.actions[0].cmp_role == 2  # most-exposed wins the only slot
    assert healed.unmirrored_cmp_roles() == [0]
    # stability: a LATER failure queues behind the older exposure
    w3, _ = healed.repair([healed.assignment[healed.topo.n_comp + 1]])
    assert w3.unmirrored_cmp_roles() == [0, 2]  # role 2 re-exposed @g3


def test_heal_tie_breaks_by_role_id():
    w = WorldState.create(10, 1.0, n_spares=1)  # 5 cmp, 4 rep: role 4 bare
    # both replicas of cmp 1 and cmp 3 die in the SAME repair (same gen)
    reps = {w.topo.replica_map[j]: w.assignment[w.topo.n_comp + j]
            for j in range(w.topo.n_rep)}
    w1, _ = w.repair([reps[3], reps[1]])
    # same gen -> role id order; the never-mirrored-by-design role trails
    assert w1.unmirrored_cmp_roles() == [1, 3, 4]
    healed, plan = w1.heal()
    assert [a.cmp_role for a in plan.actions] == [1]  # one spare only


def test_heal_caps_at_target_rdegree():
    """A 0.5-split world never heals past its achieved split ratio, even
    with spares to burn; never-mirrored-by-design roles are not eroded."""
    w = WorldState.create(8, 0.5, n_spares=2)  # 4 cmp, 2 rep (.5 achieved)
    assert w.target_n_rep == 2
    same, plan = w.heal()
    assert not plan and same is w  # deficit 0: spares stay spares
    # lose a replica -> deficit 1 -> exactly ONE spare converts
    w1, _ = w.repair([w.assignment[w.topo.n_comp]])
    healed, plan = w1.heal()
    assert len(plan.actions) == 1 and healed.topo.n_rep == 2
    assert healed.replica_deficit() == 0 and len(healed.spares) == 1
    healed.validate()


def test_backfill_preserves_role_ids_and_width():
    """A lost computational role backfills from a spare: role ids and the
    computational width survive, so a restore + replay reproduces the
    failure-free trajectory (no elastic shrink)."""
    w = WorldState.create(5, 0.0, n_spares=2)  # 3 cmp, spares {3, 4}
    w1, rep = w.repair([1])
    assert rep["backfilled"] == [(1, 3)] and not rep["lost_cmp"]
    assert rep["role_map"] == {0: 0, 1: 1, 2: 2}  # identity: no renumbering
    assert w1.topo.n_comp == 3 and w1.assignment == (0, 3, 2)
    assert w1.spares == (4,)
    w1.validate()


def test_backfill_disabled_without_spares_or_flag():
    w = WorldState.create(5, 0.0, n_spares=2)
    w1, rep = w.repair([1], use_spares=False)
    assert rep["lost_cmp"] == [1] and not rep["backfilled"]
    assert w1.topo.n_comp == 2 and w1.spares == (3, 4)
    no_spares = WorldState.create(3, 0.0)
    w2, rep2 = no_spares.repair([1])
    assert rep2["lost_cmp"] == [1] and rep2["role_map"] == {0: 0, 1: 2}


def test_dead_spare_is_removed_from_pool():
    w = WorldState.create(6, 1.0, n_spares=2)
    w1, rep = w.repair([5])
    assert rep["dead_spares"] == [5] and w1.spares == (4,)
    assert w1.topo == w.topo  # no role was touched
    w1.validate()


def test_heal_exhausts_spares_gracefully():
    w = WorldState.create(8, 1.0, n_spares=1)  # 4 cmp, 3 rep, spare {7}
    w1, _ = w.repair([4])  # rep of cmp 0 dies
    healed, plan = w1.heal()
    assert len(plan.actions) == 1 and not healed.spares  # pool drained
    w2, _ = healed.repair([5])  # rep of cmp 1 dies: nothing left to heal
    again, plan2 = w2.heal()
    assert not plan2 and again is w2  # pool empty: no-op, no crash
    assert again.replica_deficit() == 1 and plan2.deficit_after == 1


# ---------------------------------------------------------------------------
# property-based: repair . heal invariants under arbitrary sequences
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(3, 20),
    r=st.sampled_from([0.0, 0.5, 1.0]),
    n_spares=st.integers(0, 3),
    kills=st.lists(st.integers(0, 19), min_size=1, max_size=8),
    heal_each=st.booleans(),
)
def test_repair_heal_invariants(n, r, n_spares, kills, heal_each):
    """After ANY interleaving of failures and heals: role<->physical stays
    a bijection disjoint from spares and dead, every replica_map target is
    a live cmp role, mirror groups are disjoint partitions, and healing
    never pushes n_rep above the configured target."""
    n_spares = min(n_spares, n - 2)
    world = WorldState.create(n, r, n_spares=n_spares)
    target = world.target_n_rep
    for k in kills:
        victim = k % world.n_physical
        world, rep = world.repair([victim])
        if world.topo.n_comp == 0:
            return  # whole computational capacity lost - nothing to check
        world.validate()
        pre_rep = world.topo.n_rep
        if heal_each:
            world, plan = world.heal()
            world.validate()
            # healing only ever closes the deficit toward target
            assert world.topo.n_rep <= max(pre_rep, world.target_n_rep)
            assert world.topo.n_rep >= pre_rep
            assert world.generation == plan.generation
        # bijection + disjointness (validate asserts too; be explicit)
        assert len(set(world.assignment)) == len(world.assignment)
        assert not set(world.assignment) & set(world.spares)
        assert not set(world.assignment) & set(world.dead)
        # mirror groups disjoint and partition the live mesh
        groups = world.physical_groups(world.topo.comm_cmp_groups())
        flat = sorted(i for g in groups for i in g)
        assert flat == list(range(world.n_live))
        pairs = world.topo.pair_groups()
        seen = [i for g in pairs for i in g]
        assert len(seen) == len(set(seen)), "mirror groups overlap"
        # every replica target is a live cmp role
        assert all(0 <= c < world.topo.n_comp for c in world.topo.replica_map)
        # exposure bookkeeping never references mirrored or out-of-range roles
        mirrored = set(world.topo.replica_map)
        assert all(
            0 <= c < world.topo.n_comp and c not in mirrored
            for c, _ in world.exposed
        )


# ---------------------------------------------------------------------------
# Healer execution: clone + pair re-registration + shard re-placement
# ---------------------------------------------------------------------------


def _state(v: float):
    return {"params": {"w": np.full((8, 8), v)}, "opt": {"mu": np.full((4,), v / 2)}}


def test_healer_executes_clone_and_reregisters_pairs():
    w = WorldState.create(6, 1.0, n_spares=2)
    w1, _ = w.repair([3])
    # ring deliberately excludes the spares: re-registration must admit them
    ps = PartnerMemoryStore(range(4), redundancy=2)
    ps.submit(2, _state(2.0), {"step": 2})
    ps.on_failure([3])
    healer = Healer("eager", bit_exact=True)
    healed, plan = healer.maybe_heal(
        w1, snapshot=(_state(7.0), {"step": 3}), stores=[ps], step=3
    )
    assert plan and healed.topo.n_rep == 2
    # 3-phase clone executed and verified per phase
    assert plan.transfer is not None and plan.transfer.verified
    assert plan.transfer.bit_exact
    # the new pair's host joined the ring and shards were re-placed
    assert 4 in ps._live
    assert plan.replaced_steps == [2]
    assert ps.recoverable(2)


def test_healer_respects_policy_and_empty_pool():
    w = WorldState.create(6, 1.0, n_spares=2)
    w1, _ = w.repair([3])
    none = Healer("none")
    assert none.maybe_heal(w1) == (w1, None)
    deferred = Healer("deferred:2")
    assert deferred.maybe_heal(w1) == (w1, None)  # deficit 1 < 2
    w2, _ = w1.repair([2])  # second replica dies -> deficit 2
    healed, plan = deferred.maybe_heal(w2)
    assert plan and len(plan.actions) == 2  # batched heal
    assert healed.replica_deficit() == 0


def test_partner_register_peers_idempotent_and_rebalance_skips_torn():
    ps = PartnerMemoryStore(range(4), redundancy=3)
    ps.submit(1, _state(1.0))
    ps.on_failure([0, 1, 2])  # shard 0 lived on 0/1/2 only: step 1 torn
    ps.submit(2, _state(2.0))  # placed on the single-survivor ring {3}
    ps.register_peers([4, 5])
    ps.register_peers([4])  # idempotent
    assert ps._live == [3, 4, 5]
    replaced = ps.rebalance()
    assert replaced == [2]  # torn step 1 has nothing to gather: skipped
    # step 2's shards were re-spread K=3 over {3,4,5}: losing its ORIGINAL
    # sole holder no longer loses the snapshot
    ps.on_failure([3])
    assert ps.recoverable(2)
    assert not ps.recoverable(1)


# ---------------------------------------------------------------------------
# fault-scenario matrix (slow): every cell bit-identical to failure-free
# ---------------------------------------------------------------------------

_MATRIX_CHILD = """
        import jax, numpy as np, tempfile
        from repro.configs.registry import smoke_config
        from repro.core.simulator import SimCluster
        from repro.store import (DurableStore, LiveCloneStore,
                                 PartnerMemoryStore, RecoveryLadder)

        CFG = smoke_config("qwen2.5-3b")
        STEPS = 6

        def stack(spec, n):
            if spec == "none":
                return None
            levels = []
            if "L0" in spec:
                levels.append(LiveCloneStore(host=SAFE_HOST))
            if "L1" in spec:
                levels.append(PartnerMemoryStore(range(n), redundancy=2))
            if "L2" in spec:
                levels.append(DurableStore(tempfile.mkdtemp()))
            return RecoveryLadder(levels)

        def cluster(heal, stores):
            return SimCluster(
                CFG, n_slices=N_SLICES, model_shards=1, rdegree=RDEGREE,
                spares=SPARES, heal=heal, seq_len=32, stores=stores,
                checkpoint_every=0 if stores is None else 2,
            )

        ref = cluster("eager", None)
        ref_rep = ref.run(STEPS)
        ref_leaves = jax.tree.leaves(ref.params_replica())

        for heal, schedule, spec, expect in CELLS:
            sim = cluster(heal, stack(spec, N_SLICES))
            rep = sim.run(STEPS, failures=schedule)
            diff = max(
                float(np.max(np.abs(a - b)))
                for a, b in zip(ref_leaves, jax.tree.leaves(sim.params_replica()))
            )
            cell = f"cell(heal={heal}, schedule={schedule}, stores={spec})"
            if expect == "bitwise":
                assert diff == 0.0, f"{cell}: diverged by {diff}"
                # replay re-runs steps (losses get replayed entries); the
                # FINAL loss and the full parameter state must match bitwise
                assert rep.losses[-1] == ref_rep.losses[-1], f"{cell}: loss"
                assert sim.world.topo.n_comp == ref.world.topo.n_comp, cell
            else:  # the un-healed decay contrast cell: the world shrank
                # (exposure_steps tracks REPLICA deficit, which is 0 by
                # definition at rdegree=0 - width loss is the decay there)
                assert sim.world.topo.n_comp < ref.world.topo.n_comp, cell
                assert rep.restarts >= 1, cell
            print("CELL-OK", cell, f"heals={len(rep.heals)}",
                  f"restored={rep.restored_from}")
        print("MATRIX-OK")
"""


def _matrix_test(preamble: str):
    out = run_subprocess(preamble + _MATRIX_CHILD)
    assert "MATRIX-OK" in out
    return out


@pytest.mark.slow
def test_fault_matrix_rdegree_one():
    """rdegree=1.0 (2 cmp + 2 rep + 2 spares): replica kill + heal,
    back-to-back kill of a healed pair's cmp, simultaneous mirrored-pair
    kill (backfill + restore), deferred batching, and the heal=none
    promote baseline - all bit-identical to failure-free."""
    out = _matrix_test(
        """
        N_SLICES, SPARES, RDEGREE, SAFE_HOST = 6, 2, 1.0, 0
        CELLS = [
            ("eager", {2: [3]}, "L1", "bitwise"),
            ("eager", {2: [3], 4: [1]}, "L1", "bitwise"),
            ("eager", {3: [1, 3]}, "L1+L2", "bitwise"),
            ("deferred:2", {2: [2], 3: [3]}, "L1", "bitwise"),
            ("none", {2: [0]}, "L1", "bitwise"),
        ]
        """
    )
    assert out.count("CELL-OK") == 5


@pytest.mark.slow
def test_fault_matrix_rdegree_half():
    """rdegree=0.5 (2 cmp + 1 rep + 1 spare): heal of the only mirror,
    unmirrored-cmp backfill through a partner restore, mirrored-pair kill
    restoring through the L0 live-clone rung, and the promote baseline.

    Matrix worlds use n_comp=2: two-summand gradient reductions are
    order-insensitive, so bit-identity is well-defined across the mesh
    permutation a repair induces. Wider reductions re-associate fp sums
    when roles land on different devices - true of real meshes too, and
    orthogonal to the heal plane."""
    out = _matrix_test(
        """
        N_SLICES, SPARES, RDEGREE, SAFE_HOST = 4, 1, 0.5, 1
        CELLS = [
            ("eager", {2: [2]}, "L1", "bitwise"),
            ("eager", {3: [1]}, "L1", "bitwise"),
            ("eager", {3: [0, 2]}, "L0+L1", "bitwise"),
            ("none", {2: [0]}, "L1", "bitwise"),
        ]
        """
    )
    assert out.count("CELL-OK") == 4


@pytest.mark.slow
def test_fault_matrix_rdegree_zero():
    """rdegree=0 (2 cmp + 2 spares): every failure is unmaskable - spare
    backfill + ladder restore (or fresh-init full replay) keeps the
    trajectory bit-identical; without healing the world decays (the
    contrast cell documents the erosion the heal plane removes)."""
    out = _matrix_test(
        """
        N_SLICES, SPARES, RDEGREE, SAFE_HOST = 4, 2, 0.0, 0
        CELLS = [
            ("eager", {3: [1]}, "L1", "bitwise"),
            ("eager", {2: [1]}, "none", "bitwise"),
            ("eager", {2: [0], 4: [1]}, "L1+L2", "bitwise"),
            ("none", {2: [1]}, "L1", "decay"),
        ]
        """
    )
    assert out.count("CELL-OK") == 4


# ---------------------------------------------------------------------------
# flagship (slow): post-heal mirrored-pair kill survives re-replication
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_post_heal_pair_kill_survives_via_reestablished_replica():
    """Acceptance scenario: a replica dies and is re-established from a
    spare; then the ORIGINAL pair's other member dies. With healing the
    re-established replica masks it (promote, no restart) and the final
    state is bit-identical; a simultaneous kill of the HEALED pair
    backfills + restores, still bit-identical. Without healing the same
    schedule decays to a shrunk, checkpoint-only world."""
    out = run_subprocess(
        """
        import jax, numpy as np
        from repro.configs.registry import smoke_config
        from repro.core.simulator import SimCluster

        cfg = smoke_config("qwen2.5-3b")
        def mk(heal):
            return SimCluster(cfg, n_slices=6, model_shards=1, rdegree=1.0,
                              spares=2, heal=heal, seq_len=32,
                              checkpoint_every=2)
        def leaves(s):
            return jax.tree.leaves(s.params_replica())

        ref = mk("eager"); ref_rep = ref.run(6)

        # replica of cmp1 (phys 3) dies @2 -> healed from spare 4;
        # cmp1 itself (phys 1) dies @4 -> MASKED by the re-established replica
        a = mk("eager"); ra = a.run(6, failures={2: [3], 4: [1]})
        assert ra.healed_replicas == 2 and ra.promotes == 1, ra.heals
        assert ra.restarts == 0, "the healed replica must mask the kill"
        assert ra.exposure_steps == 0  # never ran below target
        diff = max(float(np.max(np.abs(x - y)))
                   for x, y in zip(leaves(ref), leaves(a)))
        assert diff == 0.0 and ref_rep.losses == ra.losses

        # the HEALED pair dies simultaneously @4 -> spare 5 backfills the
        # role + partner-memory restore: width preserved, still bitwise
        b = mk("eager"); rb = b.run(6, failures={2: [3], 4: [1, 4]})
        assert rb.restarts == 1 and b.world.topo.n_comp == 2
        assert rb.restored_from and rb.restored_from[0].startswith("L1:")
        diffb = max(float(np.max(np.abs(x - y)))
                    for x, y in zip(leaves(ref), leaves(b)))
        assert diffb == 0.0 and rb.losses[-1] == ref_rep.losses[-1]

        # baseline: same schedule, heal=none -> monotone decay
        c = mk("none"); rc = c.run(6, failures={2: [3], 4: [1]})
        assert rc.restarts == 1 and c.world.topo.n_comp == 1
        assert rc.exposure_steps > 0
        print("POST-HEAL-PAIR-OK")
        """
    )
    assert "POST-HEAL-PAIR-OK" in out


@pytest.mark.slow
def test_serving_healed_replica_warms_cache_from_partner():
    """A healed replica joins mid-decode with its KV cache warmed from its
    partner's rows; when the partner later dies, the promoted healed
    replica continues the stream bit-identically (a cold cache would
    diverge instantly)."""
    out = run_subprocess(
        """
        import numpy as np
        from repro.configs.registry import smoke_config
        from repro.serving.engine import ServeEngine

        cfg = smoke_config("qwen2.5-3b")
        def mk(heal="eager"):
            return ServeEngine(cfg, n_slices=6, model_shards=1, rdegree=1.0,
                               spares=2, heal=heal, max_len=64)

        ta = mk().decode(12)
        b = mk()
        # rep of cmp0 (phys 2) dies @4 -> healed from spare 4 (cache warmed
        # from cmp0's rows); cmp0 (phys 0) dies @8 -> promote the healed one
        tb = b.decode(12, failures={4: [2], 8: [0]})
        r = b.report
        assert r.healed_replicas >= 1 and r.promotes == 1, r.heals
        assert r.restarts == 0 and r.requeued_requests == 0
        assert np.array_equal(ta, tb), "healed replica's cache was cold"
        print("SERVE-HEAL-OK")
        """
    )
    assert "SERVE-HEAL-OK" in out


@pytest.mark.slow
def test_serving_backfill_keeps_all_streams():
    """rdegree=0 + spares + snapshots: an unmirrored slice loss used to
    drop its request streams; now the spare backfills the role and the
    re-decode from the snapshot keeps EVERY stream, bit-identical."""
    out = run_subprocess(
        """
        import numpy as np
        from repro.configs.registry import smoke_config
        from repro.serving.engine import ServeEngine

        cfg = smoke_config("qwen2.5-3b")
        a = ServeEngine(cfg, n_slices=5, model_shards=1, rdegree=0.0,
                        spares=1, max_len=64)
        ta = a.decode(12)
        b = ServeEngine(cfg, n_slices=5, model_shards=1, rdegree=0.0,
                        spares=1, heal="eager", max_len=64, snapshot_every=4)
        tb = b.decode(12, failures={9: [2]})
        r = b.report
        assert r.restarts == 1 and r.restored_from, r.restored_from
        assert r.requeued_requests == 0, "backfill must keep the stream"
        assert tb.shape == ta.shape  # all 4 streams survive
        assert np.array_equal(tb, ta), "re-decode diverged"
        print("SERVE-BACKFILL-OK")
        """
    )
    assert "SERVE-BACKFILL-OK" in out
