"""Paged decode state (repro.serving.paging): page math, table
invariants, and end-to-end stream bit-identity against the dense oracle.

The fast tests drive the :class:`PageTable` directly with a synthetic
leaf geometry (append-only attention K/V, a windowed ring leaf, and a
recurrent block leaf) and check the structural invariants the engine
relies on: the slot->page bijection, shared-prefix refcount exactness,
dirty/settled disjointness, and meta round-trips.

The slow tests run the real engine in subprocesses: the paged layout must
serve every client stream bitwise-identical to the dense (page_tokens=0)
oracle across the kill/heal/failover matrix, heal warm-up must move only
live pages, scrubbing must splice back only the poisoned page, and idle
cadence ticks must skip the snapshot entirely.
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import SRC, run_subprocess
from repro.serving.gateway import validate_bounds
from repro.serving.paging import (
    CacheLeaf,
    PageTable,
    dirty_page_indices,
    prefix_hash,
)

# synthetic geometry: two append-only attention leaves, one windowed
# (ring) leaf, one recurrent block leaf without a token axis
LEAVES = [
    CacheLeaf(path="blk/attn/k", batch_axis=1, smax=64, ring=False),
    CacheLeaf(path="blk/attn/v", batch_axis=1, smax=64, ring=False),
    CacheLeaf(path="blk/win/k", batch_axis=1, smax=16, ring=True),
    CacheLeaf(path="blk/ssm/state", batch_axis=1, smax=None, ring=False),
]


def mk_table(page: int = 8, prefix_share: bool = True) -> PageTable:
    t = PageTable(page, prefix_share=prefix_share)
    t.configure(LEAVES)
    return t


def gather(t: PageTable) -> None:
    """Simulate the engine's snapshot gather: bind every dirty page."""
    for e in list(t.slots.values()):
        for r in t.dirty_refs(e):
            t.pages[r.key] = np.zeros(1)
    t.mark_gathered()


# ---------------------------------------------------------------------------
# page math
# ---------------------------------------------------------------------------


def test_dirty_pages_append_marks_only_tail():
    # advancing 8 -> 9 in a 64-deep leaf touches only page 1 (P=8)
    assert dirty_page_indices(8, 9, smax=64, page=8) == {1}
    assert dirty_page_indices(0, 8, smax=64, page=8) == {0}
    assert dirty_page_indices(7, 9, smax=64, page=8) == {0, 1}
    assert dirty_page_indices(5, 5, smax=64, page=8) == set()
    assert dirty_page_indices(9, 5, smax=64, page=8) == set()


def test_dirty_pages_ring_wrap_marks_modular_window():
    # ring of 16, pages of 8: writing rows 14,15,0,1 touches both pages
    assert dirty_page_indices(14, 18, smax=16, page=8) == {0, 1}
    # writes confined to the second half touch only page 1
    assert dirty_page_indices(8, 12, smax=16, page=8) == {1}
    # advancing a full ring (or more) dirties every page
    assert dirty_page_indices(0, 20, smax=16, page=8) == {0, 1}
    assert dirty_page_indices(37, 99, smax=16, page=8) == {0, 1}


@settings(max_examples=150, deadline=None)
@given(seed=st.integers(0, 10**9))
def test_property_dirty_pages_equal_pages_of_written_rows(seed):
    """Ground truth: simulate the ring writes row by row - the marked
    page set must be EXACTLY the pages containing a written row (sound:
    no written row escapes; tight: no clean page ships)."""
    rng = np.random.default_rng(seed)
    smax = int(rng.choice([8, 16, 32, 64]))
    page = int(rng.choice([4, 8, 16]))
    c0 = int(rng.integers(0, 100))
    c1 = c0 + int(rng.integers(0, 150))
    written = {t % smax for t in range(c0, c1)}
    marked = dirty_page_indices(c0, c1, smax, page)
    assert marked == {r // page for r in written}, (seed, c0, c1, smax, page)


def test_prefix_hash_content_addresses_exactly_n_tokens():
    assert prefix_hash([1, 2, 3, 4], 4) == prefix_hash([1, 2, 3, 4, 99], 4)
    assert prefix_hash([1, 2, 3, 4], 4) != prefix_hash([1, 2, 3, 5], 4)
    assert prefix_hash(np.asarray([7, 8]), 2) == prefix_hash([7, 8], 2)


# ---------------------------------------------------------------------------
# bounds validation (CLI wiring is exercised by the slow test below)
# ---------------------------------------------------------------------------


def test_page_table_rejects_bad_page_tokens():
    for bad in (0, -8, 3, 100):
        with pytest.raises(AssertionError):
            PageTable(bad)
    PageTable(1)
    PageTable(128)


def test_validate_bounds_page_tokens_edges():
    validate_bounds(1, None, page_tokens=None)
    validate_bounds(1, None, page_tokens=1)
    validate_bounds(1, None, page_tokens=128)
    # zero and negative are CLI-invalid (the dense baseline is the
    # engine-API ServeEngine(page_tokens=0), not a CLI mode)
    for bad in (0, -4, -1):
        with pytest.raises(ValueError, match="--page-tokens"):
            validate_bounds(1, None, page_tokens=bad)
    for bad in (3, 100, 6):
        with pytest.raises(ValueError, match="--page-tokens"):
            validate_bounds(1, None, page_tokens=bad)


# ---------------------------------------------------------------------------
# table lifecycle: reset / sharing / remap / meta
# ---------------------------------------------------------------------------


def test_reset_drops_private_pages_and_bumps_uid():
    t = mk_table(page=8)
    e = t.ensure(0, 0)
    e.count = 12
    gather(t)
    assert t.pages  # pages materialized
    t.check_invariants()
    uid0 = e.uid
    t.reset([(0, 0)])
    assert t.slots[(0, 0)].uid > uid0  # next occupant gets fresh keys
    assert not t.pages  # the reset IS the page drop - no tree rebuild
    assert t.slots[(0, 0)].count == 0
    t.check_invariants()


def test_shared_prefix_pages_refcounted_and_gced():
    t = mk_table(page=4)
    prompt = list(range(1, 10))  # 9 tokens -> shared pages {0, 1} at P=4
    for lane in (0, 1):
        e = t.ensure(0, lane)
        t.note_prompt(0, lane, prompt)
        e.count = 9
    gather(t)
    e0, e1 = t.slots[(0, 0)], t.slots[(0, 1)]
    assert set(e0.shared) == {0, 1} and e0.shared == e1.shared
    shared0 = {r.key for r in t.slot_pages(e0) if r.shared}
    shared1 = {r.key for r in t.slot_pages(e1) if r.shared}
    # both slots reference the SAME sealed page copies, one per non-ring
    # time leaf per prompt page; the ring and block leaves never share
    assert shared0 == shared1 and len(shared0) == 4
    assert all(t.refs[k] == 2 for k in shared0)
    # a twin admitting the same prompt gathers nothing for sealed pages
    # the first slot already materialized
    assert not any(r.shared for r in t.dirty_refs(e1))
    t.check_invariants()
    t.reset([(0, 1)])
    assert all(t.refs[k] == 1 for k in shared0)
    assert all(k in t.pages for k in shared0)  # still referenced
    t.check_invariants()
    t.reset([(0, 0)])
    assert not t.refs and not t.pages  # last reference frees the bytes
    t.check_invariants()


def test_remap_preserves_uids_and_drops_dead_roles():
    t = mk_table(page=4)
    for role in (0, 1, 2):
        e = t.ensure(role, 0)
        e.count = 5
    gather(t)
    uids = {role: t.slots[(role, 0)].uid for role in (0, 1, 2)}
    # role 1 died: new role 0 continues old 0, new role 1 continues old 2
    t.remap([0, 2], lanes=1)
    assert set(t.slots) == {(0, 0), (1, 0)}
    assert t.slots[(0, 0)].uid == uids[0]
    assert t.slots[(1, 0)].uid == uids[2]  # page keys survive renumbering
    live = {r.key for e in t.slots.values() for r in t.slot_pages(e)}
    assert all(k in live for k in t.pages), "dead role's pages must drop"
    t.check_invariants()
    t.invalidate()
    assert not t.pages
    for e in t.slots.values():
        assert t.settled_refs(e) == []  # nothing is settled post-repack
        assert t.dirty_refs(e)  # everything re-gathers from ground truth
    t.check_invariants()


def test_meta_roundtrip_restores_slots_and_sharing():
    import json

    t = mk_table(page=4)
    t.note_prompt(1, 0, [1, 2, 3, 4, 5])
    t.slots[(1, 0)].count = 7
    t.ensure(0, 1).count = 3
    gather(t)
    t.mark_submitted()
    meta = t.to_meta({(1, 0): 2, (0, 1): 1}, {(1, 0): 5}, n_rows=8)
    meta = json.loads(json.dumps(meta))  # must survive the manifest
    t2 = mk_table(page=4)
    t2.load_meta(meta)
    assert set(t2.slots) == set(t.slots)
    for k, a in t.slots.items():
        b = t2.slots[k]
        assert (a.uid, a.count, a.prompt_len) == (b.uid, b.count, b.prompt_len)
        assert a.shared == b.shared
    assert t2.refs == t.refs
    for e in t2.slots.values():
        assert t2.settled_refs(e) == []  # restored marks are stale
        if e.count:
            assert t2.dirty_refs(e)  # the next snapshot re-gathers
    t2.check_invariants()


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10**9))
def test_property_table_invariants_across_random_lifecycles(seed):
    """Random admission / decode / gather / submit / repack / free
    schedules: the slot->page bijection holds after every operation,
    shared refcounts stay exact, no page bytes are orphaned, and a page
    is never both settled and dirty."""
    rng = np.random.default_rng(seed)
    roles, lanes = 3, 2
    t = mk_table(page=4)
    prompts = [list(range(1, 6)), [7] * 9, [2, 3], list(range(20, 33))]
    for _ in range(40):
        op = int(rng.integers(0, 6))
        slot = (int(rng.integers(0, roles)), int(rng.integers(0, lanes)))
        if op == 0:  # admit: free the slot, pin a prompt, prefill
            t.reset([slot])
            p = prompts[int(rng.integers(0, len(prompts)))]
            t.note_prompt(slot[0], slot[1], p)
            t.slots[slot].count = len(p)
        elif op == 1:  # decode a few tokens on every live slot
            for e in t.slots.values():
                if e.count:
                    e.count += int(rng.integers(1, 4))
        elif op == 2:  # snapshot gather (restore template / heal)
            gather(t)
        elif op == 3:  # cadence submit
            gather(t)
            t.mark_submitted()
        elif op == 4:  # elastic repack: renumber roles, invalidate cache
            keep = [int(x) for x in rng.permutation(roles)]
            t.remap(keep, lanes)
            t.invalidate()
        else:  # free
            t.reset([slot])
        t.check_invariants()
        for e in t.slots.values():
            settled = {r.key for r in t.settled_refs(e)}
            dirty = {r.key for r in t.dirty_refs(e) if not r.shared}
            assert not (settled & dirty), (seed, settled & dirty)


# ---------------------------------------------------------------------------
# real-engine integration (subprocess, slow)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_paged_kill_heal_matrix_bit_identical_and_page_accounting():
    """The acceptance matrix: paged (page_tokens=8) and dense
    (page_tokens=0) gateways serve identical client streams with and
    without a mid-stream kill + spare backfill; the paged heal warms the
    backfilled rows by moving only live pages (strictly fewer bytes than
    dense full rows); a same-prompt cohort shares its prompt page."""
    out = run_subprocess(
        """
        import numpy as np
        from repro.configs.registry import smoke_config
        from repro.serving.engine import ServeEngine
        from repro.serving.gateway import ServeGateway

        cfg = smoke_config("qwen2.5-3b")
        PROMPT = list(range(11, 19))  # 8 tokens: one full page at P=8

        def mk(pt):
            eng = ServeEngine(cfg, n_slices=3, model_shards=1, rdegree=0.0,
                              spares=1, heal="eager", max_len=64,
                              slot_granular=True, page_tokens=pt)
            return ServeGateway(eng, max_queue=64)

        def workload(gw):
            rng = np.random.default_rng(0)
            out = []
            for i in range(12):
                p = (np.asarray(PROMPT) if i % 3 == 0
                     else rng.integers(1, 50, size=2 + i % 3))
                out.append(gw.submit(p, max_new=4 + i % 5, at_step=i // 4))
            return out

        runs = {}
        for pt in (0, 8):
            for kill in (False, True):
                gw = mk(pt); ss = workload(gw)
                gw.serve(max_steps=10_000,
                         failures={6: [1]} if kill else None)
                assert all(s.done for s in ss), (pt, kill)
                if pt:
                    gw.engine.table.check_invariants()
                runs[(pt, kill)] = (gw, [s.tokens for s in ss])

        base = runs[(0, False)][1]
        for key, (gw, toks) in runs.items():
            assert toks == base, f"streams diverged from dense oracle: {key}"

        # heal warm-up at page granularity: only live pages moved
        gk = runs[(8, True)][0].engine
        assert 0 < gk.heal_warm_bytes < gk.heal_warm_bytes_full, (
            gk.heal_warm_bytes, gk.heal_warm_bytes_full)

        # prefix sharing: a same-prompt cohort in flight references ONE
        # sealed copy of the prompt page per leaf
        gd = mk(8)
        for _ in range(4):
            gd.submit(np.asarray(PROMPT), max_new=6)
        t, best = 0, 0.0
        while gd.pending() and t < 200:
            gd.run_step(t); t += 1
            best = max(best, gd.summary().get("prefix_dedupe_ratio", 0.0))
        assert best >= 2.0, best
        gd.engine.table.check_invariants()
        print("PAGED-MATRIX-OK", gk.heal_warm_bytes,
              gk.heal_warm_bytes_full, best)
        """,
        devices=4,
    )
    assert "PAGED-MATRIX-OK" in out


@pytest.mark.slow
def test_property_paged_streams_match_dense_oracle_random_schedules():
    """Property run over random admission x kill/heal/failover schedules
    (mixed shared/unique prompts, random kill step and victim): every
    paged stream - failure-free and killed - is bitwise equal to the
    dense failure-free oracle, and the page table's invariants hold after
    every serve."""
    out = run_subprocess(
        """
        import numpy as np
        from repro.configs.registry import smoke_config
        from repro.serving.engine import ServeEngine
        from repro.serving.gateway import ServeGateway

        cfg = smoke_config("qwen2.5-3b")

        def mk(pt):
            eng = ServeEngine(cfg, n_slices=3, model_shards=1, rdegree=0.0,
                              spares=1, heal="eager", max_len=64,
                              slot_granular=True, page_tokens=pt)
            return ServeGateway(eng, max_queue=64)

        for seed in (1, 2, 3):
            rng = np.random.default_rng(seed)
            n_req = int(rng.integers(6, 11))
            shared_prompt = rng.integers(1, 50, size=8)
            reqs = []
            for i in range(n_req):
                p = (shared_prompt.copy() if rng.integers(0, 2)
                     else rng.integers(1, 50, size=int(rng.integers(1, 6))))
                reqs.append((p, int(rng.integers(2, 8)),
                             int(rng.integers(0, 4))))
            kill = {int(rng.integers(3, 10)): [int(rng.integers(0, 4))]}

            def run(pt, failures=None):
                gw = mk(pt)
                ss = [gw.submit(p, max_new=m, at_step=a)
                      for p, m, a in reqs]
                gw.serve(max_steps=10_000, failures=failures)
                assert all(s.done for s in ss), (seed, pt, failures)
                if pt:
                    gw.engine.table.check_invariants()
                return ss

            oracle = run(0)                       # dense, failure-free
            s_ff = run(8)                         # paged, failure-free
            s_kill = run(8, failures=kill)        # paged, random kill
            for a, b, c in zip(oracle, s_ff, s_kill):
                assert a.tokens == b.tokens == c.tokens, (seed, a.rid)
                assert a.finish_reason == b.finish_reason == c.finish_reason
        print("PAGED-PROPERTY-OK")
        """,
        devices=4,
    )
    assert "PAGED-PROPERTY-OK" in out


@pytest.mark.slow
def test_snapshot_skip_and_scrub_page_splice():
    """Satellites 1 + 2 end to end: an idle cadence tick ships nothing
    (snapshots_skipped accounting), a poisoned settled page is detected
    by the per-page crc reference, confirmed by the 2-of-3 vote against
    the mirror row, and spliced back ALONE through restore_partial -
    bit-identical to the clean oracle; an identical corruption on BOTH
    rows votes the reference the odd one out (transient, no repair)."""
    out = run_subprocess(
        """
        import numpy as np, jax
        from repro.configs.registry import smoke_config
        from repro.serving.engine import ServeEngine
        from repro.scrub import ScrubPlane
        from repro.dist.sharding import path_str

        cfg = smoke_config("qwen2.5-3b")

        def mk(scrub=None):
            return ServeEngine(cfg, n_slices=4, model_shards=1,
                               rdegree=1.0, max_len=64, snapshot_every=4,
                               page_tokens=4, scrub=scrub)

        scrub = ScrubPlane()
        eng = mk(scrub)
        toks = eng.decode(8)
        r = eng.report

        # --- satellite 1: no-op cadence ticks skip the snapshot --------
        eng.session._checkpoint(eng.pos)  # settle any residue
        base = r.snapshots_skipped
        assert eng.snapshot_dirty() is None  # clean -> nothing to ship
        eng.session._checkpoint(eng.pos)
        assert r.snapshots_skipped == base + 1, r.snapshots_skipped
        blob, meta = eng.snapshot()  # the FULL template still materializes
        assert len(blob) > 0
        assert scrub.page_reference, "paged submits must record page crcs"

        eng2 = mk()
        toks2 = eng2.decode(8)
        assert np.array_equal(toks, toks2)

        # --- satellite 2: poison ONE settled page on the cmp row -------
        leaf = next(l for l in eng.table.leaves if l.smax is not None)
        e = next(iter(eng.table.slots.values()))
        row = eng._slot_row(e.role, e.lane)
        mrow = eng._mirror_row(e.role, e.lane)
        assert mrow >= 0

        def poison(rows):
            def fn(kp, arr):
                if path_str(kp) != leaf.path:
                    return arr
                idx = (slice(None),) * leaf.batch_axis
                for rr in rows:
                    arr = arr.at[idx + (rr, slice(0, 2))].add(1000.0)
                return arr
            return fn

        eng.cache = jax.tree_util.tree_map_with_path(
            poison([row]), eng.cache)
        res = eng.scrub_kv()
        assert res is not None and res["repaired"], res
        assert len(res["corrupt"]) == 1, res  # ONLY the poisoned page
        assert 0 < res["moved_bytes"] < res["total_bytes"], res
        assert r.sdc_detected == 1 and r.sdc_repairs == 1

        # splice restored the submitted bytes exactly: page blobs match
        # the clean oracle bit for bit
        b1, _ = eng.snapshot()
        b2, _ = eng2.snapshot()
        assert set(b1) == set(b2)
        for k in b1:
            assert np.array_equal(np.asarray(b1[k]), np.asarray(b2[k])), k

        # --- identical corruption on BOTH rows: pair outvotes the
        # reference -> transient, no repair ----------------------------
        eng.session._checkpoint(eng.pos)  # re-settle post-restore marks
        eng.cache = jax.tree_util.tree_map_with_path(
            poison([row, mrow]), eng.cache)
        res2 = eng.scrub_kv()
        assert res2 is not None and not res2["repaired"], res2
        assert res2["transient"] >= 1 and not res2["corrupt"], res2
        print("SCRUB-PAGED-OK")
        """,
        devices=4,
    )
    assert "SCRUB-PAGED-OK" in out


@pytest.mark.slow
def test_serve_cli_page_tokens_rejected():
    """--page-tokens rejects zero, negative, and non-power-of-two values
    on both the gateway and lockstep paths."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    for extra, flags in [
        ([], ["--page-tokens", "0"]),
        ([], ["--page-tokens", "-4"]),
        ([], ["--page-tokens", "100"]),
        (["--gateway"], ["--page-tokens", "0"]),
        (["--gateway"], ["--page-tokens", "3"]),
        (["--gateway"], ["--page-tokens", "-1"]),
    ]:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve",
             "--slices", "2", "--model-shards", "1"] + extra + flags,
            capture_output=True, text=True, env=env, timeout=300,
        )
        assert proc.returncode != 0, (extra, flags)
        assert "--page-tokens" in proc.stderr, (flags, proc.stderr[-500:])
