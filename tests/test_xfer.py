"""The repro.xfer transfer plane.

Chunking/striping edge cases (empty pytree, scalar leaves, chunk size
larger than the largest leaf, odd ring sizes), verified-exact delta
encoding (including a delta submit across a ring shrink with stale
placement purged), the pipelined async stager (capture-before-return,
drain barrier, double-buffer backpressure, error propagation), the
fine-grained placement locking (a load completes while a submit is
stalled mid-placement - deterministic, event-gated), and the fused
checksum-digest verification path.
"""
import threading
import time

import numpy as np
import pytest

from repro.store import PartnerMemoryStore, RecoveryLadder, flatten_with_paths
from repro.xfer import (
    AsyncStager,
    DeltaEncoder,
    TransferPlane,
    capture_tree,
    chunk_blob,
    chunk_count,
    stripe_holders,
    tree_digests,
    verify_tree,
)


def _state(v: float):
    return {
        "params": {"w": np.full((16, 16), v), "b": np.arange(4.0)},
        "opt": {"mu": np.full((8, 8), v / 2), "nu": np.full((8, 8), v / 4)},
    }


def _tmpl():
    return _state(0.0)


# ---------------------------------------------------------------------------
# chunking / striping
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk_bytes", [4, 64, 1 << 20])
def test_chunk_roundtrip_mixed_dtypes(chunk_bytes):
    blob = {
        "a": np.arange(16.0).reshape(4, 4),
        "b": np.arange(5, dtype=np.int32),
        "c": np.asarray(np.float64(3.5)),  # scalar leaf
        "d": np.array([True, False, True]),
    }
    cb = chunk_blob(blob, chunk_bytes)
    out = cb.to_blob()
    assert set(out) == set(blob)
    for k in blob:
        assert out[k].dtype == blob[k].dtype and out[k].shape == blob[k].shape
        assert np.array_equal(out[k], blob[k])
    assert cb.total_bytes == sum(v.nbytes for v in blob.values())


def test_chunk_empty_blob():
    cb = chunk_blob({}, 64)
    assert cb.n_chunks == 0 and cb.to_blob() == {} and cb.total_bytes == 0


def test_chunk_roundtrip_zero_size_leaf():
    """A zero-size leaf contributes no chunk pieces but must survive the
    round trip (shape and dtype intact)."""
    blob = {"w": np.arange(4.0, dtype=np.float32),
            "empty": np.zeros((0, 3), np.float32),
            "tail": np.zeros((0,), np.int64)}
    out = chunk_blob(blob, 64).to_blob()
    for k in blob:
        assert out[k].shape == blob[k].shape and out[k].dtype == blob[k].dtype
        assert np.array_equal(out[k], blob[k])
    ps = PartnerMemoryStore(range(4))
    ps.submit(1, blob)
    step, state, _ = ps.load({k: np.zeros_like(v) for k, v in blob.items()})
    assert step == 1 and state["empty"].shape == (0, 3)


def test_gather_rejects_rechunked_placement():
    """A gather holding a STALE manifest entry while a resubmit re-chunked
    the step (ring changed) must come back None - never misaligned bytes
    or an IndexError - so load's transient-race retry can take over."""
    ps = PartnerMemoryStore(range(8), keep=4)
    ps.submit(5, _state(1.0))
    with ps._meta_lock:
        stale = ps._manifest[5]
    ps.register_peers([100, 101, 102])  # ring grows -> resubmit re-chunks
    ps.submit(5, _state(2.0))
    assert ps._gather(5, stale) is None  # stale entry, new placement
    step, state, _ = ps.load(_tmpl())  # fresh manifest still serves
    assert step == 5
    assert np.array_equal(state["params"]["w"], _state(2.0)["params"]["w"])


def test_chunk_larger_than_largest_leaf_spans_leaves():
    """One chunk can cover several leaves - layout, not leaf size, drives
    reassembly."""
    blob = {"x": np.arange(4.0), "y": np.arange(3, dtype=np.int16),
            "z": np.asarray(np.int64(7))}
    cb = chunk_blob(blob, 1 << 20)
    assert cb.n_chunks == 1
    out = cb.to_blob()
    assert all(np.array_equal(out[k], blob[k]) for k in blob)


def test_stripe_holders_odd_rings():
    assert stripe_holders(0, [2, 5, 9], 2) == [2, 5]
    assert stripe_holders(2, [2, 5, 9], 2) == [9, 2]  # wraps
    assert stripe_holders(7, [4], 3) == [4]  # ring smaller than K
    assert chunk_count(100, 1 << 20, min_chunks=7) == 7
    assert chunk_count(0, 1 << 20, min_chunks=7) == 0  # empty submits 0 chunks


@pytest.mark.parametrize("ring", [1, 3, 7])
def test_partner_store_roundtrip_odd_rings(ring):
    ps = PartnerMemoryStore(range(ring), redundancy=2)
    ps.submit(1, _state(1.0), {"r": ring})
    # striping reaches (essentially) the whole ring even for small states
    assert ps.last_chunked.n_chunks >= ring - 1
    step, state, meta = ps.load(_tmpl())
    assert step == 1 and meta["r"] == ring
    assert np.array_equal(state["params"]["w"], _state(1.0)["params"]["w"])


def test_partner_store_empty_and_scalar_states():
    ps = PartnerMemoryStore(range(4))
    ps.submit(1, {}, {"empty": True})
    step, state, meta = ps.load({})
    assert (step, state, meta["empty"]) == (1, {}, True)
    scalars = {"s": np.float64(2.5), "n": np.int32(7)}
    ps.submit(2, scalars)
    step, state, _ = ps.load({"s": np.float64(0.0), "n": np.int32(0)})
    assert step == 2 and float(state["s"]) == 2.5 and int(state["n"]) == 7


# ---------------------------------------------------------------------------
# delta encoding
# ---------------------------------------------------------------------------


def test_delta_zero_and_codec_chunks_reconstruct_exactly():
    enc = DeltaEncoder("bf16")
    b1 = flatten_with_paths(_state(1.0))
    b2 = flatten_with_paths(_state(1.5))
    enc.encode(chunk_blob(b1, 256))
    cb2 = enc.encode(chunk_blob(b2, 256))
    assert cb2.moved_bytes < cb2.total_bytes  # something delta-encoded
    out = cb2.to_blob()
    assert all(np.array_equal(out[k], b2[k]) for k in b2)  # bit-identical
    cb3 = enc.encode(chunk_blob({k: v.copy() for k, v in b2.items()}, 256))
    assert cb3.moved_bytes == 0  # unchanged resubmit ships nothing
    assert all(c.encoding == "zero" for c in cb3.chunks)


def test_delta_falls_back_to_raw_when_not_exact():
    """A delta the codec cannot reproduce byte-exactly must ship raw - the
    per-chunk verification, not luck, guarantees bit-identical restores."""
    rng = np.random.default_rng(0)
    enc = DeltaEncoder("int8")
    b1 = {"w": rng.standard_normal(64).astype(np.float32)}
    b2 = {"w": b1["w"] + rng.standard_normal(64).astype(np.float32) * 1e-3}
    enc.encode(chunk_blob(b1, 256))
    cb2 = enc.encode(chunk_blob(b2, 256))
    assert all(c.encoding == "raw" for c in cb2.chunks)
    assert np.array_equal(cb2.to_blob()["w"], b2["w"])


def test_delta_layout_change_resets_reference():
    enc = DeltaEncoder("bf16")
    enc.encode(chunk_blob({"w": np.ones(32, np.float32)}, 64))
    cb = enc.encode(chunk_blob({"w": np.ones(32, np.float32)}, 128))  # re-chunked
    assert all(c.encoding == "raw" for c in cb.chunks)  # full submit
    cb2 = enc.encode(chunk_blob({"w": np.ones(32, np.float32)}, 128))
    assert all(c.encoding == "zero" for c in cb2.chunks)  # reference rebuilt


def test_delta_submit_across_ring_shrink_purges_stale_placement():
    """Replay resubmits a step after the ring shrank: the old placement is
    purged, the re-chunked submit ships full (layout changed), the restore
    is bit-identical, and delta encoding resumes on the next submit."""
    plane = TransferPlane(delta="bf16", pipeline=False)
    ps = PartnerMemoryStore(range(5), xfer=plane, keep=4)  # odd ring
    ps.submit(6, _state(1.0))
    ps.submit(7, _state(1.5))
    assert ps.last_chunked.moved_bytes < ps.last_chunked.total_bytes
    ps.on_failure([0])
    ps.submit(7, _state(2.0))  # recrossed step 7 on the 4-peer ring
    cb = ps.last_chunked
    assert all(c.encoding == "raw" for c in cb.chunks)  # reference reset
    # stale placement purged: no peer holds a step-7 chunk beyond the new
    # chunk count, and nothing lives on the dead peer
    assert 0 not in ps._mem
    for m in ps._mem.values():
        assert all(ci < cb.n_chunks for (s, ci) in m if s == 7)
    step, state, _ = ps.load(_tmpl())
    assert step == 7
    assert np.array_equal(state["params"]["w"], _state(2.0)["params"]["w"])
    assert np.array_equal(state["opt"]["nu"], _state(2.0)["opt"]["nu"])
    ps.submit(8, _state(2.5))  # delta chain restarts against the new ref
    assert ps.last_chunked.moved_bytes < ps.last_chunked.total_bytes


# ---------------------------------------------------------------------------
# the async stager / pipelined ladder submit
# ---------------------------------------------------------------------------


def test_stager_orders_and_drains():
    st = AsyncStager(depth=2)
    acc = []
    for i in range(6):
        st.submit(lambda i=i: acc.append(i))
    st.drain()
    assert acc == list(range(6))  # FIFO, single worker


def test_stager_backpressure_bounded_by_depth():
    st = AsyncStager(depth=2)
    gate = threading.Event()
    third_submitted = threading.Event()
    st.submit(gate.wait)  # running
    st.submit(lambda: None)  # queued
    t = threading.Thread(
        target=lambda: (st.submit(lambda: None), third_submitted.set()),
        daemon=True,
    )
    t.start()
    time.sleep(0.05)
    assert not third_submitted.is_set()  # blocked: both buffers in flight
    gate.set()
    t.join(5)
    assert third_submitted.is_set()
    st.drain()


def test_stager_propagates_errors_on_drain():
    st = AsyncStager()
    st.submit(lambda: (_ for _ in ()).throw(RuntimeError("torn")))
    with pytest.raises(RuntimeError, match="torn"):
        st.drain()
    st.submit(lambda: None)  # usable after the error surfaced
    st.drain()


def test_ladder_submit_async_captures_before_return():
    """The capture-before-return contract survives pipelining: mutable
    numpy leaves are copied synchronously, so in-place mutation right
    after submit_async must not leak into the snapshot."""
    slow = threading.Event()

    class SlowStore(PartnerMemoryStore):
        def submit_blob(self, step, blob, meta=None):
            slow.wait(5)  # stage AFTER the caller mutated
            super().submit_blob(step, blob, meta)

    ladder = RecoveryLadder([SlowStore(range(4))])
    state = {"w": np.zeros(8)}
    ladder.submit_async(1, state, {})
    state["w"][:] = 9.0  # the program's next step mutates in place
    slow.set()
    ladder.drain()
    _, got, _ = ladder.store(1).load({"w": np.zeros(8)})
    assert np.array_equal(got["w"], np.zeros(8)), "mutation leaked into snapshot"


def test_capture_tree_copies_only_mutable_leaves():
    arr = np.arange(4.0)
    cap = capture_tree({"a": arr, "b": 3, "c": "s"})
    arr[:] = -1.0
    assert np.array_equal(cap["a"], [0.0, 1.0, 2.0, 3.0])
    assert cap["b"] == 3 and cap["c"] == "s"


# ---------------------------------------------------------------------------
# fine-grained placement locking (the contention satellite, deterministic)
# ---------------------------------------------------------------------------


def test_load_completes_while_submit_stalled_mid_placement():
    """With the old whole-submit global lock a load had to wait out the
    entire placement; per-chunk placement keeps metadata critical sections
    O(1), so a load serves an older step while a submit is stalled halfway
    through striping (gated by events - no timing assumptions)."""

    class Stalled(PartnerMemoryStore):
        gate = threading.Event()
        mid_placement = threading.Event()

        def _store_chunk(self, peer, key, chunk):
            if key[0] == 2 and not self.mid_placement.is_set():
                self.mid_placement.set()
                assert self.gate.wait(10)
            super()._store_chunk(peer, key, chunk)

    ps = Stalled(range(8))
    ps.submit(1, _state(1.0), {"ok": 1})
    t = threading.Thread(target=lambda: ps.submit(2, _state(2.0)), daemon=True)
    t.start()
    assert ps.mid_placement.wait(10)
    # submit 2 is mid-placement and will hold there until gated onward
    got = ps.load(_tmpl())
    assert got is not None and got[0] == 1 and got[2]["ok"] == 1
    assert t.is_alive()  # the submit really was still in flight
    Stalled.gate.set()
    t.join(10)
    assert ps.load(_tmpl())[0] == 2


# ---------------------------------------------------------------------------
# digest verification (the fused checksum kernel path)
# ---------------------------------------------------------------------------


def test_tree_digests_catch_chunk_local_corruption():
    """The old global abs-sum averaged a big tree's corruption away; the
    per-chunk digest localizes it. Chunk size 128 floats -> the two trees
    differ in exactly one digest row."""
    a = {"w": np.ones(1024, np.float32)}
    b = {"w": np.ones(1024, np.float32)}
    b["w"][700] += 1e-3
    da = tree_digests(a, chunk_elems=128)
    db = tree_digests(b, chunk_elems=128)
    assert da.shape == (8, 2)
    differing = np.any(np.abs(da - db) > 0, axis=1)
    assert differing.sum() == 1 and differing[700 // 128]
    assert not verify_tree(a, b, chunk_elems=128)
    assert verify_tree(a, {"w": np.ones(1024, np.float32)}, chunk_elems=128)


def test_tree_digests_sign_column_catches_compensating_flips():
    """Two opposite-sign flips keep the abs-sum column constant; the plain
    sum column moves."""
    a = {"w": np.arange(1.0, 9.0, dtype=np.float32)}
    b = {"w": a["w"].copy()}
    b["w"][1] *= -1.0
    b["w"][2] *= -1.0
    assert not verify_tree(a, b)


def test_verify_tree_empty_and_scalar_trees():
    assert verify_tree({}, {})
    assert not verify_tree({}, {"x": np.ones(2)})  # shape mismatch
    assert verify_tree({"s": np.float32(2.0)}, {"s": np.float32(2.0)})
    assert not verify_tree({"s": np.float32(2.0)}, {"s": np.float32(3.0)})


def test_verify_tree_all_empty_leaves():
    """Leaves can be zero-size arrays: the digest stream is then empty and
    verification must not crash (0 chunks, trivially equal)."""
    a = {"x": np.zeros((0,), np.float32)}
    assert tree_digests(a).shape == (0, 2)
    assert verify_tree(a, {"x": np.zeros((0,), np.float32)})
