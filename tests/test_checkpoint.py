"""DurableStore (level 2): roundtrip, double-buffered async publish,
keep-based GC, atomicity, crash consistency (stale ``.tmp-*`` debris
from a writer that died mid-checkpoint), the torn-newest restore walk,
and the drop/trim-vs-in-flight-writer race. On-disk delta chains live in
``test_durable_delta.py``."""
import json
import os
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.store import DurableStore


def _state(v: float):
    return {
        "params": {"w": jnp.full((16, 16), v), "b": jnp.arange(4.0)},
        "opt": {"mu": jnp.full((16, 16), v / 2)},
    }


def test_roundtrip(tmp_path):
    ds = DurableStore(str(tmp_path))
    ds.submit_sync(5, _state(1.5), meta={"n_comp": 4})
    got = ds.load(_state(0.0))
    assert got is not None
    step, state, meta = got
    assert step == 5 and meta["n_comp"] == 4
    assert float(state["params"]["w"][0, 0]) == 1.5


def test_async_submit_and_latest(tmp_path):
    ds = DurableStore(str(tmp_path))
    ds.submit(1, _state(1.0))
    ds.submit(2, _state(2.0))
    ds.wait()
    step, state, _ = ds.load(_state(0.0))
    assert step == 2 and float(state["params"]["w"][0, 0]) == 2.0


def test_double_buffered_submits_overlap(tmp_path):
    """Up to ``buffers`` submits proceed without joining the previous
    write; load() drains them all."""
    ds = DurableStore(str(tmp_path), keep=4, buffers=2)
    for s in (1, 2, 3, 4):
        ds.submit(s, _state(float(s)))
    step, state, _ = ds.load(_state(0.0))
    assert step == 4 and float(state["params"]["w"][0, 0]) == 4.0
    assert ds.steps() == [1, 2, 3, 4]


def test_gc_keeps_newest(tmp_path):
    ds = DurableStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ds.submit_sync(s, _state(float(s)))
    assert ds.steps() == [3, 4]


def test_trim_and_drop(tmp_path):
    ds = DurableStore(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        ds.submit_sync(s, _state(float(s)))
    ds.drop(2)
    assert ds.steps() == [1, 3]
    ds.trim(1)
    assert ds.steps() == [3]


def test_restore_specific_step(tmp_path):
    ds = DurableStore(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        ds.submit_sync(s, _state(float(s)))
    step, state, _ = ds.load(_state(0.0), step=2)
    assert step == 2 and float(state["params"]["w"][0, 0]) == 2.0


def test_atomic_publish_no_partial(tmp_path):
    ds = DurableStore(str(tmp_path))
    ds.submit_sync(1, _state(1.0))
    names = os.listdir(str(tmp_path))
    assert all(not n.startswith(".tmp") for n in names)


def test_stale_tmp_gc_on_startup(tmp_path):
    """A writer that died between makedirs and rename leaves ``.tmp-<s>``;
    a fresh store GCs the debris and restores the newest VALID step."""
    ds = DurableStore(str(tmp_path))
    ds.submit_sync(3, _state(3.0))
    # simulate the mid-write crash: a half-written tmp dir for step 4
    crashed = os.path.join(str(tmp_path), ".tmp-4")
    os.makedirs(crashed)
    with open(os.path.join(crashed, "state.npz"), "w") as f:
        f.write("torn bytes")
    ds2 = DurableStore(str(tmp_path))  # the restart
    assert not any(n.startswith(".tmp") for n in os.listdir(str(tmp_path)))
    step, state, _ = ds2.load(_state(0.0))
    assert step == 3 and float(state["params"]["w"][0, 0]) == 3.0


def test_stale_tmp_gc_after_publish(tmp_path):
    """Debris is also swept by the post-publish GC, not only at startup."""
    ds = DurableStore(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), ".tmp-9"))
    ds.submit_sync(10, _state(1.0))
    assert not any(n.startswith(".tmp") for n in os.listdir(str(tmp_path)))


def test_manifest_contents(tmp_path):
    ds = DurableStore(str(tmp_path))
    ds.submit_sync(7, _state(1.0), meta={"n_comp": 2})
    with open(os.path.join(str(tmp_path), "step-0000000007", "manifest.json")) as f:
        man = json.load(f)
    assert man["step"] == 7 and man["meta"] == {"n_comp": 2}
    assert man["leaves"] == 3 and man["bytes"] > 0


# ---------------------------------------------------------------------------
# the torn-newest restore walk
# ---------------------------------------------------------------------------


def test_load_falls_back_past_torn_newest(tmp_path):
    """A torn NEWEST snapshot used to make load(step=None) return None,
    skipping the whole durable rung even though older intact step dirs
    could have served the restore; the walk must continue newest-first."""
    ds = DurableStore(str(tmp_path), keep=5)
    for s in (3, 5, 8):
        ds.submit_sync(s, _state(float(s)))
    # tear the newest: truncated npz (a disk that died mid-sector)
    with open(os.path.join(str(tmp_path), "step-0000000008", "state.npz"), "w") as f:
        f.write("torn bytes")
    got = ds.load(_state(0.0))
    assert got is not None, "torn newest must not mask older intact steps"
    step, state, _ = got
    assert step == 5 and float(state["params"]["w"][0, 0]) == 5.0
    # a missing manifest tears the dir just as hard
    os.remove(os.path.join(str(tmp_path), "step-0000000005", "manifest.json"))
    step, state, _ = ds.load(_state(0.0))
    assert step == 3 and float(state["params"]["w"][0, 0]) == 3.0
    # an explicitly requested torn step still reports None
    assert ds.load(_state(0.0), step=8) is None


def test_load_falls_back_past_schema_drifted_newest(tmp_path):
    """A newest dir whose leaves no longer match the restore template
    (schema drift) is torn FOR THIS RESTORE - it must fall back, not
    raise KeyError out of the whole durable rung."""
    ds = DurableStore(str(tmp_path), keep=5)
    ds.submit_sync(1, _state(1.0))
    ds.submit_sync(2, {"params": {"renamed": jnp.ones((4, 4))}})
    got = ds.load(_state(0.0))
    assert got is not None and got[0] == 1
    assert float(got[1]["params"]["w"][0, 0]) == 1.0


def test_bfloat16_leaves_roundtrip(tmp_path):
    """np.savez mangles non-native dtypes (bfloat16 -> void) - a bf16
    param snapshot used to submit fine and then fail every restore."""
    state = {"w": jnp.arange(64.0, dtype=jnp.bfloat16).reshape(8, 8),
             "b": jnp.ones(4)}
    ds = DurableStore(str(tmp_path))
    ds.submit_sync(1, state)
    got = ds.load(state)
    assert got is not None and got[0] == 1
    assert got[1]["w"].dtype == jnp.bfloat16
    assert np.array_equal(
        np.asarray(got[1]["w"]).view(np.uint8),
        np.asarray(state["w"]).view(np.uint8),
    )


# ---------------------------------------------------------------------------
# stray directory entries
# ---------------------------------------------------------------------------


def test_steps_skips_stray_step_entries(tmp_path):
    """Any non-numeric ``step-*`` entry (an operator's ``step-old.bak``)
    used to raise ValueError out of steps() and kill every restore walk."""
    ds = DurableStore(str(tmp_path))
    ds.submit_sync(4, _state(4.0))
    os.makedirs(os.path.join(str(tmp_path), "step-old.bak"))
    with open(os.path.join(str(tmp_path), "step-NOTES"), "w") as f:
        f.write("ops scratch")
    assert ds.steps() == [4]
    step, state, _ = ds.load(_state(0.0))
    assert step == 4 and float(state["params"]["w"][0, 0]) == 4.0
    # the stray entries survive GC untouched
    ds.submit_sync(5, _state(5.0))
    assert os.path.exists(os.path.join(str(tmp_path), "step-old.bak"))


# ---------------------------------------------------------------------------
# drop/trim vs in-flight writers
# ---------------------------------------------------------------------------


class _GatedStore(DurableStore):
    """Writers block until released - an event-gated slow disk."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.release = threading.Event()
        self.entered = threading.Event()

    def _write_prepared(self, job):
        self.entered.set()
        assert self.release.wait(timeout=30), "writer gate never released"
        super()._write_prepared(job)


def test_drop_cancels_inflight_writer(tmp_path):
    """Dropping a step whose background writer is still running used to
    let the writer republish the dir after the drop."""
    ds = _GatedStore(str(tmp_path))
    ds.submit(5, _state(5.0))
    assert ds.entered.wait(timeout=30)
    ds.drop(5)  # writer is mid-write: mark-cancelled, not republished
    ds.release.set()
    ds.wait()
    assert ds.steps() == []
    assert not os.path.exists(os.path.join(str(tmp_path), "step-0000000005"))


def test_trim_cancels_inflight_resubmit_writer(tmp_path):
    """Trim must also win against a writer resubmitting a step it is
    about to discard (replay recrossed the step while disk was slow)."""
    ds = _GatedStore(str(tmp_path), keep=5)
    ds.release.set()
    ds.submit_sync(1, _state(1.0))
    ds.submit_sync(2, _state(2.0))
    ds.release.clear()
    ds.entered.clear()
    ds.submit(1, _state(9.0))  # replay recrossed step 1; writer stalls
    assert ds.entered.wait(timeout=30)
    ds.trim(1)  # keeps only step 2: the in-flight step-1 write is void
    ds.release.set()
    ds.wait()
    assert ds.steps() == [2]
    assert not os.path.exists(os.path.join(str(tmp_path), "step-0000000001"))
