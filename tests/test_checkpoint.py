"""DurableStore (level 2): roundtrip, double-buffered async publish,
keep-based GC, atomicity, and crash consistency (stale ``.tmp-*`` debris
from a writer that died mid-checkpoint)."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.store import DurableStore


def _state(v: float):
    return {
        "params": {"w": jnp.full((16, 16), v), "b": jnp.arange(4.0)},
        "opt": {"mu": jnp.full((16, 16), v / 2)},
    }


def test_roundtrip(tmp_path):
    ds = DurableStore(str(tmp_path))
    ds.submit_sync(5, _state(1.5), meta={"n_comp": 4})
    got = ds.load(_state(0.0))
    assert got is not None
    step, state, meta = got
    assert step == 5 and meta["n_comp"] == 4
    assert float(state["params"]["w"][0, 0]) == 1.5


def test_async_submit_and_latest(tmp_path):
    ds = DurableStore(str(tmp_path))
    ds.submit(1, _state(1.0))
    ds.submit(2, _state(2.0))
    ds.wait()
    step, state, _ = ds.load(_state(0.0))
    assert step == 2 and float(state["params"]["w"][0, 0]) == 2.0


def test_double_buffered_submits_overlap(tmp_path):
    """Up to ``buffers`` submits proceed without joining the previous
    write; load() drains them all."""
    ds = DurableStore(str(tmp_path), keep=4, buffers=2)
    for s in (1, 2, 3, 4):
        ds.submit(s, _state(float(s)))
    step, state, _ = ds.load(_state(0.0))
    assert step == 4 and float(state["params"]["w"][0, 0]) == 4.0
    assert ds.steps() == [1, 2, 3, 4]


def test_gc_keeps_newest(tmp_path):
    ds = DurableStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ds.submit_sync(s, _state(float(s)))
    assert ds.steps() == [3, 4]


def test_trim_and_drop(tmp_path):
    ds = DurableStore(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        ds.submit_sync(s, _state(float(s)))
    ds.drop(2)
    assert ds.steps() == [1, 3]
    ds.trim(1)
    assert ds.steps() == [3]


def test_restore_specific_step(tmp_path):
    ds = DurableStore(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        ds.submit_sync(s, _state(float(s)))
    step, state, _ = ds.load(_state(0.0), step=2)
    assert step == 2 and float(state["params"]["w"][0, 0]) == 2.0


def test_atomic_publish_no_partial(tmp_path):
    ds = DurableStore(str(tmp_path))
    ds.submit_sync(1, _state(1.0))
    names = os.listdir(str(tmp_path))
    assert all(not n.startswith(".tmp") for n in names)


def test_stale_tmp_gc_on_startup(tmp_path):
    """A writer that died between makedirs and rename leaves ``.tmp-<s>``;
    a fresh store GCs the debris and restores the newest VALID step."""
    ds = DurableStore(str(tmp_path))
    ds.submit_sync(3, _state(3.0))
    # simulate the mid-write crash: a half-written tmp dir for step 4
    crashed = os.path.join(str(tmp_path), ".tmp-4")
    os.makedirs(crashed)
    with open(os.path.join(crashed, "state.npz"), "w") as f:
        f.write("torn bytes")
    ds2 = DurableStore(str(tmp_path))  # the restart
    assert not any(n.startswith(".tmp") for n in os.listdir(str(tmp_path)))
    step, state, _ = ds2.load(_state(0.0))
    assert step == 3 and float(state["params"]["w"][0, 0]) == 3.0


def test_stale_tmp_gc_after_publish(tmp_path):
    """Debris is also swept by the post-publish GC, not only at startup."""
    ds = DurableStore(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), ".tmp-9"))
    ds.submit_sync(10, _state(1.0))
    assert not any(n.startswith(".tmp") for n in os.listdir(str(tmp_path)))


def test_manifest_contents(tmp_path):
    ds = DurableStore(str(tmp_path))
    ds.submit_sync(7, _state(1.0), meta={"n_comp": 2})
    with open(os.path.join(str(tmp_path), "step-0000000007", "manifest.json")) as f:
        man = json.load(f)
    assert man["step"] == 7 and man["meta"] == {"n_comp": 2}
    assert man["leaves"] == 3 and man["bytes"] > 0
