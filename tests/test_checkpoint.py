"""Checkpointing: durable roundtrip, async publish, GC, partner store."""
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer, PartnerStore


def _state(v: float):
    return {
        "params": {"w": jnp.full((16, 16), v), "b": jnp.arange(4.0)},
        "opt": {"mu": jnp.full((16, 16), v / 2)},
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _state(1.5), meta={"n_comp": 4})
    got = ck.restore(_state(0.0))
    assert got is not None
    step, state, meta = got
    assert step == 5 and meta["n_comp"] == 4
    assert float(state["params"]["w"][0, 0]) == 1.5


def test_async_save_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(1, _state(1.0))
    ck.save_async(2, _state(2.0))
    ck.wait()
    step, state, _ = ck.restore(_state(0.0))
    assert step == 2 and float(state["params"]["w"][0, 0]) == 2.0


def test_gc_keeps_newest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(float(s)))
    assert ck.list_steps() == [3, 4]


def test_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        ck.save(s, _state(float(s)))
    step, state, _ = ck.restore(_state(0.0), step=2)
    assert step == 2 and float(state["params"]["w"][0, 0]) == 2.0


def test_atomic_publish_no_partial(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(1.0))
    names = os.listdir(str(tmp_path))
    assert all(not n.startswith(".tmp") for n in names)


def test_partner_store():
    ps = PartnerStore()
    ps.save(0, 7, _state(3.0), {"k": 1})
    got = ps.restore(0, _state(0.0))
    assert got is not None and got[0] == 7
    assert float(got[1]["params"]["w"][0, 0]) == 3.0
    assert ps.latest_step() == 7
    ps.drop(0)
    assert ps.restore(0, _state(0.0)) is None
