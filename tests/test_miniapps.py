"""NAS mini-app analogues: correctness under every replication mode.

The apps' *verification* is the paper's correctness story: replication
must not change results (replicas mirror; collectives on COMM_CMP with
intercomm forward must equal the unreplicated answer)."""
import pytest

from conftest import run_subprocess


@pytest.mark.slow
def test_miniapps_verify_across_degrees():
    out = run_subprocess(
        """
        import jax, numpy as np, jax.numpy as jnp
        from repro.compat import set_mesh
        from repro.configs.base import ReplicationConfig
        from repro.core.replication import WorldState
        from repro.launch.mesh import make_mesh
        from repro.apps.miniapps import MINIAPPS

        mesh = make_mesh(8, 1)
        answers = {}
        for rdeg, mode in [(0.0, "paper"), (1.0, "paper"), (1.0, "fused")]:
            world = WorldState.create(8, rdeg)
            repl = ReplicationConfig(rdegree=rdeg, collective_mode=mode)
            with set_mesh(mesh):
                for name, make in MINIAPPS.items():
                    if name == "is" and world.topo.n_rep not in (0, world.topo.n_comp):
                        continue
                    fn, init, verify = make(mesh, world, repl)
                    out = fn(jnp.asarray(init))
                    assert verify(out), (name, rdeg, mode)
                    # scalar answers must MATCH across degrees (replication
                    # must not change results)
                    scal = np.asarray(out[-1] if isinstance(out, tuple) else out)
                    key = name
                    if key in answers and name == "ep":
                        pass  # EP's estimate depends on n_comp streams
                    elif key in answers and name in ("cg", "mg"):
                        # residuals depend on partition count; only compare
                        # same-n_comp runs
                        pass
        # replication-invariance on a fixed n_comp: run cg at r=0 with 4
        # slices vs r=1.0 with 8 slices (4 cmp + 4 rep): same partitioning
        w0 = WorldState.create(4, 0.0)
        w1 = WorldState.create(8, 1.0)
        from repro.apps.miniapps import make_cg
        with set_mesh(make_mesh(4, 1)):
            fn0, b0, _ = make_cg(make_mesh(4, 1), w0, ReplicationConfig())
            r0 = np.asarray(fn0(jnp.asarray(b0))[1])[0]
        with set_mesh(make_mesh(8, 1)):
            repl = ReplicationConfig(rdegree=1.0, collective_mode="paper")
            fn1, b1, _ = make_cg(make_mesh(8, 1), w1, repl)
            r1 = np.asarray(fn1(jnp.asarray(b1))[1])[0]
        assert abs(r0 - r1) < 1e-3 * max(1.0, abs(r0)), (r0, r1)
        print("MINIAPPS-OK")
        """
    )
    assert "MINIAPPS-OK" in out
