"""Launch-layer units: input specs, HLO collective parsing, sharding rules,
and (when present) the dry-run artifacts themselves."""
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, get_arch
from repro.core.replication import WorldState
from repro.dist.sharding import param_spec, cache_manual_specs
from repro.launch.specs import per_slice_batch, seq_layout
from repro.models import model as M


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------


def test_parse_collectives():
    from repro.launch import hlo_analysis as DR

    hlo = """
  %ar = f32[1024,512]{1,0} all-reduce(f32[1024,512]{1,0} %p0), replica_groups={}
  %ag.1 = bf16[256,64]{1,0} all-gather(bf16[16,64]{1,0} %p1), dimensions={0}
  %cp = f32[8]{0} collective-permute(f32[8]{0} %p2), source_target_pairs={{0,1}}
  %rs = f32[4,4]{1,0} reduce-scatter(f32[64,4]{1,0} %p3), dimensions={0}
  %a2a = s8[32,32]{1,0} all-to-all(s8[32,32]{1,0} %p4), dimensions={0}
  %ars = f32[2,2]{1,0} all-reduce-start(f32[2,2]{1,0} %p5)
"""
    out = DR.parse_collectives(hlo)
    assert out["all-reduce"]["count"] == 2
    assert out["all-reduce"]["bytes"] == 1024 * 512 * 4 + 2 * 2 * 4
    assert out["all-gather"]["bytes"] == 256 * 64 * 2  # result bytes
    assert out["reduce-scatter"]["bytes"] == 64 * 4 * 4  # operand bytes
    assert out["collective-permute"]["count"] == 1
    assert out["all-to-all"]["count"] == 1


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(ARCHS))
def test_param_specs_divisible_everywhere(name):
    """Every parameter of every FULL config must receive a jit-legal
    sharding on a 16-way model axis (the dry-run's hard requirement)."""
    cfg = get_arch(name)
    pshape = jax.eval_shape(lambda: M.init(jax.random.PRNGKey(0), cfg))
    flat, _ = jax.tree_util.tree_flatten_with_path(pshape)
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        spec = param_spec(path, leaf.shape, cfg, 16)
        for dim, s in zip(leaf.shape, tuple(spec)):
            names = s if isinstance(s, tuple) else ((s,) if s else ())
            if "model" in names:
                assert dim % 16 == 0, (path, leaf.shape, spec)


def test_cache_manual_specs_grouped():
    cfg = get_arch("gemma3-12b")
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, 16, max_len=2048, dtype=jnp.bfloat16)
    )
    specs = cache_manual_specs(cache, "data")
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    for kp, spec in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        # grouped local caches: (G, 5, B, S, KV, hd) -> batch at index 2
        if "local" in path:
            assert tuple(spec) == (None, None, "data", None, None, None), path
        elif path.endswith(("k", "v")):
            assert tuple(spec)[-4] == "data", (path, spec)


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------


def test_per_slice_batch_rules():
    w16 = WorldState.create(16, 0.0)
    assert per_slice_batch(SHAPES["train_4k"], w16) == (16, True)
    assert per_slice_batch(SHAPES["decode_32k"], w16) == (8, True)
    assert per_slice_batch(SHAPES["long_500k"], w16) == (1, False)  # replicate
    w_r = WorldState.create(16, 1.0)  # 8 comp
    per, shard = per_slice_batch(SHAPES["prefill_32k"], w_r)
    assert shard and per == 4


def test_seq_layouts():
    vlm = get_arch("qwen2-vl-2b")
    lay = seq_layout(vlm, SHAPES["train_4k"])
    assert lay["text"] + lay["patches"] == 4096
    enc = get_arch("seamless-m4t-medium")
    lay = seq_layout(enc, SHAPES["train_4k"])
    assert lay["text"] == lay["frames"] == 2048


# ---------------------------------------------------------------------------
# dry-run artifacts (when the sweep has produced them)
# ---------------------------------------------------------------------------

_DRY = sorted(
    glob.glob(os.path.join(os.path.dirname(__file__), "..", "runs", "dryrun*", "*.json"))
)


@pytest.mark.skipif(not _DRY, reason="no dry-run artifacts present")
def test_dryrun_artifacts_wellformed():
    ok = fail = skip = 0
    for path in _DRY:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            skip += 1
            assert "full-attention" in rec["skip_reason"]
            continue
        if not rec.get("ok"):
            fail += 1
            continue
        ok += 1
        rf = rec["roofline"]
        assert rf["compute_s"] >= 0 and rf["memory_s"] > 0
        assert rf["dominant"] in ("compute_s", "memory_s", "collective_s")
    assert ok > 0
    # the latest sweep must have no failures (old sweeps may retain some)
    latest = [p for p in _DRY if "dryrun_final" in p]
    if latest:
        bad = []
        for p in latest:
            with open(p) as f:
                rec = json.load(f)
            if not (rec.get("ok") or rec.get("skipped")):
                bad.append(os.path.basename(p))
        assert not bad, bad
