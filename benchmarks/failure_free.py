"""Fig. 8 analogue: failure-free replication overheads.

Runs the NAS mini-apps + an LM train step under the paper's replication
degrees {0, 6.25, 12.5, 25, 50, 100}% and reports per-iteration time vs
the rdegree=0 baseline. Executed in a subprocess with fake CPU devices so
the collectives are real (the overhead measured is the *structural* cost
of the replica-aware protocol: extra group collectives + intercomm hops).

At rdegree=0.5 (the paper's headline point) it additionally measures the
*snapshot path*'s failure-free overhead: a train step plus a per-
iteration L1 submit, synchronous whole-blob vs the ``repro.xfer``
striped + pipelined plane - the submit the recovery model charges every
step must not serialize behind the step.

Usage: ``python benchmarks/failure_free.py [mode] [--tiny]`` - ``--tiny``
runs rdegrees {0, 0.5} with fewer reps and no mini-apps (CI smoke).
Results also merge into the repo-root ``BENCH_perf.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

PAPER_RDEGREES = [0.0, 0.0625, 0.125, 0.25, 0.5, 1.0]

_CHILD = """
import os, sys, time, json
import jax, numpy as np, jax.numpy as jnp
from repro.compat import set_mesh
from repro.configs.base import ReplicationConfig, TrainConfig
from repro.configs.registry import smoke_config
from repro.core.replication import WorldState
from repro.core import data_plane as DP
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.optim.adamw import adamw
from repro.optim.schedules import constant
from repro.dist.sharding import param_shardings
from repro.data.pipeline import TokenPipeline
from repro.apps.miniapps import MINIAPPS
from repro.ft import FTSession
from repro.ft.miniapp import MiniAppProgram

N_SLICES = 8
REPS = int(os.environ.get("BENCH_REPS", "5"))
TINY = os.environ.get("BENCH_TINY", "0") == "1"
mode = os.environ.get("BENCH_MODE", "paper")
mesh = make_mesh(N_SLICES, 1)
results = []

def timeit(fn, *args, reps=REPS):
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts)

for rdeg in %(degrees)s:
    world = WorldState.create(N_SLICES, rdeg)
    repl = ReplicationConfig(rdegree=rdeg, collective_mode=mode)
    with set_mesh(mesh):
        # --- LM train step ---
        cfg = smoke_config("qwen2.5-3b")
        pipe = TokenPipeline(cfg, seq_len=64, per_slice_batch=2, seed=0)
        params = M.init(jax.random.PRNGKey(0), cfg)
        opt = adamw(constant(1e-3))
        pshard = param_shardings(params, mesh, cfg)
        params = jax.device_put(params, pshard)
        opt_state = opt.init(params)
        step = DP.build_train_step(cfg, TrainConfig(), repl, mesh, world, opt,
                                   donate=False)
        batch = jax.tree.map(jnp.asarray, pipe.global_batch(0, world))
        t = timeit(lambda b: step(params, opt_state, b)[2]["loss"], batch)
        results.append({"app": "lm_train", "rdegree": rdeg, "mode": mode,
                        "n_comp": world.topo.n_comp, "sec": t})
        # --- snapshot-path overhead at the paper's headline rdegree ------
        if rdeg == 0.5:
            from repro.store import PartnerMemoryStore, RecoveryLadder
            from repro.xfer import TransferPlane

            state = {"params": params, "opt": opt_state}
            for variant, lad, sub in (
                ("ckpt_sync",
                 RecoveryLadder([PartnerMemoryStore(range(N_SLICES),
                                                    coarse_lock=True)],
                                xfer=TransferPlane(pipeline=False)),
                 lambda l, i, s: l.submit(i, s, {})),
                ("ckpt_pipelined",
                 RecoveryLadder([PartnerMemoryStore(range(N_SLICES))]),
                 lambda l, i, s: l.submit_async(i, s, {})),
            ):
                out = step(params, opt_state, batch)  # warm
                jax.block_until_ready(out[2]["loss"])
                subs = []
                for i in range(max(REPS, 4)):
                    out = step(params, opt_state, batch)
                    jax.block_until_ready(out[2]["loss"])
                    t0 = time.perf_counter()
                    sub(lad, i, state)
                    subs.append(time.perf_counter() - t0)
                lad.drain()
                # the caller-blocking cost the snapshot path adds to each
                # iteration (the staging/placement of the pipelined path
                # overlaps the next step's XLA compute); median: step-time
                # jitter on shared CPU dwarfs the submit otherwise
                sub_s = float(np.median(subs))
                results.append({"app": "lm_train+" + variant, "rdegree": rdeg,
                                "mode": mode, "n_comp": world.topo.n_comp,
                                "sec": t + sub_s, "step_sec": t,
                                "submit_sec": sub_s})
            # durable snapshot path: per-iteration disk bytes, full
            # self-contained dirs vs on-disk delta chains (consecutive
            # failure-free submits of an unchanged state are the delta
            # plane's best case: everything ships as zero chunks)
            dd = os.environ.get("BENCH_DURABLE_DELTA", "none")
            if dd != "none":
                import tempfile
                from repro.store import DurableStore

                iters = max(REPS, 4)
                for variant, ds in (
                    ("ckpt_durable_full",
                     DurableStore(tempfile.mkdtemp(), keep=3)),
                    ("ckpt_durable_delta",
                     DurableStore(tempfile.mkdtemp(), keep=3, delta=dd)),
                ):
                    lad = RecoveryLadder([ds])
                    subs = []
                    for i in range(iters):
                        out = step(params, opt_state, batch)
                        jax.block_until_ready(out[2]["loss"])
                        t0 = time.perf_counter()
                        lad.submit_async(i, state, {})
                        subs.append(time.perf_counter() - t0)
                    lad.drain()
                    sub_s = float(np.median(subs))
                    results.append({"app": "lm_train+" + variant,
                                    "rdegree": rdeg, "mode": mode,
                                    "n_comp": world.topo.n_comp,
                                    "sec": t + sub_s, "step_sec": t,
                                    "submit_sec": sub_s,
                                    "bytes_written": ds.io_bytes_total,
                                    "bytes_per_iter": ds.io_bytes_total // iters})
        if TINY:
            continue
        # --- mini-apps, built + dispatched through the repro.ft session ---
        for name in MINIAPPS:
            if name == "is" and world.topo.n_rep not in (0, world.topo.n_comp):
                continue
            prog = MiniAppProgram(name, repl)
            FTSession(prog, n_slices=N_SLICES, rdegree=rdeg,
                      replay="none", unit="iter")
            t = timeit(lambda: prog.run_step(0))
            assert prog.verified(), name
            results.append({"app": name, "rdegree": rdeg, "mode": mode,
                            "n_comp": world.topo.n_comp, "sec": t})
print("RESULTS_JSON:" + json.dumps(results))
"""


def run(degrees=None, mode: str = "paper", reps: int = 5, tiny: bool = False,
        durable_delta: str = "none"):
    if tiny:
        degrees = degrees or [0.0, 0.5]
        reps = min(reps, 2)
    degrees = degrees or PAPER_RDEGREES
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env["BENCH_MODE"] = mode
    env["BENCH_REPS"] = str(reps)
    env["BENCH_TINY"] = "1" if tiny else "0"
    env["BENCH_DURABLE_DELTA"] = durable_delta
    code = textwrap.dedent(_CHILD % {"degrees": degrees})
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=3000,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS_JSON:")][0]
    return json.loads(line[len("RESULTS_JSON:"):])


def rows(results):
    """CSV rows: app,rdegree,us_per_call,overhead_vs_r0_pct (snapshot-path
    rows report overhead vs the bare step at the SAME rdegree instead)."""
    base = {
        r["app"]: r["sec"] for r in results if r["rdegree"] == 0.0
    }
    out = []
    for r in results:
        if "step_sec" in r:
            ov = (r["sec"] / r["step_sec"] - 1.0) * 100.0
            d = f"submit_overhead={ov:+.1f}%"
            if "bytes_per_iter" in r:
                d += f" bytes_per_iter={r['bytes_per_iter']}"
        else:
            ov = (r["sec"] / base[r["app"]] - 1.0) * 100.0 if r["app"] in base else 0.0
            d = f"overhead={ov:+.1f}%"
        out.append(
            (f"failure_free/{r['app']}/r{r['rdegree']:g}/{r['mode']}",
             r["sec"] * 1e6, d)
        )
    return out


if __name__ == "__main__":
    import sys as _s

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from perf_json import pop_durable_delta, update_perf_json

    argv = list(_s.argv[1:])
    dd = pop_durable_delta(argv)
    args = [a for a in argv if not a.startswith("--")]
    res = run(mode=args[0] if args else "paper", tiny="--tiny" in argv,
              durable_delta=dd)
    update_perf_json("failure_free", res)
    for name, us, d in rows(res):
        print(f"{name},{us:.0f},{d}")
