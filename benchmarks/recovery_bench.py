"""Recovery-cost benchmark over the ``repro.store`` ladder (the paper's
core motivation - "replication allows for fast recovery ... by simply
dropping the failed processes").

Measures, with real state sizes on the simulated cluster:

- promote path   : repair + communicator regen + re-lower (NO state motion)
- level-0 restore: LiveCloneStore submit + load (3-phase clone, O(memcpy))
- level-1 restore: PartnerMemoryStore K-way striped submit + load
- level-2 restore: DurableStore async write + load (disk roundtrip)
- l1-submit      : caller-blocking L1 submit, whole-blob synchronous (the
                   pre-xfer path: one global lock, no overlap) vs the
                   transfer plane's striped + pipelined path (the paper's
                   Sec. V message splitting; must be >= 2x faster)
- durable-delta  : (with ``--durable-delta bf16|int8``) bytes a close-
                   consecutive-submit cadence writes to disk, full
                   self-contained snapshots vs on-disk delta chains
                   (must shed >= 2x), plus the chain-restore cost and
                   its dirs-read bound
- pair-death     : BOTH members of a mirrored pair killed mid-run; recovery
                   must come from the striped level-1 redundancy (the
                   scenario the old single-partner copy could not survive)
- heal           : replica death + eager re-replication from a spare; the
                   recovery-window cost of the 3-phase verified clone +
                   chunk re-striping

Usage: ``python benchmarks/recovery_bench.py [--tiny]`` - ``--tiny`` runs
the CI smoke shape (4 slices, fewer steps). Results also merge into the
repo-root ``BENCH_perf.json`` (the cross-PR perf trajectory).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_CHILD = """
import json, time, tempfile
import jax, numpy as np
from repro.configs.registry import smoke_config
from repro.core.simulator import SimCluster
from repro.store import (DurableStore, LiveCloneStore, PartnerMemoryStore,
                         RecoveryLadder)
from repro.xfer import TransferPlane

TINY = {tiny}
N = 4 if TINY else 8
results = []
cfg = smoke_config("qwen2.5-3b")

# promote path: replication masks the failure, no state motion
sim = SimCluster(cfg, n_slices=N, model_shards=1, rdegree=1.0, seq_len=32)
sim.run(4, failures={{2: [0]}})
results.append({{"path": "promote", "restore_s": sim.report.handler_seconds,
                "replayed": sim.report.replayed_steps}})

# ladder levels, timed on the trainer's real state pytree
state = {{"params": sim.params_replica(),
         "opt": jax.tree.map(np.asarray, sim.opt_state)}}
template = jax.tree.map(np.zeros_like, state)
stores = [
    LiveCloneStore(),
    PartnerMemoryStore(range(N), redundancy=2),
    DurableStore(tempfile.mkdtemp()),
]
nbytes = int(sum(a.nbytes for a in jax.tree.leaves(state)))
for s in stores:
    s.submit(3, state, {{"step": 3}}); s.wait()  # warm (jit of the digest kernel)
    t0 = time.perf_counter(); s.submit(4, state, {{"step": 4}}); s.wait()
    submit_s = time.perf_counter() - t0
    t0 = time.perf_counter(); got = s.load(template)
    load_s = time.perf_counter() - t0
    assert got is not None and got[0] == 4
    results.append({{"path": f"level{{s.level}}/{{s.name}}",
                    "restore_s": load_s, "submit_s": submit_s,
                    "bytes": nbytes}})

# L1 submit acceptance: striped + pipelined must beat the whole-blob
# synchronous path (the pre-xfer behavior) by >= 2x on caller-blocking
# time - the device state stays referenced, so the pipelined submit
# returns after the O(1) mutable-leaf capture and the staging + striping
# overlap the next step. Submitted state is the trainer's REAL device
# state (what FTSession._checkpoint hands the ladder).
dev_state = {{"params": sim.params, "opt": sim.opt_state}}
reps = 3 if TINY else 6
sync = RecoveryLadder([PartnerMemoryStore(range(N), coarse_lock=True)],
                      xfer=TransferPlane(pipeline=False))
piped = RecoveryLadder([PartnerMemoryStore(range(N))])
timings = {{}}
for name, lad, sub in (
    ("whole_blob", sync, lambda l, i: l.submit(i, dev_state, {{}})),
    ("striped_pipelined", piped, lambda l, i: l.submit_async(i, dev_state, {{}})),
):
    ts = []
    for i in range(reps):
        t0 = time.perf_counter(); sub(lad, i); ts.append(time.perf_counter() - t0)
        # the trainer's cadence: a train step separates submits; the
        # double-buffered stager drains behind it (emulated at the cost
        # of one synchronous whole-blob submit, a LOWER bound on a step)
        if name == "striped_pipelined":
            time.sleep(timings["whole_blob"])
    t0 = time.perf_counter(); lad.drain()
    drain_s = time.perf_counter() - t0
    timings[name] = float(np.mean(ts))
    results.append({{"path": f"l1-submit/{{name}}", "restore_s": 0.0,
                    "submit_s": timings[name], "drain_s": drain_s,
                    "bytes": nbytes}})
speedup = timings["whole_blob"] / max(timings["striped_pipelined"], 1e-12)
assert speedup >= 2.0, f"striped+pipelined submit only {{speedup:.1f}}x faster"
results.append({{"path": "l1-submit/speedup", "restore_s": 0.0,
                "speedup": speedup}})

# durable delta chains: close consecutive submits (each tick perturbs a
# small slice of the real trainer state - the fine-cadence / sparse-update
# regime ReStore's sub-block reuse targets) written as full snapshots vs
# on-disk delta chains; the chain restore must stay byte-identical to the
# full-snapshot restore, read <= max_chain dirs, and shed >= 2x the bytes
DD = {durable_delta!r}
if DD != "none":
    from repro.store import flatten_with_paths
    from repro.xfer import TransferPlane

    wstate = jax.tree.map(np.array, state)  # writable host copies
    big = max(jax.tree.leaves(wstate), key=lambda a: a.nbytes)
    ticks = 6 if TINY else 10
    full_ds = DurableStore(tempfile.mkdtemp(), keep=ticks + 1)
    delta_ds = DurableStore(tempfile.mkdtemp(), keep=ticks + 1, delta=DD,
                            max_chain=4,
                            xfer=TransferPlane(chunk_bytes=64 * 1024))
    for i in range(ticks):
        big.reshape(-1)[i * 512 : (i + 1) * 512] += 1.0 / 64.0
        for ds in (full_ds, delta_ds):
            ds.submit(10 + i, wstate, {{"tick": i}})
    for ds in (full_ds, delta_ds):
        ds.wait()
    t0 = time.perf_counter(); got_full = full_ds.load(template)
    full_load_s = time.perf_counter() - t0
    t0 = time.perf_counter(); got_delta = delta_ds.load(template)
    delta_load_s = time.perf_counter() - t0
    assert got_full is not None and got_delta is not None
    assert got_full[0] == got_delta[0] == 10 + ticks - 1
    fb, db = flatten_with_paths(got_full[1]), flatten_with_paths(got_delta[1])
    assert set(fb) == set(db) and all(
        np.array_equal(fb[k], db[k]) for k in fb
    ), "delta-chain restore diverged from the full-snapshot restore"
    assert delta_ds.last_restore_dirs <= 4, delta_ds.last_restore_dirs
    ratio = full_ds.io_bytes_total / max(delta_ds.io_bytes_total, 1)
    assert ratio >= 2.0, f"durable delta chains only {{ratio:.1f}}x fewer bytes"
    results.append({{"path": "durable-delta/full", "restore_s": full_load_s,
                    "bytes_written": full_ds.io_bytes_total, "bytes": nbytes}})
    results.append({{"path": "durable-delta/delta", "restore_s": delta_load_s,
                    "bytes_written": delta_ds.io_bytes_total,
                    "restore_dirs": delta_ds.last_restore_dirs,
                    "bytes": nbytes}})
    results.append({{"path": "durable-delta/savings", "restore_s": 0.0,
                    "bytes_ratio": ratio}})

# restart path: unreplicated loss -> ladder restore + replay
sim2 = SimCluster(cfg, n_slices=N, model_shards=1, rdegree=0.0, seq_len=32,
                  checkpoint_dir=tempfile.mkdtemp(), checkpoint_every=2)
sim2.run(6, failures={{5: [N - 1]}})
results.append({{"path": "restart", "restore_s": sim2.report.handler_seconds,
                "replayed": sim2.report.replayed_steps,
                "restored_from": sim2.report.restored_from}})

# partner-pair double failure: cmp role 0 AND its replica die together;
# the K-way sharded level-1 store must serve the restore
sim3 = SimCluster(cfg, n_slices=N, model_shards=1, rdegree=1.0, seq_len=32,
                  checkpoint_every=2)
pair = [0, sim3.world.topo.n_comp]  # physicals of (cmp 0, its replica)
rep3 = sim3.run(6, failures={{3: pair}})
assert rep3.restarts == 1, "pair death must be unmaskable"
assert rep3.restored_from and rep3.restored_from[0].startswith("L1:partner"), (
    "pair death must restore from sharded partner redundancy: "
    + str(rep3.restored_from))
results.append({{"path": "pair-death", "restore_s": rep3.handler_seconds,
                "replayed": rep3.replayed_steps,
                "restored_from": rep3.restored_from}})

# heal path: a replica dies, the eager policy re-establishes the mirror
# from a spare inside the recovery window (3-phase verified clone +
# partner-ring re-registration + chunk re-striping)
sim4 = SimCluster(cfg, n_slices=N, model_shards=1, rdegree=1.0, seq_len=32,
                  spares=1, heal="eager", checkpoint_every=2)
rep4 = sim4.run(6, failures={{3: [sim4.world.topo.n_comp]}})  # replica of cmp 0
assert rep4.healed_replicas == 1, rep4.heals
xfer_s = sim4.session.last_heal.transfer.total_seconds
results.append({{"path": "heal", "restore_s": rep4.handler_seconds,
                "heal_clone_s": xfer_s, "healed": rep4.healed_replicas,
                "replaced_steps": sim4.session.last_heal.replaced_steps}})
print("RESULTS_JSON:" + json.dumps(results))
"""


def run(tiny: bool = False, durable_delta: str = "none"):
    env = dict(os.environ)
    n = 4 if tiny else 8
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    code = _CHILD.format(tiny=tiny, durable_delta=durable_delta)
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=2000,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS_JSON:")][0]
    return json.loads(line[len("RESULTS_JSON:"):])


def rows(results):
    out = []
    for r in results:
        extra = f"replayed={r.get('replayed', 0)}"
        if "restored_from" in r:
            extra += " from=" + ",".join(r["restored_from"] or ["-"])
        if "bytes" in r:
            extra = f"bytes={r['bytes']} submit_us={r.get('submit_s', 0) * 1e6:.0f}"
            if "drain_s" in r:
                extra += f" drain_us={r['drain_s'] * 1e6:.0f}"
        if "speedup" in r:
            extra = f"speedup={r['speedup']:.1f}x"
        if "bytes_written" in r:
            extra = f"bytes_written={r['bytes_written']}"
            if "restore_dirs" in r:
                extra += f" restore_dirs={r['restore_dirs']}"
        if "bytes_ratio" in r:
            extra = f"bytes_ratio={r['bytes_ratio']:.1f}x"
        if "heal_clone_s" in r:
            extra = (f"heal_clone_us={r['heal_clone_s'] * 1e6:.0f} "
                     f"healed={r['healed']} replaced={r['replaced_steps']}")
        out.append((f"recovery/{r['path']}", r["restore_s"] * 1e6, extra))
    return out


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from perf_json import pop_durable_delta, update_perf_json

    dd = pop_durable_delta(sys.argv)
    tiny = "--tiny" in sys.argv
    results = run(tiny=tiny, durable_delta=dd)
    update_perf_json("recovery", results)
    for name, us, d in rows(results):
        print(f"{name},{us:.0f},{d}")
