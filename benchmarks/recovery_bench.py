"""Recovery-cost benchmark: promote vs checkpoint/restart (the paper's
core motivation - "replication allows for fast recovery ... by simply
dropping the failed processes").

Measures, with real state sizes on the simulated cluster:
- promote path  : repair + communicator regen + re-lower (NO state motion)
- restart path  : repair + restore from partner/durable checkpoint + replay
- 3-phase clone : dynamic replica rebirth cost (state_transfer)
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_CHILD = """
import json, time, tempfile
import jax, numpy as np
from repro.configs.registry import smoke_config
from repro.core.simulator import SimCluster
from repro.core.state_transfer import HostState, clone_state

results = []
cfg = smoke_config("qwen2.5-3b")

# promote path
sim = SimCluster(cfg, n_slices=8, model_shards=1, rdegree=1.0, seq_len=32)
sim.run(4, failures={2: [0]})
results.append({"path": "promote", "handler_s": sim.report.handler_seconds,
                "replayed": sim.report.replayed_steps})

# restart path (no replicas -> partner-memory restore + replay)
sim2 = SimCluster(cfg, n_slices=8, model_shards=1, rdegree=0.0, seq_len=32,
                  checkpoint_dir=tempfile.mkdtemp(), checkpoint_every=2)
sim2.run(6, failures={5: [3]})
results.append({"path": "restart", "handler_s": sim2.report.handler_seconds,
                "replayed": sim2.report.replayed_steps})

# 3-phase clone (dynamic replica rebirth)
p = sim.params_replica()
o = jax.tree.map(np.asarray, sim.opt_state)
host = HostState(step=4, rng_seed=0, data_cursor=4, collective_seq=4, generation=0)
t0 = time.perf_counter()
_, _, _, rep = clone_state(p, o, host)
results.append({"path": "clone3phase", "handler_s": rep.total_seconds,
                "bytes": rep.total_bytes, "verified": rep.verified,
                "phases": rep.seconds_by_phase})
print("RESULTS_JSON:" + json.dumps(results))
"""


def run():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CHILD)],
        capture_output=True, text=True, env=env, timeout=2000,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS_JSON:")][0]
    return json.loads(line[len("RESULTS_JSON:"):])


def rows(results):
    out = []
    for r in results:
        extra = f"replayed={r.get('replayed', 0)}"
        if r["path"] == "clone3phase":
            extra = f"bytes={r.get('bytes', 0)} verified={r.get('verified')}"
        out.append((f"recovery/{r['path']}", r["handler_s"] * 1e6, extra))
    return out


if __name__ == "__main__":
    for name, us, d in rows(run()):
        print(f"{name},{us:.0f},{d}")
