"""repro.scrub benchmarks: scrub overhead + digest-guided partial restore.

Two questions the online SDC plane must answer with numbers:

1. What does continuous scrubbing COST? Per-step time with the in-step
   digest cross-check on vs off at rdegree 0.5 (the paper's headline
   replication setting) - the scrub rides the step's existing collectives,
   so the overhead should be a small fraction of a step.
2. What does digest-guided partial restore SAVE? A single bit flip right
   after a checkpoint poisons one chunk of one mirror; the repair should
   move only the differing chunks, not the whole blob
   (``FTReport.sdc_bytes_moved`` vs ``sdc_bytes_full``).

``--tiny`` runs the CI smoke shape (4 slices, short runs).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_CHILD = """
import json
from repro.configs.registry import smoke_config
from repro.core.fault_injector import SDCEvent, SDCSchedule
from repro.core.simulator import SimCluster

TINY = {tiny}
N = 4 if TINY else 8
STEPS = 6 if TINY else 12
cfg = smoke_config("qwen2.5-3b")
results = []

# --- 1. scrub overhead at rdegree 0.5 (check off vs on) -------------------
times = {{}}
for check in (False, True):
    sim = SimCluster(cfg, n_slices=N, model_shards=1, rdegree=0.5,
                     seq_len=32, sdc_check=check)
    rep = sim.run(STEPS)
    times[check] = rep.app_seconds / max(rep.steps_completed, 1)
results.append({{
    "case": "scrub-overhead/r0.5", "steps": STEPS,
    "us_off": times[False] * 1e6, "us_on": times[True] * 1e6,
    "overhead_frac": times[True] / times[False] - 1.0,
}})

# --- 2. partial vs full restore bytes on a single-chunk corruption --------
# sign-bit flip (the old checksum's provable blind spot) one step after a
# checkpoint: the update gate froze the step, so exactly one chunk of the
# victim's view differs from the submit and the repair moves only that
sim = SimCluster(cfg, n_slices=4, model_shards=1, rdegree=1.0, seq_len=32,
                 checkpoint_every=2, chunk_bytes=64 * 1024,
                 sdc_check=True, sdc_inject=True)
rep = sim.run(STEPS, sdc=SDCSchedule(
    [SDCEvent(step=3, victim=1, target="param", bit=31)]))
assert rep.sdc_detected == 1, rep.sdc_detected
assert rep.sdc_repairs == 1, rep.sdc_repairs
assert rep.sdc_bytes_full > 0
results.append({{
    "case": "partial-restore", "steps": STEPS,
    "detected": rep.sdc_detected, "repairs": rep.sdc_repairs,
    "restarts": rep.restarts,
    "moved_bytes": rep.sdc_bytes_moved, "full_bytes": rep.sdc_bytes_full,
    "moved_frac": rep.sdc_bytes_moved / rep.sdc_bytes_full,
    "handler_us": rep.handler_seconds * 1e6,
    "restored_from": rep.restored_from,
}})
print("RESULTS_JSON:" + json.dumps(results))
"""


def run(tiny: bool = False):
    env = dict(os.environ)
    n = 4 if tiny else 8
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CHILD.format(tiny=tiny))],
        capture_output=True, text=True, env=env, timeout=3000,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS_JSON:")][0]
    return json.loads(line[len("RESULTS_JSON:"):])


def rows(results):
    out = []
    for r in results:
        if r["case"] == "scrub-overhead/r0.5":
            out.append((
                "sdc/scrub-overhead/r0.5", r["us_on"],
                f"off={r['us_off']:.0f}us overhead=+{r['overhead_frac']:.1%}",
            ))
        else:
            out.append((
                "sdc/partial-restore", r["handler_us"],
                f"moved={r['moved_bytes']}/{r['full_bytes']}B "
                f"({r['moved_frac']:.1%}) repairs={r['repairs']} "
                f"restarts={r['restarts']}",
            ))
    return out


if __name__ == "__main__":
    results = run(tiny="--tiny" in sys.argv)
    from perf_json import update_perf_json

    update_perf_json("sdc", results)
    for name, us, d in rows(results):
        print(f"{name},{us:.0f},{d}")
