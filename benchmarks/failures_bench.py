"""Fig. 9(a) analogue: overheads under failures.

SimCluster runs REAL train steps with Weibull-scheduled failure injections
at several replication degrees, splitting total time into app time vs
error-handler time (repair + mesh rebuild + re-lower + replay) - the
paper's "most of the overheads ... are due to the error handler".

Uses the post-PR-2 store plane exclusively: SimCluster stacks the K-way
partner-memory level + durable level from ``checkpoint_dir`` /
``checkpoint_every`` (the old ``partner=`` / ``checkpointer=`` kwargs are
gone). ``--tiny`` runs the CI smoke shape (4 slices, one rdegree, one
trial).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_CHILD = """
import json, numpy as np
from repro.configs.registry import smoke_config
from repro.core.fault_injector import FaultInjector
from repro.core.simulator import SimCluster

TINY = {tiny}
N = 4 if TINY else 8
STEPS = 8 if TINY else 14
RDEGREES = [1.0] if TINY else [0.5, 1.0]
TRIALS = 1 if TINY else 2
results = []
for rdeg in RDEGREES:
    for trial in range(TRIALS):
        cfg = smoke_config("qwen2.5-3b")
        sim = SimCluster(cfg, n_slices=N, model_shards=1, rdegree=rdeg,
                         seq_len=32, checkpoint_dir=f"/tmp/ckpt_f{{rdeg}}_{{trial}}",
                         checkpoint_every=4)
        if TINY:
            # deterministic single promote-path failure: the smoke must
            # exercise the error handler, not depend on the Weibull draw
            failures = {{3: [0]}}
        else:
            inj = FaultInjector(N, scale=6.0, shape=0.7, seed=trial)
            events = inj.schedule(STEPS - 2, list(range(N)))
            failures = {{}}
            for t, victim in events[:3]:
                failures.setdefault(int(t) + 1, []).append(victim)
        rep = sim.run(STEPS, failures=failures)
        results.append({{
            "rdegree": rdeg, "trial": trial,
            "app_s": rep.app_seconds, "handler_s": rep.handler_seconds,
            "failures": rep.failures, "promotes": rep.promotes,
            "restarts": rep.restarts, "steps": rep.steps_completed,
            "final_loss": rep.losses[-1] if rep.losses else float("nan"),
        }})
print("RESULTS_JSON:" + json.dumps(results))
"""


def run(tiny: bool = False):
    env = dict(os.environ)
    n = 4 if tiny else 8
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CHILD.format(tiny=tiny))],
        capture_output=True, text=True, env=env, timeout=3000,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS_JSON:")][0]
    return json.loads(line[len("RESULTS_JSON:"):])


def rows(results):
    out = []
    for r in results:
        total = r["app_s"] + r["handler_s"]
        out.append(
            (
                f"failures/r{r['rdegree']:g}/t{r['trial']}",
                total / max(r["steps"], 1) * 1e6,
                f"handler_frac={r['handler_s']/max(total,1e-9):.2f} "
                f"promotes={r['promotes']} restarts={r['restarts']}",
            )
        )
    return out


if __name__ == "__main__":
    for name, us, d in rows(run(tiny="--tiny" in sys.argv)):
        print(f"{name},{us:.0f},{d}")
