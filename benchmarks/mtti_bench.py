"""Fig. 9(b) analogue: MTTI vs replication degree - plus the repro.heal
restored-replication view.

Two host-only (no devices) studies:

1. ``run``: the paper's Monte-Carlo MTTI table (Weibull inter-failure
   times, uniform victim choice) over the paper's rdegrees, with the
   Daly-optimal checkpoint-interval stretch - now with an extra column:
   the same topology given a spare pool + eager healing
   (``mtti_montecarlo_healed`` runs the real ``repair``/``heal`` algebra).

2. ``heal_trajectory``: the erosion picture the heal plane exists to fix.
   A deterministic schedule kills the current replica slices one at a
   time (the worst case for redundancy); after each repair the effective
   rdegree is recorded. With ``--heal none`` it decays monotonically to 0
   (PartRePer's Sec. VI shrink semantics); with ``--heal eager`` each
   kill is healed from the spare pool and rdegree returns to target until
   spares run out. ``time_at_risk`` integrates the replica deficit over
   events - the exposure a week-long job would accumulate.

Usage: ``python benchmarks/mtti_bench.py [--tiny] [--heal POLICY]``
(``--tiny`` is the CI smoke shape).
"""
from __future__ import annotations

import sys

from repro.core.mtti import daly_interval, mtti_montecarlo, mtti_montecarlo_healed
from repro.core.replication import ReplicaTopology, WorldState
from repro.heal.policy import HealPolicy

PAPER_RDEGREES = [0.0, 0.0625, 0.125, 0.25, 0.5, 1.0]


def run(n_comp: int = 256, system_scale: float = 10.0, shape: float = 0.7,
        trials: int = 800, checkpoint_cost: float = 1.0, n_spares: int = 0):
    """Holds nComp fixed and ADDS replicas (the paper's setup: 256 cmp +
    rDegree*256 replicas). With ``n_spares`` > 0 an extra ``mtti_healed``
    column prices eager re-replication from the spare pool."""
    results = []
    for r in PAPER_RDEGREES:
        n_rep = round(n_comp * r)
        topo = ReplicaTopology(n_comp=n_comp, replica_map=tuple(range(n_rep)))
        m = mtti_montecarlo(topo, system_scale, shape, trials=trials)
        rec = {
            "rdegree": r,
            "n_slices": topo.n_slices,
            "mtti": m,
            "tau_opt": daly_interval(m, checkpoint_cost),
        }
        if n_spares:
            rec["mtti_healed"] = mtti_montecarlo_healed(
                topo.n_slices + n_spares, r, n_spares=n_spares,
                policy="eager", system_scale=system_scale, shape=shape,
                trials=max(trials // 2, 100),
            )
        results.append(rec)
    base = results[0]["mtti"]
    for rec in results:
        rec["mtti_gain"] = rec["mtti"] / base
    return results


def heal_trajectory(n_slices: int = 8, rdegree: float = 1.0, n_spares: int = 2,
                    policy: str = "eager", events: int = 0):
    """Kill the replica slices one at a time; record the effective-rdegree
    trajectory and the accumulated time-at-risk (replica deficit summed
    over events). ``events`` defaults to nRep + spares (enough to drain
    redundancy AND the pool)."""
    pol = HealPolicy.parse(policy)
    world = WorldState.create(n_slices, rdegree, n_spares=n_spares)
    target = world.target_n_rep
    if not events:
        events = world.topo.n_rep + len(world.spares)
    traj = [{
        "event": 0, "victim": None, "rdegree": world.topo.rdegree,
        "n_rep": world.topo.n_rep, "deficit": world.replica_deficit(),
        "spares": len(world.spares), "healed": 0, "at_target": True,
    }]
    time_at_risk = 0
    for k in range(1, events + 1):
        reps = [world.assignment[r] for r in world.topo.rep_roles()]
        if not reps and world.topo.n_comp <= 1:
            break
        # kill the highest replica physical; once redundancy is gone, a
        # computational slice (the paper's interruption case)
        victim = max(reps) if reps else world.assignment[world.topo.n_comp - 1]
        world, rep = world.repair([victim], use_spares=pol.enabled)
        healed = 0
        if pol.wants_heal(world.replica_deficit()):
            world, plan = world.heal()
            healed = len(plan.actions)
        time_at_risk += world.replica_deficit()
        traj.append({
            "event": k, "victim": victim, "rdegree": world.topo.rdegree,
            "n_rep": world.topo.n_rep, "deficit": world.replica_deficit(),
            "spares": len(world.spares), "healed": healed,
            "at_target": world.topo.n_rep >= min(target, world.target_n_rep),
        })
    return {"policy": str(pol), "target_n_rep": target, "trajectory": traj,
            "time_at_risk": time_at_risk}


def rows(results):
    out = []
    for r in results:
        extra = f"gain={r['mtti_gain']:.2f}x tau={r['tau_opt']:.1f}"
        if "mtti_healed" in r:
            extra += f" healed_mtti={r['mtti_healed'] * 1e6:.0f}"
        out.append((f"mtti/r{r['rdegree']:g}", r["mtti"] * 1e6, extra))
    return out


def trajectory_rows(result):
    pol = result["policy"]
    out = []
    for t in result["trajectory"]:
        out.append((
            f"heal/{pol}/event{t['event']}",
            t["rdegree"] * 100,
            f"n_rep={t['n_rep']} deficit={t['deficit']} spares={t['spares']}"
            + (f" healed={t['healed']}" if t["healed"] else "")
            + (" AT-TARGET" if t["at_target"] else " BELOW-TARGET"),
        ))
    out.append((f"heal/{pol}/time_at_risk", result["time_at_risk"], "sum(deficit) over events"))
    return out


if __name__ == "__main__":
    tiny = "--tiny" in sys.argv
    policy = "eager"
    if "--heal" in sys.argv:
        i = sys.argv.index("--heal")
        if i + 1 >= len(sys.argv):
            sys.exit("--heal requires a value: none | eager | deferred:K")
        policy = sys.argv[i + 1]
        HealPolicy.parse(policy)  # fail fast on a bad spec
    if tiny:
        traj = heal_trajectory(n_slices=6, rdegree=1.0, n_spares=2, policy=policy)
        for name, v, d in trajectory_rows(traj):
            print(f"{name},{v:.0f},{d}")
        for name, us, d in rows(run(n_comp=16, trials=60, n_spares=4)):
            print(f"{name},{us:.0f},{d}")
    else:
        for name, us, d in rows(run(n_spares=32)):
            print(f"{name},{us:.0f},{d}")
        for pol in ("none", "eager", "deferred:2"):
            for name, v, d in trajectory_rows(
                heal_trajectory(n_slices=16, rdegree=1.0, n_spares=4, policy=pol)
            ):
                print(f"{name},{v:.0f},{d}")
