"""Fig. 9(b) analogue: MTTI vs replication degree.

Pure-host Monte-Carlo over the replica topology (no devices): Weibull
inter-failure times, uniform victim choice - the paper's injector. Run at
the paper's scale (256 computational slices) plus the production mesh
scale, and report the Daly-optimal checkpoint interval stretch.
"""
from __future__ import annotations

from repro.core.mtti import daly_interval, mtti_montecarlo
from repro.core.replication import ReplicaTopology

PAPER_RDEGREES = [0.0, 0.0625, 0.125, 0.25, 0.5, 1.0]


def run(n_comp: int = 256, system_scale: float = 10.0, shape: float = 0.7,
        trials: int = 800, checkpoint_cost: float = 1.0):
    """Holds nComp fixed and ADDS replicas (the paper's setup: 256 cmp +
    rDegree*256 replicas)."""
    results = []
    for r in PAPER_RDEGREES:
        n_rep = round(n_comp * r)
        topo = ReplicaTopology(n_comp=n_comp, replica_map=tuple(range(n_rep)))
        m = mtti_montecarlo(topo, system_scale, shape, trials=trials)
        results.append(
            {
                "rdegree": r,
                "n_slices": topo.n_slices,
                "mtti": m,
                "tau_opt": daly_interval(m, checkpoint_cost),
            }
        )
    base = results[0]["mtti"]
    for rec in results:
        rec["mtti_gain"] = rec["mtti"] / base
    return results


def rows(results):
    return [
        (
            f"mtti/r{r['rdegree']:g}",
            r["mtti"] * 1e6,
            f"gain={r['mtti_gain']:.2f}x tau={r['tau_opt']:.1f}",
        )
        for r in results
    ]


if __name__ == "__main__":
    for name, us, d in rows(run()):
        print(f"{name},{us:.0f},{d}")
