"""Benchmark harness - one suite per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
- failure_free : Fig. 8  (replication overheads, NAS mini-apps + LM)
- mtti         : Fig. 9b (MTTI vs replication degree)
- failures     : Fig. 9a (overheads under Weibull failures)
- recovery     : Sec. I/VI claims (promote vs restart vs 3-phase clone)
- roofline     : dry-run derived three-term roofline per (arch x shape)

``python -m benchmarks.run [suite ...]`` - default: all.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    wanted = sys.argv[1:] or ["mtti", "recovery", "failure_free", "failures", "roofline"]
    failures = 0
    for suite in wanted:
        try:
            if suite == "failure_free":
                from benchmarks import failure_free as m

                rows = m.rows(m.run(reps=3))
            elif suite == "mtti":
                from benchmarks import mtti_bench as m

                rows = m.rows(m.run(trials=400))
            elif suite == "failures":
                from benchmarks import failures_bench as m

                rows = m.rows(m.run())
            elif suite == "recovery":
                from benchmarks import recovery_bench as m

                rows = m.rows(m.run())
            elif suite == "roofline":
                from benchmarks import roofline as m

                rows = m.rows()
            else:
                print(f"unknown suite {suite}", file=sys.stderr)
                failures += 1
                continue
            for name, us, derived in rows:
                print(f"{name},{us:.0f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{suite},0,SUITE-ERROR {type(e).__name__}: {e}", file=sys.stderr)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
