"""Benchmark harness - one suite per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and merges every suite's raw
results into the repo-root ``BENCH_perf.json`` (the cross-PR perf
trajectory: failure-free overhead per rdegree, submit/restore/heal
timings, xfer contention/delta stats):

- failure_free : Fig. 8  (replication overheads, NAS mini-apps + LM,
                 plus the snapshot-path overhead at rdegree=0.5)
- mtti         : Fig. 9b (MTTI vs replication degree)
- failures     : Fig. 9a (overheads under Weibull failures)
- recovery     : Sec. I/VI claims (promote vs restart vs 3-phase clone,
                 whole-blob vs striped+pipelined L1 submit, heal window)
- xfer         : repro.xfer microbenchmarks (lock contention, pipelined
                 submit latency, delta bytes moved)
- roofline     : dry-run derived three-term roofline per (arch x shape)
- sdc          : repro.scrub (in-step digest scrub overhead at r0.5,
                 digest-guided partial-restore bytes vs the full blob)

``python -m benchmarks.run [suite ...]`` - default: all.
"""
from __future__ import annotations

import sys
import traceback

from benchmarks.perf_json import rows_payload, update_perf_json


def main() -> None:
    wanted = sys.argv[1:] or [
        "mtti", "recovery", "xfer", "failure_free", "failures", "roofline",
        "sdc",
    ]
    failures = 0
    for suite in wanted:
        try:
            results = None
            if suite == "failure_free":
                from benchmarks import failure_free as m

                results = m.run(reps=3)
                rows = m.rows(results)
            elif suite == "mtti":
                from benchmarks import mtti_bench as m

                results = m.run(trials=400)
                rows = m.rows(results)
            elif suite == "failures":
                from benchmarks import failures_bench as m

                results = m.run()
                rows = m.rows(results)
            elif suite == "recovery":
                from benchmarks import recovery_bench as m

                results = m.run()
                rows = m.rows(results)
            elif suite == "xfer":
                from benchmarks import xfer_bench as m

                results = m.run()
                rows = m.rows(results)
            elif suite == "roofline":
                from benchmarks import roofline as m

                rows = m.rows()
            elif suite == "sdc":
                from benchmarks import sdc_bench as m

                results = m.run()
                rows = m.rows(results)
            else:
                print(f"unknown suite {suite}", file=sys.stderr)
                failures += 1
                continue
            update_perf_json(
                suite, results if results is not None else rows_payload(rows)
            )
            for name, us, derived in rows:
                print(f"{name},{us:.0f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{suite},0,SUITE-ERROR {type(e).__name__}: {e}", file=sys.stderr)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
