"""repro.xfer microbenchmarks: lock contention, striping, delta.

Host-only (no devices, no subprocess), three sections:

- **contention** - the satellite fix made concrete: a writer thread
  submits continuously while the main thread samples ``load`` latency.
  Under the old whole-blob global lock (``coarse_lock=True``) every load
  waits out a full blob placement; under per-chunk placement the metadata
  critical sections are O(1) and loads proceed.
- **submit** - caller-blocking submit latency: synchronous whole-blob vs
  the plane's striped + double-buffered pipelined path.
- **delta** - bytes moved for close consecutive submits under
  none/bf16/int8 encoding (verified-exact; restores bit-identical).

Usage: ``python benchmarks/xfer_bench.py [--tiny]``.
"""
from __future__ import annotations

import os
import sys
import threading
import time

import numpy as np

from repro.store import PartnerMemoryStore, RecoveryLadder, flatten_with_paths
from repro.xfer import TransferPlane


def _blob(mb: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = mb * (1 << 20) // 8 // 4
    return {f"layer{i}/w": rng.standard_normal(n) for i in range(4)}


def _load_latency_under_writer(store, blobs, template, seconds: float):
    """Sample load() latency while a writer thread submits continuously
    (alternating between two slightly-different blobs, so per-chunk delta
    comparison/encoding - the byte-level work of a real submit - runs on
    every placement)."""
    stop = threading.Event()

    def writer():
        step = 0
        while not stop.is_set():
            store.submit_blob(step, blobs[step % len(blobs)], {})
            step += 1

    t = threading.Thread(target=writer, daemon=True)
    store.submit_blob(-1, blobs[0], {})  # something to load from the start
    t.start()
    lats = []
    t_end = time.perf_counter() + seconds
    while time.perf_counter() < t_end:
        t0 = time.perf_counter()
        got = store.load(template)
        lats.append(time.perf_counter() - t0)
        assert got is not None
    stop.set()
    t.join()
    return lats


def run(tiny: bool = False):
    import jax.numpy as jnp

    mb = 4 if tiny else 32
    seconds = 0.5 if tiny else 2.0
    reps = 4 if tiny else 8
    blob = {k: v.astype(np.float32) for k, v in _blob(mb).items()}
    template = {k: np.zeros_like(v) for k, v in blob.items()}
    results = {}

    # --- contention: whole-blob global lock vs per-chunk placement ----------
    # the writer's per-submit byte work (delta compare/encode) runs inside
    # the global lock under ``coarse_lock`` and outside it when placement
    # is per-chunk - concurrent load latency shows the difference
    blob_b = dict(blob)
    first = sorted(blob_b)[0]
    blob_b[first] = blob_b[first] + np.float32(0.5)
    for mode, coarse in (("coarse_lock", True), ("fine_grained", False)):
        store = PartnerMemoryStore(
            range(8), redundancy=2, keep=2, coarse_lock=coarse,
            xfer=TransferPlane(delta="bf16", pipeline=False),
        )
        lats = _load_latency_under_writer(store, [blob, blob_b], template, seconds)
        results[f"contention/{mode}"] = {
            "loads": len(lats),
            "load_p50_us": float(np.percentile(lats, 50) * 1e6),
            "load_max_us": float(np.max(lats) * 1e6),
        }

    # --- caller-blocking submit: whole-blob sync vs striped+pipelined ------
    # state leaves are device-resident (what a trainer submits): the
    # pipelined path returns after the O(1) mutable-leaf capture and
    # stages/places behind the caller's next step (emulated by a sleep of
    # one synchronous submit - a lower bound on a real train step)
    state = {k: jnp.asarray(v) for k, v in blob.items()}
    sync = RecoveryLadder(
        [PartnerMemoryStore(range(8), coarse_lock=True)],
        xfer=TransferPlane(pipeline=False),
    )
    piped = RecoveryLadder([PartnerMemoryStore(range(8))])
    sync_mean = 0.0
    for name, ladder, submit in (
        ("whole_blob_sync", sync, lambda l, i: l.submit(i, state, {})),
        ("striped_pipelined", piped, lambda l, i: l.submit_async(i, state, {})),
    ):
        ts = []
        for i in range(reps):
            t0 = time.perf_counter()
            submit(ladder, i)
            ts.append(time.perf_counter() - t0)
            if name == "striped_pipelined":
                time.sleep(sync_mean)
        t0 = time.perf_counter()
        ladder.drain()
        if name == "whole_blob_sync":
            sync_mean = float(np.mean(ts))
        results[f"submit/{name}"] = {
            "submit_us": float(np.mean(ts) * 1e6),
            "drain_us": float((time.perf_counter() - t0) * 1e6),
        }

    # --- delta encoding: bytes moved between close submits ------------------
    for codec in ("none", "bf16", "int8"):
        plane = TransferPlane(delta=codec, pipeline=False)
        store = PartnerMemoryStore(range(8), xfer=plane)
        base = {k: v.astype(np.float32) for k, v in blob.items()}
        store.submit_blob(0, base, {})
        # a "close" next step: most leaves unchanged, one nudged by a
        # bf16-representable constant
        nxt = dict(base)
        nxt["layer0/w"] = base["layer0/w"] + np.float32(0.5)
        store.submit_blob(1, nxt, {})
        cb = store.last_chunked
        got = store.load({k: np.zeros_like(v) for k, v in nxt.items()})
        assert got is not None and got[0] == 1
        assert all(np.array_equal(got[1][k], nxt[k]) for k in nxt), codec
        results[f"delta/{codec}"] = {
            "total_bytes": cb.total_bytes,
            "moved_bytes": cb.moved_bytes,
            "saved_pct": round(100.0 * (1 - cb.moved_bytes / cb.total_bytes), 1),
        }
    return results


def rows(results):
    out = []
    for name, r in sorted(results.items()):
        if name.startswith("contention"):
            out.append((f"xfer/{name}", r["load_p50_us"],
                        f"load_max_us={r['load_max_us']:.0f} loads={r['loads']}"))
        elif name.startswith("submit"):
            out.append((f"xfer/{name}", r["submit_us"],
                        f"drain_us={r['drain_us']:.0f}"))
        else:
            out.append((f"xfer/{name}", 0.0,
                        f"moved={r['moved_bytes']} of={r['total_bytes']} "
                        f"saved={r['saved_pct']}%"))
    return out


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from perf_json import update_perf_json

    tiny = "--tiny" in sys.argv
    res = run(tiny=tiny)
    update_perf_json("xfer", res)
    for name, us, d in rows(res):
        print(f"{name},{us:.0f},{d}")
