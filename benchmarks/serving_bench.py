"""Serving-gateway benchmark: request throughput and tail TTFT, with and
without a mid-stream kill, against the no-gateway fixed-batch baseline.

Three scenarios over the same synthetic open-loop workload (mixed prompt
lengths and generation budgets, staggered arrivals):

- ``gateway/failure-free``: continuous batching - slots free at
  EOS/max-new and refill from the admission queue mid-decode;
- ``gateway/mid-kill``: an UNmirrored serving slice dies mid-decode; its
  in-flight requests requeue at the queue front with streamed prefixes
  pinned and a spare backfills the role. Every client stream must stay
  byte-identical to the failure-free run (asserted), and the p99 TTFT
  across the kill is the row CI floors;
- ``baseline/fixed-batch``: the no-gateway discipline - admit a wave of
  requests, decode until the LAST one finishes, only then admit the next
  wave (what ``ServeEngine.decode``'s lockstep position forces).

The acceptance row ``gateway/speedup`` asserts continuous batching
completes the workload in no more serve steps than the fixed-batch
baseline (it should be strictly fewer whenever generation lengths vary).

The ``paging/*`` rows measure the paged decode state (pages ARE the
transfer chunks) against the dense whole-tree layout at rdegree=0.5:

- ``paging/snapshot-bytes``: bytes actually shipped per cadence tick,
  paged vs dense - asserts a >=5x reduction AND that a mid-decode kill +
  snapshot restore on the paged layout stays bit-identical to the dense
  failure-free oracle;
- ``paging/capacity``: host bytes the store retains per snapshot - the
  max-concurrent-requests multiplier at fixed host memory;
- ``paging/heal-warm``: bytes moved warming a spare-backfilled role
  (live pages only) vs the dense full-row copy;
- ``paging/prefix-dedupe``: sealed-page references served per distinct
  shared prompt-prefix page for a same-prompt cohort.

Usage: ``python benchmarks/serving_bench.py [--tiny]`` - ``--tiny`` is
the CI smoke shape. Results merge into the repo-root ``BENCH_perf.json``
under ``suites["serving"]``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_CHILD = """
import json, time
import numpy as np
from repro.configs.registry import smoke_config
from repro.serving.engine import ServeEngine
from repro.serving.gateway import ServeGateway

TINY = {tiny}
N = 3 if TINY else 4          # serving slices (all cmp: rdegree=0)
R = 12 if TINY else 32        # requests
MAXNEW = 6 if TINY else 10
KILL = 5 if TINY else 8       # serve step of the mid-stream kill
cfg = smoke_config("qwen2.5-3b")
results = []

def workload(gw):
    rng = np.random.default_rng(0)
    return [
        gw.submit(rng.integers(1, cfg.vocab_size, size=2 + i % 4),
                  max_new=2 + (i * 5) % MAXNEW, at_step=i // 4)
        for i in range(R)
    ]

def mk_gateway(page_tokens=128):
    eng = ServeEngine(cfg, n_slices=N, model_shards=1, rdegree=0.0,
                      spares=1, heal="eager", max_len=64,
                      slot_granular=True, page_tokens=page_tokens)
    return ServeGateway(eng, max_queue=2 * R)

def stats(gw, wall):
    s = gw.summary()
    return {{"req_s": s["completed"] / wall, "steps": s["steps"],
            "completed": s["completed"], "requeues": s["requeues"],
            "tok_s": s["tokens_decoded"] / wall,
            "ttft_p50_steps": s["ttft_p50_steps"],
            "ttft_p99_steps": s["ttft_p99_steps"]}}

# --- gateway, failure-free ---------------------------------------------------
gw0 = mk_gateway(); streams0 = workload(gw0)
t0 = time.perf_counter(); gw0.serve(max_steps=100_000)
wall0 = time.perf_counter() - t0
assert all(s.done for s in streams0)
row0 = stats(gw0, wall0)
results.append({{"path": "gateway/failure-free", **row0}})

# --- gateway, unmirrored kill mid-decode ------------------------------------
gw1 = mk_gateway(); streams1 = workload(gw1)
t0 = time.perf_counter(); gw1.serve(max_steps=100_000, failures={{KILL: [1]}})
wall1 = time.perf_counter() - t0
row1 = stats(gw1, wall1)
bit_identical = all(
    b.done and a.tokens == b.tokens for a, b in zip(streams0, streams1)
)
assert bit_identical, "client streams diverged across the kill"
assert row1["requeues"] >= 1, "the kill must have requeued in-flight work"
results.append({{"path": "gateway/mid-kill", **row1,
                "bit_identical": bit_identical}})

# --- no-gateway baseline: fixed-batch waves ----------------------------------
# same workload, admitted a full batch at a time; the wave only turns
# over when its LAST sequence finishes (lockstep decode discipline)
gwb = mk_gateway()
rng = np.random.default_rng(0)
reqs = [(rng.integers(1, cfg.vocab_size, size=2 + i % 4),
         2 + (i * 5) % MAXNEW) for i in range(R)]
B = gwb.registry.n_slots
t0 = time.perf_counter()
done_b = 0
for w in range(0, R, B):
    wave = [gwb.submit(p, max_new=m) for p, m in reqs[w : w + B]]
    gwb.serve(max_steps=100_000)
    done_b += sum(s.done for s in wave)
wallb = time.perf_counter() - t0
assert done_b == R
rowb = stats(gwb, wallb)
results.append({{"path": "baseline/fixed-batch", **rowb}})

steps_ratio = rowb["steps"] / max(row0["steps"], 1)
assert row0["steps"] <= rowb["steps"], (
    f"continuous batching took MORE steps than fixed waves: "
    f"{{row0['steps']}} > {{rowb['steps']}}"
)
results.append({{"path": "gateway/speedup", "steps_ratio": steps_ratio,
                "req_s_ratio": row0["req_s"] / max(rowb["req_s"], 1e-9)}})

# --- paged decode state: pages ARE the transfer chunks -----------------------
# lockstep engines with a snapshot cadence at rdegree=0.5 (2 cmp + 1 rep
# slices): count the bytes each cadence submit actually moves into the
# partner store, paged (page_tokens=4) vs dense (page_tokens=0)
SNAP_T = 10 if TINY else 16

def snap_run(pt, failures=None):
    eng = ServeEngine(cfg, n_slices=3, model_shards=1, rdegree=0.5,
                      max_len=64, snapshot_every=2, page_tokens=pt)
    store = eng.session.ladder.stores[0]
    acc = {{"moved": 0, "total": 0, "n": 0}}
    orig = store.submit_blob
    def counting(step, blob, meta=None):
        orig(step, blob, meta)
        cb = store.last_chunked
        acc["moved"] += cb.moved_bytes
        acc["total"] += cb.total_bytes
        acc["n"] += 1
    store.submit_blob = counting
    toks = eng.decode(SNAP_T, failures=failures)
    eng.session.ladder.drain()
    return eng, toks, acc

e_d, t_d, acc_d = snap_run(0)                        # dense oracle
e_p, t_p, acc_p = snap_run(4)                        # paged, failure-free
e_k, t_k, acc_k = snap_run(4, failures={{SNAP_T - 3: [1]}})  # paged + kill
ids = e_k._streams  # request streams that survived the loss
bit_identical = bool(
    np.array_equal(t_p, t_d) and np.array_equal(t_k, t_d[ids])
)
assert bit_identical, "paged decode diverged from the dense oracle"
dense_per_snap = acc_d["moved"] / max(acc_d["n"], 1)
paged_per_snap = acc_p["moved"] / max(acc_p["n"], 1)
reduction = dense_per_snap / max(paged_per_snap, 1.0)
assert reduction >= 5.0, (
    f"paged snapshots must ship >=5x fewer bytes: {{reduction:.2f}}x "
    f"({{dense_per_snap:.0f}} vs {{paged_per_snap:.0f}})"
)
results.append({{"path": "paging/snapshot-bytes",
                "dense_bytes_per_snap": dense_per_snap,
                "paged_bytes_per_snap": paged_per_snap,
                "reduction": reduction, "bit_identical": bit_identical}})

# host memory the store retains per snapshot = the max-concurrent-
# requests multiplier at fixed host memory
dense_host = acc_d["total"] / max(acc_d["n"], 1)
paged_host = acc_p["total"] / max(acc_p["n"], 1)
results.append({{"path": "paging/capacity",
                "dense_snap_host_bytes": dense_host,
                "paged_snap_host_bytes": paged_host,
                "max_concurrent_ratio": dense_host / max(paged_host, 1.0)}})

# heal warm-up: gw1's kill + eager heal backfilled a spare; the paged
# repack warmed its rows by moving live pages only
ek = gw1.engine
assert 0 < ek.heal_warm_bytes < ek.heal_warm_bytes_full, (
    ek.heal_warm_bytes, ek.heal_warm_bytes_full)
results.append({{"path": "paging/heal-warm",
                "paged_bytes": ek.heal_warm_bytes,
                "dense_bytes": ek.heal_warm_bytes_full,
                "saving_pct": round(100.0 * (1 - ek.heal_warm_bytes
                                             / ek.heal_warm_bytes_full), 1)}})

# prefix dedupe: a same-prompt cohort shares ONE sealed prompt page per
# leaf (page_tokens=8 so the 8-token prompt fills a page exactly)
gwp = mk_gateway(page_tokens=8)
PROMPT = list(range(11, 19))
for _ in range(4):
    gwp.submit(np.asarray(PROMPT), max_new=4)
t, dedupe = 0, 0.0
while gwp.pending() and t < 300:
    gwp.run_step(t); t += 1
    dedupe = max(dedupe, gwp.summary().get("prefix_dedupe_ratio", 0.0))
assert dedupe >= 2.0, dedupe
results.append({{"path": "paging/prefix-dedupe", "ratio": dedupe}})

print("RESULTS_JSON:" + json.dumps(results))
"""


def run(tiny: bool = False):
    env = dict(os.environ)
    n = (3 if tiny else 4) + 1  # slices + 1 spare
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    code = _CHILD.format(tiny=tiny)
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=2000,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS_JSON:")][0]
    return json.loads(line[len("RESULTS_JSON:"):])


def rows(results):
    out = []
    for r in results:
        if r["path"].startswith("paging/"):
            extra = " ".join(
                f"{k}={v:.2f}" if isinstance(v, float) else f"{k}={v}"
                for k, v in r.items() if k != "path"
            )
            out.append((f"serving/{r['path']}", 0.0, extra))
            continue
        if "steps_ratio" in r:
            extra = (f"steps_ratio={r['steps_ratio']:.2f}x "
                     f"req_s_ratio={r['req_s_ratio']:.2f}x")
            out.append((f"serving/{r['path']}", 0.0, extra))
            continue
        extra = (f"req_s={r['req_s']:.1f} steps={r['steps']} "
                 f"ttft_p99={r['ttft_p99_steps']:.0f}steps "
                 f"requeues={r['requeues']}")
        if "bit_identical" in r:
            extra += f" bit_identical={r['bit_identical']}"
        out.append((f"serving/{r['path']}", 1e6 / max(r["req_s"], 1e-9), extra))
    return out


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from perf_json import update_perf_json

    results = run(tiny="--tiny" in sys.argv)
    update_perf_json("serving", results)
    for name, us, d in rows(results):
        print(f"{name},{us:.0f},{d}")
