"""Chaos-matrix benchmarks: what gray failures COST the liveness layer.

Four scenarios, each priced against a failure-free reference run and
required to end bit-identical to it (recovery must not perturb the
trajectory):

- ``hang-detect``: a slice beats without progress; the stall detector
  convicts it within the suspicion window. Headline numbers: detection
  latency (ticks from injection to conviction - the FTHP-MPI timeout
  figure of merit) and ``stalled_units`` (how long the world was wedged
  before the conviction - the cost a report-driven detector never pays
  because it never fires).
- ``drop-detect``: heartbeats stop while the slice otherwise runs; pure
  silence conviction (the crash-shaped path).
- ``slow-quarantine``: a fail-slow peer left as sole holder of a dead
  pair's chunks is quarantined mid-restore within the rung deadline and
  the ladder falls L1 -> L2 instead of wedging the recovery window.
- ``flap``: a drop shorter than the window; the detector must soft-suspect
  and recover it at ZERO cost - no failures, no shrinks, no restarts (the
  false-positive guard: a wrong shrink is strictly worse than a flap).

``--tiny`` runs the CI smoke shape (6 slices, 6 steps).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_CHILD = """
import json, tempfile
import jax, numpy as np
from repro.configs.registry import smoke_config
from repro.core.simulator import SimCluster
from repro.store import DurableStore, PartnerMemoryStore, RecoveryLadder

TINY = {tiny}
STEPS = 6 if TINY else 10
WINDOW = 4.0
cfg = smoke_config("qwen2.5-3b")
results = []

def cluster(stores=None, rung_deadline=0.0, live=True):
    return SimCluster(
        cfg, n_slices=6, model_shards=1, rdegree=1.0, spares=2,
        heal="eager", seq_len=32, stores=stores,
        checkpoint_every=0 if stores is None else 2,
        suspicion_window=WINDOW if live else 0.0,
        rung_deadline_s=rung_deadline,
    )

ref = cluster(live=False)
ref_rep = ref.run(STEPS)
ref_leaves = jax.tree.leaves(ref.params_replica())

def bit_identical(sim, rep):
    diff = max(
        float(np.max(np.abs(a - b)))
        for a, b in zip(ref_leaves, jax.tree.leaves(sim.params_replica()))
    )
    return diff == 0.0 and rep.losses[-1] == ref_rep.losses[-1]

# --- hang: beat-without-progress, stall conviction -------------------------
sim = cluster()
rep = sim.run(STEPS, chaos="3:hang:3")
results.append({{
    "case": "hang-detect", "steps": STEPS, "window": WINDOW,
    "detections": rep.detections, "detect_latency": rep.detect_latency,
    "stalled_units": rep.stalled_units, "failures": rep.failures,
    "restarts": rep.restarts, "handler_us": rep.handler_seconds * 1e6,
    "bit_identical": bit_identical(sim, rep),
}})

# --- drop: pure-silence conviction -----------------------------------------
sim = cluster()
rep = sim.run(STEPS, chaos="1:drop:2")
results.append({{
    "case": "drop-detect", "steps": STEPS, "window": WINDOW,
    "detections": rep.detections, "detect_latency": rep.detect_latency,
    "failures": rep.failures, "restarts": rep.restarts,
    "handler_us": rep.handler_seconds * 1e6,
    "bit_identical": bit_identical(sim, rep),
}})

# --- fail-slow peer: quarantine mid-restore, L1 -> L2 fall-through ---------
ps = PartnerMemoryStore(range(6), redundancy=2)
ladder = RecoveryLadder(
    [ps, DurableStore(tempfile.mkdtemp())], rung_deadline_s=0.5)
sim = cluster(stores=ladder, rung_deadline=0.5)
rep = sim.run(STEPS, failures={{3: [0, 2]}}, chaos="2:slow:1")
results.append({{
    "case": "slow-quarantine", "steps": STEPS, "rung_deadline_s": 0.5,
    "quarantines": rep.quarantines, "restored_from": rep.restored_from,
    "l1_detail": ladder.attempts[0].detail, "restarts": rep.restarts,
    "handler_us": rep.handler_seconds * 1e6,
    "bit_identical": bit_identical(sim, rep),
}})

# --- flap: soft-suspect then recover, no shrink ----------------------------
sim = cluster()
rep = sim.run(STEPS, chaos="2:flap:1:3")
results.append({{
    "case": "flap", "steps": STEPS, "window": WINDOW,
    "flaps": rep.flaps, "failures": rep.failures, "restarts": rep.restarts,
    "promotes": rep.promotes, "detections": rep.detections,
    "bit_identical": bit_identical(sim, rep),
}})

print("RESULTS_JSON:" + json.dumps(results))
"""


def run(tiny: bool = False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CHILD.format(tiny=tiny))],
        capture_output=True, text=True, env=env, timeout=3000,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS_JSON:")][0]
    return json.loads(line[len("RESULTS_JSON:"):])


def rows(results):
    out = []
    for r in results:
        bit = "bitwise" if r["bit_identical"] else "DIVERGED"
        if r["case"] == "hang-detect":
            out.append((
                "chaos/hang-detect", r["handler_us"],
                f"latency={r['detect_latency'][0]:g}/window={r['window']:g} "
                f"wedged={r['stalled_units']}u {bit}",
            ))
        elif r["case"] == "drop-detect":
            out.append((
                "chaos/drop-detect", r["handler_us"],
                f"latency={r['detect_latency'][0]:g}/window={r['window']:g} "
                f"{bit}",
            ))
        elif r["case"] == "slow-quarantine":
            out.append((
                "chaos/slow-quarantine", r["handler_us"],
                f"quarantines={len(r['quarantines'])} "
                f"restored={r['restored_from'][0] if r['restored_from'] else '-'} "
                f"{bit}",
            ))
        else:
            out.append((
                "chaos/flap", 0.0,
                f"flaps={r['flaps']} failures={r['failures']} "
                f"restarts={r['restarts']} {bit}",
            ))
    return out


if __name__ == "__main__":
    results = run(tiny="--tiny" in sys.argv)
    from perf_json import update_perf_json

    update_perf_json("chaos", results)
    for name, us, d in rows(results):
        print(f"{name},{us:.0f},{d}")
