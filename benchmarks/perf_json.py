"""BENCH_perf.json - the repo-root perf-trajectory file.

Every benchmark suite merges its section here (atomic replace), so the
failure-free overhead per rdegree and the submit/restore/heal timings are
tracked across PRs: CI uploads the file as an artifact and a reviewer can
diff two runs without re-parsing CSV stdout.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
PATH = os.path.join(ROOT, "BENCH_perf.json")


def update_perf_json(section: str, payload: Any, path: str = PATH) -> str:
    """Merge ``payload`` under ``suites[section]`` (atomic rename)."""
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError):
            data = {}
    data.setdefault("suites", {})[section] = payload
    data["updated"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path


def rows_payload(rows) -> list:
    """The common ``(name, us, derived)`` row triple as JSON records."""
    return [{"name": n, "us": round(us, 1), "derived": d} for n, us, d in rows]


def pop_durable_delta(argv: list) -> str:
    """Consume ``--durable-delta <codec>`` from ``argv`` (shared by the
    benchmark mains; removed in place so positional args stay clean).
    Exits with a usage error on a missing or unknown codec."""
    import sys

    if "--durable-delta" not in argv:
        return "none"
    i = argv.index("--durable-delta")
    if i + 1 >= len(argv) or argv[i + 1] not in ("bf16", "int8"):
        sys.exit("--durable-delta needs a codec: bf16 | int8")
    dd = argv[i + 1]
    del argv[i : i + 2]
    return dd
