"""Roofline report: reads the dry-run artifacts (runs/dryrun/*.json) and
emits the per-(arch x shape x mesh) three-term table for EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

def _default_dir() -> str:
    root = os.path.join(os.path.dirname(__file__), "..", "runs")
    final = os.path.join(root, "dryrun_final")
    return final if os.path.isdir(final) else os.path.join(root, "dryrun")


DRYRUN_DIR = os.environ.get("DRYRUN_DIR", _default_dir())


def load(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs: List[Dict], mesh: str = "pod_16x16") -> List[Dict]:
    rows = []
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("skipped"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "skipped": r["skip_reason"]})
            continue
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "error": r.get("error", "?")})
            continue
        rf = r["roofline"]
        rows.append(
            {
                "arch": r["arch"],
                "shape": r["shape"],
                "compute_s": rf["compute_s"],
                "memory_s": rf["memory_s"],
                "collective_s": rf["collective_s"],
                "dominant": rf["dominant"],
                "useful_flop_ratio": rf["useful_flop_ratio"],
                "roofline_fraction": rf["roofline_fraction"],
                "peak_gb": r["scanned"]["memory"].get("peak_memory_in_bytes", 0)
                / 2**30,
            }
        )
    return rows


def rows(recs=None):
    recs = recs or load()
    out = []
    for row in table(recs):
        if "skipped" in row or "error" in row:
            out.append(
                (f"roofline/{row['arch']}/{row['shape']}", 0.0,
                 row.get("skipped") or ("ERROR " + str(row.get("error"))[:60]))
            )
            continue
        out.append(
            (
                f"roofline/{row['arch']}/{row['shape']}",
                row["compute_s"] * 1e6,
                f"dom={row['dominant'][:-2]} mem_s={row['memory_s']:.3f} "
                f"coll_s={row['collective_s']:.3f} "
                f"frac={row['roofline_fraction']:.3f}",
            )
        )
    return out


def markdown(recs=None, mesh: str = "pod_16x16") -> str:
    recs = recs or load()
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | useful FLOP ratio | roofline frac | peak GB/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for row in table(recs, mesh):
        if "skipped" in row:
            lines.append(
                f"| {row['arch']} | {row['shape']} | - | - | - | skipped | - | - | - |"
            )
            continue
        if "error" in row:
            lines.append(
                f"| {row['arch']} | {row['shape']} | - | - | - | ERROR | - | - | - |"
            )
            continue
        lines.append(
            f"| {row['arch']} | {row['shape']} | {row['compute_s']:.4f} | "
            f"{row['memory_s']:.4f} | {row['collective_s']:.4f} | "
            f"{row['dominant'][:-2]} | {row['useful_flop_ratio']:.2f} | "
            f"{row['roofline_fraction']:.3f} | {row['peak_gb']:.2f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    for name, us, d in rows():
        print(f"{name},{us:.0f},{d}")
