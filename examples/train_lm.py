"""End-to-end driver: train a ~100M-param LM with partial replication,
async checkpointing, and Weibull fault injection.

Default runs a ~2M-param model for 60 steps (CPU-friendly). ``--hundred-m``
selects a ~100M-param qwen2.5-family config and 300 steps - the full
e2e recipe (same code path, several hours on this 1-core container;
minutes on a real mesh).

    PYTHONPATH=src python examples/train_lm.py [--hundred-m] [--steps N]
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--hundred-m", action="store_true")
ap.add_argument("--steps", type=int, default=0)
ap.add_argument("--rdegree", type=float, default=0.5)
ap.add_argument("--inject", default="weibull", choices=["weibull", "none"])
args = ap.parse_args()

if os.environ.get("_REPRO_REEXEC") != "1":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["_REPRO_REEXEC"] = "1"
    os.execv(sys.executable, [sys.executable] + sys.argv)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import tempfile

from repro.configs.registry import get_arch, smoke_config
from repro.core.fault_injector import FaultInjector
from repro.core.simulator import SimCluster

if args.hundred_m:
    # ~100M params: qwen2.5 family, 8 layers, d=512, vocab 32k
    model = dataclasses.replace(
        get_arch("qwen2.5-3b"),
        name="qwen2.5-100m",
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=2,
        head_dim=64,
        d_ff=2048,
        vocab_size=32768,
        remat="none",
    )
    steps = args.steps or 300
    seq_len = 256
else:
    model = smoke_config("qwen2.5-3b")
    steps = args.steps or 60
    seq_len = 64

print(f"training {model.name}: {model.param_count()/1e6:.1f}M params, "
      f"{steps} steps, rdegree={args.rdegree}")

cluster = SimCluster(
    model,
    n_slices=4,
    model_shards=2,
    rdegree=args.rdegree,
    per_slice_batch=2,
    seq_len=seq_len,
    lr=3e-4,
    checkpoint_dir=tempfile.mkdtemp(prefix="ckpt_"),
    checkpoint_every=max(10, steps // 6),
)

failures = {}
if args.inject == "weibull":
    inj = FaultInjector(4, scale=steps / 2.5, shape=0.7, seed=1)
    for t, victim in inj.schedule(steps - 5, list(range(4)))[:2]:
        failures.setdefault(int(t) + 1, []).append(victim)
    print("scheduled failures:", failures)

report = cluster.run(steps, failures=failures)

for i in range(0, len(report.losses), max(1, len(report.losses) // 12)):
    print(f"step {i:4d}  loss {report.losses[i]:.4f}")
print(f"final loss {report.losses[-1]:.4f}")
for ev in report.events:
    print("EVENT:", ev)
print(
    f"steps={report.steps_completed} app={report.app_seconds:.1f}s "
    f"handler={report.handler_seconds:.1f}s promotes={report.promotes} "
    f"restarts={report.restarts} replayed={report.replayed_steps}"
)
assert report.losses[-1] < report.losses[0], "loss must decrease"
print("OK: loss decreased through failures")
