"""Batched serving with replica failover - engine and gateway.

Part 1 (engine): decodes a token stream for a batch of requests with 100%
replication, kills a serving slice mid-stream, and shows the promoted
replica continuing from its own KV cache - the token stream is
bit-identical to a failure-free run (asserted).

Part 2 (gateway): streams requests through repro.serving.gateway -
bounded admission, continuous batching (slots refill mid-decode as
sequences finish), and an UNmirrored kill whose in-flight requests
requeue at the front with their streamed prefixes pinned; after the spare
backfills, every client stream is byte-identical to the failure-free run
(asserted).

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-2.7b]
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2.5-3b")
ap.add_argument("--tokens", type=int, default=24)
args = ap.parse_args()

if os.environ.get("_REPRO_REEXEC") != "1":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["_REPRO_REEXEC"] = "1"
    os.execv(sys.executable, [sys.executable] + sys.argv)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.registry import smoke_config
from repro.serving.engine import ServeEngine

model = smoke_config(args.arch)

ref = ServeEngine(model, n_slices=4, model_shards=2, rdegree=1.0, max_len=64)
ref_tokens = ref.decode(args.tokens)

eng = ServeEngine(model, n_slices=4, model_shards=2, rdegree=1.0, max_len=64)
tokens = eng.decode(args.tokens, failures={args.tokens // 2: [0]})

print(f"decoded {tokens.shape[2]} tokens for "
      f"{tokens.shape[0] * tokens.shape[1]} requests")
for ev in eng.report.events:
    print("EVENT:", ev)
print("request 0 ids:", tokens[0, 0, :16].tolist())
same = np.array_equal(ref_tokens, tokens)
print(f"token stream identical to failure-free run: {same}")
assert same
print(
    f"promotes={eng.report.promotes} failover={eng.report.failover_seconds:.2f}s "
    f"decode={eng.report.decode_seconds:.2f}s"
)

# ---- part 2: the gateway ---------------------------------------------------
from repro.serving.gateway import ServeGateway


def serve(failures=None):
    e = ServeEngine(model, n_slices=3, model_shards=1, rdegree=0.0,
                    spares=1, heal="eager", max_len=64, slot_granular=True)
    gw = ServeGateway(e, max_queue=32)
    rng = np.random.default_rng(0)
    streams = [
        gw.submit(rng.integers(1, model.vocab_size, size=2 + i % 4),
                  max_new=6 + i % 5, at_step=i // 3)
        for i in range(10)
    ]
    gw.serve(max_steps=500, failures=failures)
    return gw, streams


base_gw, base_streams = serve()
gw, streams = serve(failures={5: [1]})  # unmirrored slice dies mid-decode

for ref_s, s in zip(base_streams, streams):
    assert s.done and s.tokens == ref_s.tokens, (s.rid, s.tokens)
s = gw.summary()
print(f"\ngateway: {s['completed']} requests served over {s['steps']} steps, "
      f"{s['requeues']} requeued across the kill, "
      f"ttft p99 {s['ttft_p99_steps']:.0f} steps")
print("every client stream byte-identical to the failure-free run: True")
