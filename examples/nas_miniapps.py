"""NAS mini-app analogues under replication (the paper's Sec. VII suite).

Runs EP / CG / MG / STENCIL / IS / PIC through the replica-aware
communicators at a chosen replication degree and verifies each app's
invariant.

    PYTHONPATH=src python examples/nas_miniapps.py [--rdegree 0.5] [--mode paper]
"""
import argparse
import os
import sys
import time

ap = argparse.ArgumentParser()
ap.add_argument("--rdegree", type=float, default=1.0)
ap.add_argument("--mode", default="paper", choices=["paper", "fused", "branch"])
args = ap.parse_args()

if os.environ.get("_REPRO_REEXEC") != "1":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["_REPRO_REEXEC"] = "1"
    os.execv(sys.executable, [sys.executable] + sys.argv)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.apps.miniapps import MINIAPPS
from repro.configs.base import ReplicationConfig
from repro.core.replication import WorldState
from repro.launch.mesh import make_mesh

mesh = make_mesh(8, 1)
world = WorldState.create(8, args.rdegree)
repl = ReplicationConfig(rdegree=args.rdegree, collective_mode=args.mode)
print(
    f"mesh 8x1, {world.topo.n_comp} computational + {world.topo.n_rep} "
    f"replica slices, mode={args.mode}"
)

with jax.set_mesh(mesh):
    for name, make in MINIAPPS.items():
        if name == "is" and world.topo.n_rep not in (0, world.topo.n_comp):
            print(f"{name:8s} SKIP (all_to_all needs equal communicator groups)")
            continue
        fn, init, verify = make(mesh, world, repl)
        x = jnp.asarray(init)
        out = fn(x)  # compile
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = fn(x)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) * 1e3
        print(f"{name:8s} {dt:8.2f} ms/iter  verified={verify(out)}")
