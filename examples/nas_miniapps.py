"""NAS mini-app analogues under replication (the paper's Sec. VII suite).

Runs EP / CG / MG / STENCIL / IS / PIC through the replica-aware
communicators at a chosen replication degree - each app wrapped as a
``repro.ft`` ResilientProgram, so failure injection recovers through the
same session error handler as the trainer and the server - and verifies
each app's invariant.

    PYTHONPATH=src python examples/nas_miniapps.py [--rdegree 0.5] \
        [--mode paper] [--inject-failure 1:0]
"""
import argparse
import os
import sys
import time

ap = argparse.ArgumentParser()
ap.add_argument("--rdegree", type=float, default=1.0)
ap.add_argument("--mode", default="paper", choices=["paper", "fused", "branch"])
ap.add_argument("--iters", type=int, default=3)
ap.add_argument("--inject-failure", default="",
                help="comma list of iter:physical_slice injections")
args = ap.parse_args()

if os.environ.get("_REPRO_REEXEC") != "1":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["_REPRO_REEXEC"] = "1"
    os.execv(sys.executable, [sys.executable] + sys.argv)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps.miniapps import MINIAPPS
from repro.configs.base import ReplicationConfig
from repro.core.replication import WorldState
from repro.ft import FailureSchedule, FTSession
from repro.ft.miniapp import MiniAppProgram

world = WorldState.create(8, args.rdegree)
repl = ReplicationConfig(rdegree=args.rdegree, collective_mode=args.mode)
print(
    f"mesh 8x1, {world.topo.n_comp} computational + {world.topo.n_rep} "
    f"replica slices, mode={args.mode}"
)

for name in MINIAPPS:
    if name == "is" and world.topo.n_rep not in (0, world.topo.n_comp):
        print(f"{name:8s} SKIP (all_to_all needs equal communicator groups)")
        continue
    # IS cannot rebuild over a shrunk (unbalanced) world for the same
    # uniform-groups reason, so it runs failure-free
    inject = None if name == "is" else FailureSchedule.parse(args.inject_failure)
    prog = MiniAppProgram(name, repl)
    session = FTSession(prog, n_slices=8, rdegree=args.rdegree,
                        replay="none", unit="iter")
    prog.run_step(0)  # compile outside the timed window
    t0 = time.perf_counter()
    session.run(args.iters, inject)
    dt = (time.perf_counter() - t0) * 1e3 / max(args.iters, 1)
    r = session.report
    print(
        f"{name:8s} {dt:8.2f} ms/iter  verified={prog.verified()}"
        f"  promotes={r.promotes} handler={r.handler_seconds*1e3:.1f}ms"
    )
    for ev in r.events:
        print("  EVENT:", ev)
