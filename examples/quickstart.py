"""Quickstart: fault-tolerant replicated training in ~40 lines.

Trains a tiny qwen2.5-family model on 4 mesh slices with 100% replication,
kills a computational slice mid-run, and shows the replica being promoted
with zero trajectory impact.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

if os.environ.get("_REPRO_REEXEC") != "1":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["_REPRO_REEXEC"] = "1"
    os.execv(sys.executable, [sys.executable] + sys.argv)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.registry import smoke_config
from repro.core.simulator import SimCluster

model = smoke_config("qwen2.5-3b")  # reduced same-family config for CPU

cluster = SimCluster(
    model,
    n_slices=4,       # 4 model-parallel slices on the data axis
    model_shards=2,   # 2-way tensor parallelism (GSPMD-managed)
    rdegree=1.0,      # 100% replication: 2 computational + 2 replica slices
    seq_len=64,
)
print(
    f"world: {cluster.world.topo.n_comp} computational + "
    f"{cluster.world.topo.n_rep} replica slices"
)

# kill physical slice 0 (a computational slice) before step 5
report = cluster.run(10, failures={5: [0]})

for i, loss in enumerate(report.losses):
    print(f"step {i:2d}  loss {loss:.4f}")
for ev in report.events:
    print("EVENT:", ev)
print(
    f"\npromotes={report.promotes} restarts={report.restarts} "
    f"error-handler={report.handler_seconds:.2f}s "
    f"(vs app {report.app_seconds:.2f}s)"
)
assert report.promotes == 1 and report.restarts == 0
print("recovered via replica promotion - no checkpoint restore needed")
