"""In-graph per-chunk state digests + deterministic bit-flip injection.

The scrub plane digests state INSIDE the compiled train step, so the
chunking here is deliberately different from ``repro.xfer.digest`` (which
streams the whole tree as one fp32 stream for host-side clone/heal
verification): each float leaf is padded out to a whole number of
``chunk_elems`` chunks, so a chunk never straddles two leaves and a
poisoned chunk names its leaf exactly (``chunk_leaf_map``).

Every chunk digests to an ``[abs-sum, sum]`` row. The pair of columns is
the sign-blindness fix: the old ``sum(x**2)`` scalar is invariant under
``x -> -x`` of any element, while here a sign flip moves the ``sum``
column by ``2|x|`` with the ``abs-sum`` column pinned - and a magnitude
flip moves both.

Injection is in-graph too: the corruption spec rides into the step as a
small traced int32 vector, so arming/disarming a flip never recompiles.
The flip itself is a bitcast-XOR on one element of one leaf, gated on the
slice index - exactly one mirror of a pair sees the poisoned value, which
is what RedMPI-style cross-replica comparison must catch.
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

#: scrub digest granularity (elements per chunk, per leaf)
SCRUB_CHUNK_ELEMS = 1 << 12

#: corruption-spec layout: [active, victim, target, leaf, elem, bit]
SPEC_LEN = 6
TARGET_GRAD = 0
TARGET_PARAM = 1

#: disarmed spec - constant-folds the injection branch away when closed over
NULL_SPEC = np.zeros((SPEC_LEN,), np.int32)


def encode_spec(victim: int, target, leaf: int, elem: int, bit: int) -> np.ndarray:
    """Armed corruption spec. ``target`` is ``"grad"``/``"param"`` or the
    integer code; ``victim`` is a mesh position (flat slice index)."""
    if isinstance(target, str):
        target = {"grad": TARGET_GRAD, "param": TARGET_PARAM}[target]
    return np.asarray([1, victim, int(target), leaf, elem, bit], np.int32)


def _digest_leaves(tree: PyTree) -> List[Tuple[int, Any]]:
    """(full-tree leaf index, leaf) for every non-empty float leaf, in
    ``jax.tree.leaves`` order - the leaf space both the digest matrix and
    the injection spec index into."""
    out = []
    for i, x in enumerate(jax.tree.leaves(tree)):
        if not hasattr(x, "dtype"):
            continue
        if not jnp.issubdtype(x.dtype, jnp.floating):
            continue
        if int(np.prod(x.shape)) == 0:
            continue
        out.append((i, x))
    return out


def n_scrub_chunks(tree: PyTree, chunk_elems: int = SCRUB_CHUNK_ELEMS) -> int:
    return sum(
        -(-int(np.prod(x.shape)) // chunk_elems) for _, x in _digest_leaves(tree)
    )


def chunk_leaf_map(tree: PyTree, chunk_elems: int = SCRUB_CHUNK_ELEMS) -> np.ndarray:
    """chunk row -> full-tree leaf index (chunks never straddle leaves)."""
    owners: List[int] = []
    for i, x in _digest_leaves(tree):
        owners += [i] * -(-int(np.prod(x.shape)) // chunk_elems)
    return np.asarray(owners, np.int64)


def leaf_digest_matrix(tree: PyTree,
                       chunk_elems: int = SCRUB_CHUNK_ELEMS) -> jnp.ndarray:
    """(n_chunks, 2) fp32 ``[abs-sum, sum]`` rows over per-leaf-padded
    chunks. Pure jnp - traceable inside the train step's shard_map and
    identical code host-side (the scrub plane's submit reference)."""
    rows = []
    for _, x in _digest_leaves(tree):
        flat = jnp.ravel(x).astype(jnp.float32)
        pad = (-flat.shape[0]) % chunk_elems
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
        m = flat.reshape(-1, chunk_elems)
        rows.append(
            jnp.stack([jnp.sum(jnp.abs(m), axis=1), jnp.sum(m, axis=1)], axis=1)
        )
    if not rows:
        return jnp.zeros((0, 2), jnp.float32)
    return jnp.concatenate(rows, axis=0)


def inject_bitflip(tree: PyTree, spec, idx, target: int) -> PyTree:
    """Flip bit ``spec[5]`` of element ``spec[4]`` of (float32) leaf
    ``spec[3]`` - only on the slice whose flat index ``idx`` equals
    ``spec[1]``, only when ``spec[0]`` is armed and ``spec[2]`` matches
    ``target`` (the call site's TARGET_GRAD/TARGET_PARAM).

    ``spec`` may be traced (armed/disarmed without recompiling) or the
    ``NULL_SPEC`` constant (XLA folds the whole branch away). Out-of-range
    leaf/elem/bit indices clamp rather than trap, so a fuzzing schedule
    can never crash the step.
    """
    active = (spec[0] != 0) & (idx == spec[1]) & (spec[2] == target)
    leaves = jax.tree.leaves(tree)
    treedef = jax.tree.structure(tree)
    out = []
    for i, x in enumerate(leaves):
        if (not hasattr(x, "dtype") or x.dtype != jnp.float32
                or int(np.prod(x.shape)) == 0):
            out.append(x)
            continue
        hit = active & (spec[3] == i)
        flat = jnp.ravel(x)
        elem = jnp.clip(spec[4], 0, flat.shape[0] - 1)
        bit = jnp.clip(spec[5], 0, 31)
        word = jax.lax.bitcast_convert_type(flat[elem], jnp.int32)
        flipped = jax.lax.bitcast_convert_type(
            word ^ (jnp.int32(1) << bit), jnp.float32
        )
        val = jnp.where(hit, flipped, flat[elem])
        out.append(flat.at[elem].set(val).reshape(x.shape))
    return jax.tree.unflatten(treedef, out)
