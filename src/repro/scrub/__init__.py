"""repro.scrub - online SDC scrubbing: detect -> vote -> partial restore.

Mirrored pairs compare per-chunk ``[abs-sum, sum]`` digests of gradients
and params inside every train step (detect), a mismatch is adjudicated by
a majority vote among >=3 digest holders (the pair, other live slices,
and the last submit's reference digests), and recovery reloads ONLY the
chunks whose digests disagree with the vote - digest-guided partial
restore through the RecoveryLadder.
"""
from repro.scrub.digest import (
    NULL_SPEC,
    SCRUB_CHUNK_ELEMS,
    SPEC_LEN,
    TARGET_GRAD,
    TARGET_PARAM,
    chunk_leaf_map,
    encode_spec,
    inject_bitflip,
    leaf_digest_matrix,
    n_scrub_chunks,
)
from repro.scrub.plane import ScrubPlane
from repro.scrub.vote import (
    ScrubEvidence,
    ScrubVerdict,
    majority_vote,
    mismatched_pairs,
    rows_differ,
)

__all__ = [
    "NULL_SPEC", "SCRUB_CHUNK_ELEMS", "SPEC_LEN", "TARGET_GRAD",
    "TARGET_PARAM", "chunk_leaf_map", "encode_spec", "inject_bitflip",
    "leaf_digest_matrix", "n_scrub_chunks", "ScrubPlane", "ScrubEvidence",
    "ScrubVerdict", "majority_vote", "mismatched_pairs", "rows_differ",
]
