"""Host-side scrub plane state: the last good submit's digest reference.

The in-step tables compare LIVE mirrors; this plane pins them against the
past - the param digests of the state that was last submitted to the
recovery ladder. It is the third digest holder the majority vote needs in
a two-slice world, and the "last known good" anchor the corruption
recovery rolls back to.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from repro.scrub.digest import SCRUB_CHUNK_ELEMS, leaf_digest_matrix

PyTree = Any


class ScrubPlane:
    """Records per-chunk digests of each ladder submit.

    ``tol`` is the absolute per-column slack for in-table comparisons
    (0.0: healthy mirrors are bit-identical); references recorded here
    are compared with an additional relative tolerance because the host
    and in-step compilations may associate the chunk sums differently.
    """

    def __init__(self, *, chunk_elems: int = SCRUB_CHUNK_ELEMS,
                 tol: float = 0.0):
        self.chunk_elems = int(chunk_elems)
        self.tol = float(tol)
        self._ref: Optional[np.ndarray] = None
        self._ref_step: Optional[int] = None

    def record_submit(self, step: int, tree: PyTree) -> np.ndarray:
        """Digest the just-submitted state; returns the (n_chunks, 2) rows."""
        ref = np.asarray(leaf_digest_matrix(tree, self.chunk_elems))
        self._ref = ref
        self._ref_step = int(step)
        return ref

    @property
    def reference(self) -> Optional[np.ndarray]:
        return self._ref

    @property
    def reference_step(self) -> Optional[int]:
        return self._ref_step

    def clear(self) -> None:
        self._ref = None
        self._ref_step = None
