"""Host-side scrub plane state: the last good submit's digest reference.

The in-step tables compare LIVE mirrors; this plane pins them against the
past - the param digests of the state that was last submitted to the
recovery ladder. It is the third digest holder the majority vote needs in
a two-slice world, and the "last known good" anchor the corruption
recovery rolls back to.
"""
from __future__ import annotations

import zlib
from typing import Any, Dict, Optional

import numpy as np

from repro.scrub.digest import SCRUB_CHUNK_ELEMS, leaf_digest_matrix
from repro.xfer.chunking import leaf_bytes

PyTree = Any


class ScrubPlane:
    """Records per-chunk digests of each ladder submit.

    ``tol`` is the absolute per-column slack for in-table comparisons
    (0.0: healthy mirrors are bit-identical); references recorded here
    are compared with an additional relative tolerance because the host
    and in-step compilations may associate the chunk sums differently.
    """

    def __init__(self, *, chunk_elems: int = SCRUB_CHUNK_ELEMS,
                 tol: float = 0.0):
        self.chunk_elems = int(chunk_elems)
        self.tol = float(tol)
        self._ref: Optional[np.ndarray] = None
        self._ref_step: Optional[int] = None
        self._page_ref: Optional[Dict[str, int]] = None
        self._page_ref_step: Optional[int] = None

    def record_submit(self, step: int, tree: PyTree) -> np.ndarray:
        """Digest the just-submitted state; returns the (n_chunks, 2) rows."""
        ref = np.asarray(leaf_digest_matrix(tree, self.chunk_elems))
        self._ref = ref
        self._ref_step = int(step)
        return ref

    def record_pages(self, step: int, pages: Dict[str, np.ndarray]
                     ) -> Dict[str, int]:
        """Fingerprint a PAGED submit: one crc32 per page key. Paged
        decode state is compared page-by-page (the page IS the chunk the
        ladder can splice back), so the reference is keyed, not a
        positional digest matrix. Keys that leave the page set between
        submits simply age out of the reference with them."""
        ref = {k: zlib.crc32(leaf_bytes(np.asarray(v)))
               for k, v in pages.items()}
        self._page_ref = ref
        self._page_ref_step = int(step)
        return ref

    @property
    def reference(self) -> Optional[np.ndarray]:
        return self._ref

    @property
    def reference_step(self) -> Optional[int]:
        return self._ref_step

    @property
    def page_reference(self) -> Optional[Dict[str, int]]:
        return self._page_ref

    @property
    def page_reference_step(self) -> Optional[int]:
        return self._page_ref_step

    def clear(self) -> None:
        self._ref = None
        self._ref_step = None
        self._page_ref = None
        self._page_ref_step = None
