"""Majority vote over per-slice digest tables: name the poisoned replica.

A pair mismatch only says "these two disagree" - RedMPI's dual-redundancy
blind spot. Naming the poisoned member needs a third opinion. The scrub
plane has two kinds:

- the OTHER live slices' rows of the same in-step table. Params are
  replicated, so every healthy slice's param-digest row is bit-identical
  (same compiled program, same array) - comparable with zero tolerance;
- the partner store's reference digests of the last good submit,
  recorded host-side by :class:`repro.scrub.plane.ScrubPlane`. Host and
  in-step compilations may associate the chunk reductions differently,
  so the reference is compared under a small relative tolerance and a
  live holder always outranks it.

The vote is conservative: a verdict needs a strict majority among the
holders that took a side, otherwise it is inconclusive and the caller
falls back to a full restore (corruption is never "probably fine").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np


def _rel_tol(a: np.ndarray, b: np.ndarray, rel: float) -> np.ndarray:
    return rel * np.maximum(1.0, np.maximum(np.abs(a), np.abs(b)))


def rows_differ(a: np.ndarray, b: np.ndarray, *, tol: float = 0.0,
                rel: float = 0.0) -> np.ndarray:
    """(n_chunks,) bool: chunks whose [abs-sum, sum] rows differ beyond
    ``tol`` (absolute) plus ``rel`` (relative, symmetric in a/b)."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    bound = tol + _rel_tol(a, b, rel)
    return np.any(np.abs(a - b) > bound, axis=-1)


@dataclass
class ScrubEvidence:
    """What the train step exported when a pair digest mismatched."""

    step: int
    sdc: float                      # global max |pair digest diff|
    grad_table: Optional[np.ndarray] = None   # (n_slices, n_chunks, 2)
    param_table: Optional[np.ndarray] = None  # by mesh position
    pairs: Tuple[Tuple[int, int], ...] = ()   # mesh-position mirror pairs


@dataclass
class ScrubVerdict:
    victim: Optional[int]           # mesh position, None if inconclusive
    poisoned_chunks: np.ndarray = field(
        default_factory=lambda: np.zeros((0,), np.int64))
    holders: int = 0                # third-party holders that took a side
    conclusive: bool = False
    persistent: bool = False        # param-space corruption (state poisoned)
    reason: str = ""


def mismatched_pairs(table: np.ndarray,
                     pairs: Sequence[Sequence[int]],
                     *, tol: float = 0.0) -> List[Tuple[int, int]]:
    """Mirror pairs whose digest rows disagree (singleton groups skipped)."""
    out = []
    for g in pairs:
        if len(g) != 2:
            continue
        a, b = int(g[0]), int(g[1])
        if bool(np.any(rows_differ(table[a], table[b], tol=tol))):
            out.append((a, b))
    return out


def majority_vote(table: np.ndarray, pair: Tuple[int, int], *,
                  reference: Optional[np.ndarray] = None,
                  tol: float = 0.0, ref_rel: float = 1e-6) -> ScrubVerdict:
    """Name the poisoned member of ``pair`` from a digest table whose
    healthy rows are identical by construction (replicated state).

    Every other live slice is a holder (exact comparison); the last-submit
    ``reference`` digests are one more holder (relative comparison). The
    loser of a strict majority is the victim; its poisoned chunks are the
    rows differing from the winner's.
    """
    a, b = int(pair[0]), int(pair[1])
    n = table.shape[0]
    votes = {a: 0, b: 0}
    holders = 0
    for m in (a, b):
        for other in range(n):
            if other in (a, b):
                continue
            if not np.any(rows_differ(table[m], table[other], tol=tol)):
                votes[m] += 1
    holders = n - 2
    if reference is not None and reference.shape == table[a].shape:
        holders += 1
        for m in (a, b):
            if not np.any(rows_differ(table[m], reference,
                                      tol=tol, rel=ref_rel)):
                votes[m] += 1
    if votes[a] == votes[b]:
        return ScrubVerdict(victim=None, holders=holders, conclusive=False,
                            reason=f"tie {votes[a]}:{votes[b]} "
                                   f"among {holders} holders")
    winner, victim = (a, b) if votes[a] > votes[b] else (b, a)
    bad = rows_differ(table[victim], table[winner], tol=tol)
    return ScrubVerdict(
        victim=victim,
        poisoned_chunks=np.nonzero(bad)[0].astype(np.int64),
        holders=holders,
        conclusive=True,
        reason=f"{votes[winner]}:{votes[victim]} for slice {winner}",
    )
