"""Distribution rules: sharding specs for params, optimizer state, caches."""
