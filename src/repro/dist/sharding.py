"""Sharding rules for every state pytree in the system.

The mesh has two kinds of axes (paper Sec. V mapping):

- the flattened (pod, data) *slice* axes - the replication/failure domain,
  manual inside ``shard_map``; params are replicated over them, batches and
  decode caches are sharded over them;
- the ``model`` axis - a GSPMD auto axis carrying tensor/expert parallelism
  inside a slice.

Rules are name-based over the flattened param path (the same paths the
checkpointer serializes), and every model-axis placement is guarded by
divisibility so any config lowers on any mesh: a dimension that does not
divide the model-axis size is simply replicated.

Layout summary (base shapes; stacked leaves carry leading layer/group dims):

- ``embed`` (V, d)        -> vocab over model   (padded_vocab is 256-aligned)
- ``lm_head`` (d, V)      -> vocab over model
- attention ``wq/wk/wv``  -> output (head) dim over model; ``wo`` input dim
- MLP ``w_gate/w_up``     -> d_ff over model; ``w_down`` d_ff (input) dim
- MoE expert stacks       -> expert dim over model (``MoEConfig.sharding ==
  'expert'``), else d_ff (tensor parallel); router replicated
- Mamba ``in_*``          -> projection output over model; ``out_proj`` and
  conv weights input-channel over model; scalar head params replicated
- norms / biases / scalars -> replicated

Decode caches shard the request batch over the slice axes (the serving
analogue of the replication domain) and the head dim over model, matching
the decode path's point-of-use constraints in ``models/layers.py``.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

PyTree = Any

# leaves whose LAST dim is the model-sharded output projection
_SHARD_OUT = frozenset(
    {"wq", "wk", "wv", "w_gate", "w_up", "in_z", "in_x", "in_bc", "in_dt",
     "bq", "bk", "bv"}
)
# leaves whose SECOND-TO-LAST dim is the model-sharded input contraction
_SHARD_IN = frozenset({"wo", "w_down", "out_proj", "conv_x_w", "conv_bc_w"})


def path_str(key_path) -> str:
    return "/".join(
        str(getattr(k, "key", getattr(k, "idx", k))) for k in key_path
    )


def _model_size(mesh: Mesh) -> int:
    return int(mesh.shape["model"]) if "model" in mesh.axis_names else 1


def slice_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def _lead(mesh: Mesh):
    axes = slice_axes(mesh)
    return axes if len(axes) > 1 else axes[0]


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def param_spec(path: str, shape: Sequence[int], cfg: ModelConfig,
               n_model: int) -> P:
    """PartitionSpec for one parameter leaf. ``path`` is the flattened
    pytree path, ``shape`` the full (possibly layer-stacked) shape."""
    parts = path.split("/")
    leaf = parts[-1]
    spec = [None] * len(shape)
    if n_model <= 1 or not shape:
        return P(*spec)

    def place(axis: int) -> None:
        if shape[axis] >= n_model and shape[axis] % n_model == 0:
            spec[axis] = "model"

    if leaf == "embed":
        place(len(shape) - 2)  # (V, d): vocab
    elif leaf == "lm_head":
        place(len(shape) - 1)  # (d, V): vocab
    elif leaf == "router":
        pass  # tiny; replicated keeps routing local
    elif "moe" in parts and leaf in ("w_gate", "w_up", "w_down"):
        # expert stacks (.., E, in, out)
        mode = cfg.moe.sharding if cfg.moe is not None else "tensor"
        e_axis = len(shape) - 3
        f_axis = len(shape) - 1 if leaf != "w_down" else len(shape) - 2
        if mode == "expert" and shape[e_axis] % n_model == 0:
            spec[e_axis] = "model"
        else:
            place(f_axis)
    elif leaf in _SHARD_OUT:
        place(len(shape) - 1)
    elif leaf in _SHARD_IN:
        place(len(shape) - 2)
    return P(*spec)


def param_shardings(params: PyTree, mesh: Mesh, cfg: ModelConfig) -> PyTree:
    """NamedSharding pytree for a param tree (arrays or ShapeDtypeStructs):
    replicated over the slice axes, model-sharded per ``param_spec``."""
    n_model = _model_size(mesh)

    def per_leaf(key_path, leaf):
        return NamedSharding(
            mesh, param_spec(path_str(key_path), leaf.shape, cfg, n_model)
        )

    return jax.tree_util.tree_map_with_path(per_leaf, params)


def opt_shardings(opt_state: PyTree, pshard: PyTree, mesh: Mesh) -> PyTree:
    """Optimizer-state shardings: moments mirror the params, the step
    counter is a replicated scalar."""
    return type(opt_state)(
        step=NamedSharding(mesh, P()), mu=pshard, nu=pshard
    )


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------


def cache_batch_axis(path: str, ndim: int) -> int:
    """Index of the request-batch dim in a cache leaf.

    Attention k/v leaves are (..stack dims.., B, S, KV, hd) -> ndim-4
    (covers plain (L,B,S,KV,hd), grouped (G,R,B,S,KV,hd) and cross
    (L,B,enc,KV,hd)); SSM conv/state stacks are (L, B, ...) -> 1.
    """
    leaf = path.split("/")[-1]
    if leaf in ("k", "v"):
        return ndim - 4
    return 1


def cache_manual_specs(cache: PyTree, lead) -> PyTree:
    """Per-leaf PartitionSpecs over the MANUAL slice axes only (shard_map
    in/out specs): ``lead`` on the batch dim, everything else unconstrained
    (the model axis is auto). ``lead=None`` replicates (small-batch cells).
    """

    def per_leaf(key_path, leaf):
        spec = [None] * leaf.ndim
        if lead is not None:
            spec[cache_batch_axis(path_str(key_path), leaf.ndim)] = lead
        return P(*spec)

    return jax.tree_util.tree_map_with_path(per_leaf, cache)


def cache_shardings(cache: PyTree, mesh: Mesh, *,
                    shard_batch: bool = True) -> PyTree:
    """NamedSharding pytree for a decode cache: batch over the slice axes
    (when it divides), head dim of k/v over the model axis (when it
    divides) so decode attention runs shard-local (layers.py decode path).
    """
    n_model = _model_size(mesh)
    axes = slice_axes(mesh)
    lead = _lead(mesh)
    n_slices = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

    def per_leaf(key_path, leaf):
        path = path_str(key_path)
        spec = [None] * leaf.ndim
        if shard_batch and axes:
            b_axis = cache_batch_axis(path, leaf.ndim)
            if leaf.shape[b_axis] % n_slices == 0 and leaf.shape[b_axis] > 0:
                spec[b_axis] = lead
        if (
            n_model > 1
            and path.split("/")[-1] in ("k", "v")
            and leaf.shape[-1] % n_model == 0
        ):
            spec[-1] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(per_leaf, cache)
