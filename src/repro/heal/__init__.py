"""``repro.heal`` - elastic re-replication (restoring rdegree online).

PartRePer-MPI's shrink semantics (paper Sec. VI) erode redundancy
monotonically: every masked failure consumes a replica, and after ``nRep``
failures the job runs checkpoint-only until restart. FTHP-MPI makes
*restoring* replication a first-class recovery step, and ReStore shows
surviving-node memory is fast enough to rebuild redundancy online. This
package is that capability:

- :class:`HealPolicy` - ``none`` (paper baseline) | ``eager`` | ``deferred(k)``;
- :class:`HealPlan` / :class:`HealAction` - what ``WorldState.heal`` emits:
  spare -> replica conversions, most-exposed-first;
- :class:`Healer` - executes a plan: 3-phase live clone through the
  ``state_transfer``/``LiveCloneStore`` machinery, partner-store pair
  re-registration, and shard re-placement, inside the recovery window so
  the next re-lowered step compiles with the healed topology.

The spare pool itself lives on :class:`~repro.core.replication.WorldState`
(``spares``/``exposed``/``target_rdegree``); ``FTSession`` wires the
policy via its ``heal=`` / ``n_spares=`` knobs and accounts heals and
time-at-risk in :class:`~repro.ft.FTReport`.
"""
from repro.heal.healer import Healer
from repro.heal.plan import HealAction, HealPlan
from repro.heal.policy import HealPolicy

__all__ = ["HealAction", "HealPlan", "HealPolicy", "Healer"]
