"""HealPlan - the re-replication transition record.

``WorldState.heal`` is a pure topology transition (spares -> replica
roles); what it emits is a :class:`HealPlan`: which computational role
gets re-mirrored onto which spare physical slice, in which order, and why
that order (the exposure generation - how long the role has been running
unprotected). The :class:`~repro.heal.healer.Healer` then *executes* the
plan - 3-phase live clone, partner-store pair re-registration, shard
re-placement - and annotates it with the transfer accounting.

Kept dependency-free (no jax, no stores) so ``core/replication.py`` can
emit plans without pulling the execution machinery into the topology
algebra.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional


@dataclass(frozen=True)
class HealAction:
    """Re-establish the mirror of ``cmp_role`` on physical slice ``spare``.

    ``exposed_since`` is the world generation at which the role lost its
    replica (-1: the role was unmirrored by the initial rdegree split, not
    by erosion) - the sort key that makes healing most-exposed-first.
    """

    cmp_role: int
    spare: int
    exposed_since: int = -1


@dataclass
class HealPlan:
    """One heal transition: the actions plus execution accounting."""

    generation: int  #: world generation the plan was computed at
    actions: List[HealAction] = field(default_factory=list)
    deficit_before: int = 0  #: target_n_rep - n_rep before healing
    deficit_after: int = 0
    #: 3-phase live-clone accounting (a ``TransferReport``), filled by the
    #: Healer when the program exposes a snapshot to clone
    transfer: Optional[Any] = None
    #: snapshot steps whose partner shards were re-placed onto the new ring
    replaced_steps: List[int] = field(default_factory=list)

    def __bool__(self) -> bool:
        return bool(self.actions)

    def describe(self) -> str:
        pairs = " ".join(
            f"role{a.cmp_role}<-spare{a.spare}"
            + (f"(exposed@g{a.exposed_since})" if a.exposed_since >= 0 else "")
            for a in self.actions
        )
        return (
            f"healed {len(self.actions)} mirror(s): {pairs} "
            f"deficit {self.deficit_before}->{self.deficit_after}"
        )
