"""Healer - executes :class:`~repro.heal.plan.HealPlan` transitions.

``WorldState.heal`` decides *which* spares re-mirror *which* exposed
computational roles; the Healer performs the three side effects that make
the new pair real:

1. **3-phase live clone** (paper Sec. III-A process-image transfer): the
   program's snapshot is staged through a :class:`LiveCloneStore` -
   data/heap/stack phase ordering, per-phase verification - so the spare
   adopts a provably faithful copy of its partner's state before the pair
   goes live. The staged clone is what the re-lowered step places onto the
   spare's devices.
2. **Pair re-registration**: the spare's host memory joins every
   partner-memory store's peer ring (``register_peers``) so future
   snapshot shards land on it.
3. **Shard re-placement** (``rebalance``): existing snapshots are re-
   placed onto the healed ring, restoring K-way redundancy that the
   failure eroded (ReStore's re-distribution step).

The Healer runs inside ``FTSession.recover``'s window, after the
session's ``ladder.drain()`` barrier (any pipelined submit still in
flight has landed) and after the restore walk (so a backfilled partner is
cloned from its *restored* state), and before the communicator
re-derivation, so the next re-lowered step compiles with the healed
topology. Per-phase clone verification goes through the ``repro.xfer``
digest path (the fused Pallas checksum kernel, one on-device pass per
phase).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Any, Dict, Iterable, List, Optional, Tuple, Union

from repro.heal.plan import HealPlan
from repro.heal.policy import HealPolicy
from repro.store.liveclone import LiveCloneStore

if TYPE_CHECKING:  # import-time cycle: replication emits the plans we run
    from repro.core.replication import WorldState

PyTree = Any


class Healer:
    def __init__(self, policy: Union[str, HealPolicy] = "none", *,
                 bit_exact: bool = False):
        self.policy = HealPolicy.parse(policy)
        # the rebirth staging buffer: one slot, always the newest clone
        self.stage = LiveCloneStore(verify=True, bit_exact=bit_exact, keep=1)
        self.plans: List[HealPlan] = []
        # capacity listener (the serving gateway's worker registry): called
        # with (healed_world, plan_or_None, fresh_physicals) whenever a
        # recovery window brings new physicals into the world - healed
        # replicas re-arming the FT plane, spare backfills growing the
        # serving pool back - so the pool re-registers them live
        self.on_capacity: Optional[Any] = None

    @property
    def enabled(self) -> bool:
        return self.policy.enabled

    def maybe_heal(
        self,
        world: "WorldState",
        *,
        snapshot: Optional[Tuple[PyTree, Dict]] = None,
        stores: Iterable = (),
        step: int = 0,
        extra_peers: Iterable[int] = (),
    ) -> Tuple["WorldState", Optional[HealPlan]]:
        """Heal ``world`` if the policy wants it. Returns the (possibly)
        healed world and the executed plan (``None`` when nothing healed).

        ``snapshot`` is the program's ``(state, meta)`` - the mirrored
        state the new replicas adopt; ``stores`` is walked for partner-
        memory levels to re-register the new pairs with. ``extra_peers``
        are other physicals that entered the world this recovery (spare
        backfills): they join the SAME registration + shard re-placement
        pass, so the manifest is re-gathered and re-spread once per
        recovery window, not once per cause.
        """
        healed, plan = world, None
        if self.policy.wants_heal(world.replica_deficit()) and world.spares:
            healed, plan = world.heal()
            if not plan:
                healed, plan = world, None

        # 1) 3-phase live clone of the partner state (verified per phase)
        if plan and snapshot is not None:
            state, meta = snapshot
            self.stage.submit(step, state, dict(meta))
            plan.transfer = self.stage.last_report

        # 2) + 3) pair re-registration and shard re-placement - one pass
        # for backfilled AND healed physicals
        fresh = list(extra_peers) + ([a.spare for a in plan.actions] if plan else [])
        replaced = self.register_spares(fresh, stores)
        if plan:
            plan.replaced_steps = replaced
            self.plans.append(plan)
        if fresh and self.on_capacity is not None:
            self.on_capacity(healed, plan, fresh)
        return healed, plan

    @staticmethod
    def register_spares(physicals: Iterable[int], stores: Iterable) -> List[int]:
        """Add newly-active physicals (healed replicas or backfilled roles)
        to every peer-ring store and re-place existing shards onto the new
        ring. Returns the snapshot steps whose shards were re-placed."""
        physicals = list(physicals)
        replaced: List[int] = []
        if not physicals:
            return replaced
        for s in stores:
            register = getattr(s, "register_peers", None)
            if register is None:
                continue
            register(physicals)
            rebalance = getattr(s, "rebalance", None)
            if rebalance is not None:
                replaced.extend(rebalance())
        return sorted(set(replaced))
