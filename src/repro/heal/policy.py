"""Healing policies - when to convert spares back into replicas.

FTHP-MPI makes restoring replication a first-class recovery step; the
policy knob here decides *when* that step runs, because re-establishing a
mirror is not free: it costs a 3-phase live clone plus a communicator
re-derivation and step re-lower (the same compile the error handler
already pays once per repair).

- ``none``     - PartRePer baseline: replication erodes monotonically;
                 spares are never consumed (Sec. VI shrink semantics).
- ``eager``    - heal inside every recovery window that leaves a replica
                 deficit: the re-lower is already being paid, so the extra
                 cost is just the clone.
- ``deferred(k)`` - batch heals: only convert spares once the replica
                 deficit reaches ``k``, amortizing the clone+re-lower over
                 several failures (a cluster that fails in bursts heals
                 once per burst, not once per death).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Union


@dataclass(frozen=True)
class HealPolicy:
    kind: str  # "none" | "eager" | "deferred"
    threshold: int = 1

    _SPEC = re.compile(r"^deferred[:(](\d+)\)?$")

    def __post_init__(self):
        assert self.kind in ("none", "eager", "deferred"), self.kind
        assert self.threshold >= 1, self.threshold

    @classmethod
    def parse(cls, spec: Union[str, "HealPolicy"]) -> "HealPolicy":
        """CLI syntax: ``none`` | ``eager`` | ``deferred:K`` / ``deferred(K)``."""
        if isinstance(spec, HealPolicy):
            return spec
        s = (spec or "none").strip().lower()
        if s in ("none", "eager"):
            return cls(s)
        m = cls._SPEC.match(s)
        if m:
            return cls("deferred", threshold=int(m.group(1)))
        raise ValueError(
            f"bad heal policy {spec!r}: expected none | eager | deferred:K"
        )

    @property
    def enabled(self) -> bool:
        return self.kind != "none"

    def wants_heal(self, deficit: int) -> bool:
        """Should a recovery window with ``deficit`` missing replicas heal?"""
        if self.kind == "none" or deficit <= 0:
            return False
        if self.kind == "eager":
            return True
        return deficit >= self.threshold

    def __str__(self) -> str:
        return self.kind if self.kind != "deferred" else f"deferred:{self.threshold}"
