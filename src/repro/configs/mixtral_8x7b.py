"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2
[arXiv:2401.04088; hf]

The assignment specifies SWA (window 4096), which makes long_500k
sub-quadratic in cache footprint. 8 experts < 16-way model axis, so the
experts use tensor sharding (d_ff over the model axis, no all_to_all);
see DESIGN.md for the trade-off vs expert-parallel.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    attn_pattern="sliding",
    window=4096,
    mlp="swiglu",
    moe=MoEConfig(n_experts=8, top_k=2, sharding="tensor"),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)
