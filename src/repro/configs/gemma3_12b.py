"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144
[hf:google/gemma-3-1b-pt; unverified]

Gemma-3 uses head_dim=256 (decoupled from d_model/n_heads) and interleaves
five sliding-window (1024) layers per global layer, which is what makes the
long_500k cell sub-quadratic in cache footprint.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    attn_pattern="local_global",
    local_global_ratio=5,  # 5 local : 1 global
    window=1024,
    mlp="swiglu",
    attn_logit_softcap=0.0,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
