"""Configuration system for PartRePer-JAX.

Every architecture in the assigned pool is expressed as a ``ModelConfig``;
every dry-run / benchmark cell is a ``ModelConfig`` x ``ShapeConfig`` pair;
the paper's technique is configured by ``ReplicationConfig``.

Configs are frozen dataclasses so they can be closed over by jitted
functions and hashed as static arguments.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")
ATTN_PATTERNS = ("full", "sliding", "local_global")
MLP_KINDS = ("swiglu", "squared_relu", "gelu")


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256  # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (GShard-style capacity dispatch)."""

    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    # 'expert': experts sharded over the model axis (EP, all_to_all dispatch)
    # 'tensor': every device holds all experts, d_ff sharded (TP, no a2a)
    sharding: str = "expert"
    router_aux_coef: float = 0.01
    # GShard-style dispatch groups: tokens are split into groups of this
    # size with per-group capacity. Without grouping the dispatch one-hot
    # einsum is O(T^2 k E / E) in tokens (C grows with T) - the dominant
    # compute term at 4k+ sequence lengths (see EXPERIMENTS.md Perf-1).
    group_size: int = 1024


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention
    attn_pattern: str = "full"
    window: int = 4096  # sliding-window size when pattern uses windows
    local_global_ratio: int = 0  # gemma3: N local layers per 1 global layer
    qkv_bias: bool = False
    attn_logit_softcap: float = 0.0

    # mlp
    mlp: str = "swiglu"

    # moe / ssm
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # hybrid (hymba): fraction of head dim given to SSM path per layer,
    # plus a handful of full-attention ("global") layers.
    hybrid_global_layers: Tuple[int, ...] = ()

    # enc-dec
    enc_layers: int = 0  # >0 => encoder-decoder; n_layers counts decoder layers

    # vlm / audio frontends are STUBS per assignment: input_specs() provides
    # precomputed patch/frame embeddings of this many positions.
    n_prefix_embeds: int = 0

    # embeddings / misc
    rope_theta: float = 10_000.0
    mrope: bool = False  # qwen2-vl multimodal rope
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    # parallel residual (command-r style: attn and mlp from the same norm)
    parallel_block: bool = False

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # activation checkpointing policy: 'none' | 'block' (remat each layer)
    remat: str = "block"
    # scan-over-layers (compact HLO; XLA cost_analysis counts the body once).
    # False unrolls the stacks - used by the roofline depth-variant pass.
    scan_layers: bool = True

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        assert self.attn_pattern in ATTN_PATTERNS, self.attn_pattern
        assert self.mlp in MLP_KINDS, self.mlp
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived quantities -------------------------------------------------
    def padded_vocab(self, multiple: int = 256) -> int:
        """Vocab padded for clean sharding over the model axis (every
        production framework does this; labels never reference the pad)."""
        return -(-self.vocab_size // multiple) * multiple

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Whether the arch supports long (500k) contexts without a full
        quadratic / full-length global KV dominating: SSM, hybrid-SWA and
        sliding-window archs qualify; local_global (gemma3) qualifies because
        5/6 of the layers hold only window-sized caches."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attn_pattern in ("sliding", "local_global")

    @property
    def has_decoder(self) -> bool:
        return True  # no encoder-only archs in the assigned pool

    def is_global_layer(self, layer_idx: int) -> bool:
        """local_global pattern: 1 global layer per (ratio+1) layers."""
        if self.attn_pattern != "local_global":
            return self.attn_pattern == "full"
        return (layer_idx + 1) % (self.local_global_ratio + 1) == 0

    def param_count(self) -> int:
        """Total parameter count (embedding + blocks), exact per family."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.head_dim
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd
        emb = v * d if self.tie_embeddings else 2 * v * d

        def attn_params() -> int:
            p = d * q + 2 * d * kv + q * d  # wq, wk, wv, wo
            if self.qkv_bias:
                p += q + 2 * kv
            return p

        def mlp_params(dff: int) -> int:
            if self.mlp == "swiglu":
                return 3 * d * dff
            return 2 * d * dff  # up + down

        def ssm_params() -> int:
            assert self.ssm is not None
            di = self.ssm.d_inner(d)
            nh = self.ssm.n_heads(d)
            # in_proj: z, x, B, C, dt ; out_proj ; conv ; A, D, dt_bias
            in_p = d * (2 * di + 2 * self.ssm.d_state + nh)
            out_p = di * d
            conv = (di + 2 * self.ssm.d_state) * self.ssm.d_conv
            return in_p + out_p + conv + 3 * nh

        per_layer = 2 * d  # two RMSNorm scales
        if self.family == "ssm":
            per_layer += ssm_params()
        elif self.family == "hybrid":
            per_layer += attn_params() + mlp_params(f) + ssm_params()
        elif self.family == "moe":
            assert self.moe is not None
            per_layer += attn_params()
            per_layer += d * self.moe.n_experts  # router
            per_layer += self.moe.n_experts * mlp_params(f)
        else:
            per_layer += attn_params() + mlp_params(f)

        total = emb + L * per_layer + d  # final norm
        if self.enc_layers:
            enc_layer = 2 * d + attn_params() + mlp_params(f)
            # decoder layers also carry cross-attention + its norm
            total += self.enc_layers * enc_layer + L * (attn_params() + d)
        return total

    def active_param_count(self) -> int:
        """Active (per-token) parameters - differs for MoE."""
        if self.family != "moe" or self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        inactive_experts = self.moe.n_experts - self.moe.top_k
        per_expert = 3 * d * f if self.mlp == "swiglu" else 2 * d * f
        return self.param_count() - self.n_layers * inactive_experts * per_expert


# ---------------------------------------------------------------------------
# Input-shape configuration (the assigned shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def lowers_serve_step(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell is runnable, with a reason when not."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, "long_500k skipped: pure full-attention architecture"
    return True, ""


# ---------------------------------------------------------------------------
# Replication (the paper's knob) + run configuration
# ---------------------------------------------------------------------------

# Paper's replication degrees (Fig. 8): percent of computational slices
# that have replicas.
PAPER_RDEGREES = (0.0, 0.0625, 0.125, 0.25, 0.5, 1.0)


@dataclass(frozen=True)
class ReplicationConfig:
    """Partial replication of mesh data-slices (PartRePer-MPI Sec. V)."""

    rdegree: float = 0.0  # fraction of computational slices with replicas
    # paper-faithful: group-psum on COMM_CMP + ppermute intercomm to REP.
    # fused: single masked all-reduce over the whole data axis (beyond-paper).
    # branch: replicas contribute grad/k inside the all-reduce (beyond-paper).
    collective_mode: str = "paper"  # 'paper' | 'fused' | 'branch'
    # SDC detection: mirrored pairs cross-check per-chunk [abs-sum, sum]
    # digests of gradients AND params inside the step (RedMPI-style); a
    # mismatch gates the optimizer update so no poisoned step ever lands
    sdc_check: bool = False
    # absolute per-column digest slack; 0.0 because healthy mirrors are
    # bit-identical (same compiled program, same inputs)
    sdc_tol: float = 0.0
    # scrub digest granularity (elements per per-leaf chunk)
    sdc_chunk_elems: int = 1 << 12
    # compress the cmp->rep intercomm payload (beyond-paper)
    intercomm_compression: str = "none"  # 'none' | 'bf16' | 'int8'
    # dtype of the gradient all-reduce on the data plane (beyond-paper:
    # halves collective + memory traffic of the reduction; optimizer still
    # accumulates in fp32)
    grad_reduce_dtype: str = "float32"  # 'float32' | 'bfloat16'


@dataclass(frozen=True)
class TrainConfig:
    seed: int = 0
    steps: int = 100
    learning_rate: float = 3e-4
    warmup_steps: int = 10
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    microbatches: int = 1  # gradient accumulation
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    checkpoint_every: int = 0  # 0 = off
    checkpoint_dir: str = ""
    log_every: int = 10


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1  # >1 adds the leading "pod" axis

    @property
    def n_slices(self) -> int:
        """Model-parallel slices = product of (pod, data)."""
        return self.pods * self.data

    @property
    def n_devices(self) -> int:
        return self.pods * self.data * self.model


def reduced(model: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    changes = dict(
        n_layers=4 if model.attn_pattern == "local_global" else 2,
        local_global_ratio=1 if model.attn_pattern == "local_global" else 0,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(model.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128 if model.d_ff else 0,
        vocab_size=256,
        window=32,
        remat="none",
        n_prefix_embeds=8 if model.n_prefix_embeds else 0,
        enc_layers=2 if model.enc_layers else 0,
    )
    if model.moe is not None:
        changes["moe"] = dataclasses.replace(model.moe, n_experts=4, top_k=2)
    if model.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            model.ssm, d_state=16, head_dim=16, chunk=16
        )
    if model.hybrid_global_layers:
        changes["hybrid_global_layers"] = (1,)
    changes.update(overrides)
    return dataclasses.replace(model, name=model.name + "-smoke", **changes)
