"""hymba-1.5b [hybrid] — parallel attention + mamba heads in each block.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16
[arXiv:2411.13676; hf]

Each block runs an attention path and an SSM path in parallel on the same
input and mean-fuses their (normalised) outputs. Most attention layers use
a sliding window; a few are global (first/middle/last) - which keeps the
long-context cache footprint small -> long_500k runs.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attn_pattern="sliding",
    window=1024,
    hybrid_global_layers=(0, 15, 31),  # full-attention layers
    mlp="swiglu",
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, chunk=256),
    rope_theta=10_000.0,
    tie_embeddings=True,
)
