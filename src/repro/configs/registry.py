"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable, reduced

from repro.configs.command_r_35b import CONFIG as _command_r
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.qwen2_5_3b import CONFIG as _qwen25
from repro.configs.nemotron_4_15b import CONFIG as _nemotron
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl
from repro.configs.phi3_5_moe_42b import CONFIG as _phi35
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.mamba2_2_7b import CONFIG as _mamba2
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.seamless_m4t_medium import CONFIG as _seamless

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _command_r,
        _gemma3,
        _qwen25,
        _nemotron,
        _qwen2vl,
        _phi35,
        _mixtral,
        _mamba2,
        _hymba,
        _seamless,
    )
}

# short aliases accepted by --arch
ALIASES = {
    "command-r": "command-r-35b",
    "gemma3": "gemma3-12b",
    "qwen2.5": "qwen2.5-3b",
    "nemotron": "nemotron-4-15b",
    "qwen2-vl": "qwen2-vl-2b",
    "phi3.5-moe": "phi3.5-moe-42b-a6.6b",
    "mixtral": "mixtral-8x7b",
    "mamba2": "mamba2-2.7b",
    "hymba": "hymba-1.5b",
    "seamless": "seamless-m4t-medium",
}


def get_arch(name: str) -> ModelConfig:
    name = ALIASES.get(name, name)
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells():
    """Every (arch, shape) cell with its applicability verdict."""
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, reason = shape_applicable(arch, shape)
            yield arch, shape, ok, reason


def smoke_config(name: str) -> ModelConfig:
    return reduced(get_arch(name))
