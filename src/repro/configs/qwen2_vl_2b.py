"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (frontend stubbed).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
[arXiv:2409.12191; hf]

Per assignment the vision frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (n_prefix_embeds positions) which the backbone
consumes ahead of the text tokens. M-RoPE splits the rotary dim into
temporal/height/width sections with separate position ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    attn_pattern="full",
    qkv_bias=True,
    mlp="swiglu",
    mrope=True,
    n_prefix_embeds=256,  # precomputed vision patch embeddings per sample
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
