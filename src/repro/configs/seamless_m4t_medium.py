"""seamless-m4t-medium [audio] — encoder-decoder, multimodal (frontend stubbed).

12L d_model=1024 16H (GQA kv=16, i.e. MHA) d_ff=4096 vocab=256206
[arXiv:2308.11596; hf]

Per assignment the speech frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings for the encoder. 12 encoder + 12 decoder
layers; decoder layers add cross-attention over encoder output. For decode
shapes, the decoder self-attention cache is seq_len long and the encoder
context is capped at 4096 frames (see DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,  # decoder layers
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    attn_pattern="full",
    mlp="gelu",
    n_prefix_embeds=0,  # encoder input is entirely precomputed frames
    rope_theta=10_000.0,
    tie_embeddings=True,
)
