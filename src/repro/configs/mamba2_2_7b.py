"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.

64L d_model=2560 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]

d_inner = 2*d_model = 5120, head_dim 64 -> 80 SSD heads per layer.
Decode state is O(heads * head_dim * d_state) per layer - long_500k runs.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=1,  # unused for ssm
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=64),
    tie_embeddings=True,
)
