"""The application surface of ``repro.ft``.

A :class:`ResilientProgram` is what an application implements to run under
:class:`~repro.ft.session.FTSession`. Only two hooks are mandatory -
``build_step`` (lower the jitted step onto a mesh/world) and ``run_step``
(execute one dispatch unit). Everything else defaults to a no-op and is
opted into by workloads that need it:

===================  =====================================================
hook                 who uses it
===================  =====================================================
``build_step``       everyone: re-lowered on every communicator regen
``run_step``         everyone: the hot-path dispatch unit (step / token)
``sample_range``     trainers with a seekable pipeline (message logging)
``snapshot``         trainers/servers: state submitted to the
                     ``repro.store`` recovery ladder on the checkpoint
                     cadence (doubles as the restore template)
``restore``          trainers/servers: adopt a ladder snapshot after an
                     unmasked failure
``init_fresh``       trainers: restart from scratch (no level recoverable)
``repack_state``     servers: carry promoted replicas' live caches across
                     the shrink (paper: "the replica now becomes the
                     computational process")
``replay_inputs``    anything holding input cursors that must seek to the
                     replay plan's start step
===================  =====================================================

The session assigns itself to ``program.session`` before the first
``build_step`` call, so programs may read ``self.session.world`` /
``self.session.mesh`` / ``self.session.report`` from any hook.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

from repro.core.recovery import ReplayPlan
from repro.core.replication import WorldState

PyTree = Any


class ResilientProgram:
    """Base class (and documentation of the hook contract) for programs
    executed by :class:`~repro.ft.session.FTSession`."""

    # set by FTSession.__init__ before the first build_step call
    session: Any = None

    # ---- mandatory ---------------------------------------------------------
    def build_step(self, mesh, world: WorldState) -> None:
        """(Re)generate communicators: re-place state onto ``mesh`` and
        re-lower the step function with the new world's groups. Called once
        at session construction and after every repair."""
        raise NotImplementedError

    def run_step(self, step: int) -> Any:
        """Execute dispatch unit ``step`` (a train step, a decode token, a
        mini-app iteration). Timed as app time by the session."""
        raise NotImplementedError

    # ---- message logging / replay (trainers) -------------------------------
    def sample_range(self, step: int, cmp_role: int) -> Tuple[int, int]:
        """Global sample-id range the computational role consumed at
        ``step`` - recorded into the per-role step logs."""
        return (0, 0)

    def replay_inputs(self, plan: ReplayPlan) -> None:
        """Seek input state to ``plan.start_step`` (no-op for programs whose
        inputs are pure functions of the step index)."""

    # ---- recovery-ladder snapshots (trainers + servers) --------------------
    def snapshot(self) -> Optional[Tuple[PyTree, Dict]]:
        """(state, meta) submitted to the session's ``repro.store`` ladder;
        the state pytree doubles as the restore template. ``None`` => the
        program is not checkpointable."""
        return None

    def restore(self, state: PyTree, meta: Dict) -> None:
        """Adopt checkpointed ``state`` (inverse of ``snapshot``)."""
        raise NotImplementedError(
            f"{type(self).__name__} snapshots state but does not restore"
        )

    def init_fresh(self) -> None:
        """Re-initialize from scratch - the restore path of last resort.
        Default: keep current state (stateless programs resume in place)."""

    # ---- elastic repack (servers) ------------------------------------------
    def repack_state(self, old_world: WorldState, new_world: WorldState) -> None:
        """Carry live state across the shrink, BEFORE ``build_step`` runs on
        the new world (e.g. re-pack per-slice KV-cache rows so promoted
        replicas keep their mirrored caches). ``new_world`` may contain
        physicals that were NOT in the old world: replicas the heal plane
        just re-established on spares (warm their mirrored state from the
        partner) and spare-backfilled computational roles (their state is
        the just-restored snapshot; ``session.last_repair['role_map']``
        maps new role ids back to old ones)."""
