"""``repro.ft`` - the single public API for fault-tolerant execution.

PartRePer-MPI's promise is that an existing MPI application becomes fault
tolerant by linking one library: the EMPI_* wrappers run the hot path on
the fast native MPI while Open MPI + ULFM handle detect -> revoke ->
agree -> shrink behind the scenes. This package is that library for jitted
JAX programs:

- :class:`ResilientProgram` is the application surface - wrap a step
  function (and optionally snapshot/restore/repack hooks) and every future
  workload is a ~50-line program;
- :class:`FTSession` is the wrapper library - it owns the base mesh,
  :class:`~repro.core.replication.WorldState`, the
  :class:`~repro.core.control_plane.ControlPlane`, the generation guard,
  the full error handler (revoke -> agree -> repair -> shrink ->
  re-lower -> replay), restore through the pluggable
  :class:`~repro.store.RecoveryLadder` (live clone -> K-way partner
  memory -> durable -> fresh init), failure injection via
  :class:`FailureSchedule`, and the unified :class:`FTReport`.

Paper mapping: FTSession.run is Fig. 7's dispatch loop, FTSession.recover
is Sec. VI's error handler, FailureSchedule is the fault injector, and the
ResilientProgram hooks are the application-side EMPI entry points.
"""
from repro.core.recovery import ReplayPlan
from repro.ft.program import ResilientProgram
from repro.ft.session import FailureSchedule, FTReport, FTSession

__all__ = [
    "FailureSchedule",
    "FTReport",
    "FTSession",
    "ReplayPlan",
    "ResilientProgram",
]
