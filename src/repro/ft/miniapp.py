"""NAS mini-apps as ResilientPrograms (the paper's Sec. VII suite, run
through the same session API as the trainer and the server).

Each mini-app step is a pure function of (mesh, world, inputs), and the
inputs are regenerated deterministically for whatever world survives - so
the recovery policy is resume-in-place (``replay='none'``): after repair
the session re-lowers the app over the shrunk world and the interrupted
iteration reruns. This is exactly what linking the paper's library buys an
existing MPI mini-app: no app-side failure code at all.

    prog = MiniAppProgram("cg", ReplicationConfig(rdegree=1.0))
    session = FTSession(prog, n_slices=8, rdegree=1.0, replay="none")
    session.run(10, failures={4: [0]})
    assert prog.verified()
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp

from repro.apps.miniapps import MINIAPPS
from repro.compat import set_mesh
from repro.configs.base import ReplicationConfig
from repro.ft.program import ResilientProgram
from repro.ft.session import FailureSchedule, FTReport, FTSession


class MiniAppProgram(ResilientProgram):
    """Wrap one mini-app (``ep``/``cg``/``mg``/``stencil``/``is``/``pic``)
    for FTSession execution."""

    def __init__(self, name: str, repl: ReplicationConfig, **make_kwargs):
        self.name = name
        self.make = MINIAPPS[name]
        self.repl = repl
        self.make_kwargs = make_kwargs
        self.step_fn: Optional[Callable] = None
        self.state = None
        self.verify: Optional[Callable] = None
        self.last_out = None

    # ---- ResilientProgram hooks -------------------------------------------
    def build_step(self, mesh, world) -> None:
        self.mesh = mesh
        self.step_fn, init, self.verify = self.make(
            mesh, world, self.repl, **self.make_kwargs
        )
        # inputs are regenerated for the (possibly shrunk) world: replicas
        # mirror their partner's shard, exactly like the data pipeline
        self.state = jnp.asarray(init)

    def run_step(self, step: int):
        with set_mesh(self.mesh):
            self.last_out = self.step_fn(self.state)
        return self.last_out

    # ---- conveniences ------------------------------------------------------
    def verified(self) -> bool:
        return self.last_out is not None and bool(self.verify(self.last_out))


def run_miniapp(
    name: str,
    *,
    n_slices: int,
    rdegree: float = 0.0,
    mode: str = "paper",
    iters: int = 1,
    failures: Optional[Dict[int, Any]] = None,
    model_shards: int = 1,
    **make_kwargs,
) -> FTSession:
    """One-call driver: build the app, run ``iters`` iterations under the
    session (with optional failure injection), return the session."""
    repl = ReplicationConfig(rdegree=rdegree, collective_mode=mode)
    prog = MiniAppProgram(name, repl, **make_kwargs)
    session = FTSession(
        prog,
        n_slices=n_slices,
        model_shards=model_shards,
        rdegree=rdegree,
        replay="none",
        report=FTReport(),
        unit="iter",
    )
    session.run(iters, FailureSchedule(failures))
    return session
