"""FTSession: the ULFM lifecycle, owned once, for every workload.

The session is the paper's wrapper library. The program supplies the data
plane (a jitted step built by ``build_step``); the session supplies
everything PartRePer-MPI layers around it:

- the base mesh over the physical slice pool (fixed for the job's life);
- :class:`~repro.core.replication.WorldState` - the role -> physical-slice
  assignment that repair shuffles ("the replica now becomes the
  computational process");
- :class:`~repro.core.control_plane.ControlPlane` - detection, revocation,
  agreement (Secs. III-B, IV, VI-A);
- the generation guard in the dispatch loop (Fig. 7's EMPI_Test
  interleave, host-side);
- the error handler (Sec. VI): revoke -> agree -> ``WorldState.repair`` ->
  recovery-ladder restore when replication cannot mask the failure ->
  ``shrink_mesh`` -> program re-lower -> replay plan from the survivors'
  step logs (Sec. VI-B message recovery, with duplicate suppression);
- snapshot submission to the :class:`~repro.store.RecoveryLadder` (live
  clone / K-way partner memory / durable - whichever levels the caller
  stacked) on the trainer's cadence;
- re-replication through the ``repro.heal`` plane (``heal=`` policy +
  ``n_spares=`` warm standbys): after each repair the
  :class:`~repro.heal.Healer` converts spares back into replicas of the
  most-exposed roles (3-phase live clone, partner pair re-registration,
  shard re-placement), and spare *backfill* inside ``WorldState.repair``
  keeps lost computational roles - and the bitwise trajectory - alive;
- deterministic failure injection via :class:`FailureSchedule`;
- a unified :class:`FTReport` of app/handler seconds, recovery events,
  heals, and time-at-risk (``exposure_steps``).

All recovery state flows through ``repro.store``'s ``StateStore``
protocol; the session holds no backend-specific checkpoint code.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.compat import mesh_from_devices
from repro.core.control_plane import (
    CommunicatorRevoked,
    ControlPlane,
    ProcessFailed,
)
from repro.core.elastic import shrink_mesh
from repro.core.fault_injector import ChaosLatency, ChaosSchedule, ChaosState
from repro.core.recovery import ReplayPlan, StepLog, StepRecord, replay_plan
from repro.core.replication import WorldState
from repro.heal import Healer, HealPolicy
from repro.store import RecoveryLadder, StateStore
from repro.xfer.chunking import PagedBlob

PyTree = Any


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------


@dataclass
class FTReport:
    """Unified accounting across workloads. Programs may subclass to add
    workload-specific fields (losses, token counts, ...)."""

    steps_completed: int = 0
    app_seconds: float = 0.0
    handler_seconds: float = 0.0
    failures: int = 0
    promotes: int = 0
    restarts: int = 0
    interruptions: List[int] = field(default_factory=list)
    replayed_steps: int = 0
    events: List[str] = field(default_factory=list)
    #: one entry per ladder restore: "L<level>:<store>@step<step>"
    restored_from: List[str] = field(default_factory=list)
    #: one entry per executed HealPlan (repro.heal): which roles were
    #: re-mirrored onto which spares, and the clone accounting
    heals: List[str] = field(default_factory=list)
    #: replicas re-established by the heal plane (sum over plans)
    healed_replicas: int = 0
    #: time-at-risk accumulator: per completed dispatch unit, how many
    #: mirrors the world ran below its configured target (0 under healing
    #: that keeps up; grows linearly once redundancy erodes un-healed)
    exposure_steps: int = 0
    #: silent-data-corruption scrubbing (repro.scrub): pair digest
    #: mismatches the step-level scrub flagged ...
    sdc_detected: int = 0
    #: ... of which grad-space transients resolved by a single retry ...
    sdc_transient: int = 0
    #: ... and persistent corruptions repaired through a restore
    sdc_repairs: int = 0
    #: bytes digest-guided partial restores actually moved, and what the
    #: equivalent full-blob restores would have moved
    sdc_bytes_moved: int = 0
    sdc_bytes_full: int = 0
    #: gray failures (the chaos plane): units the world spent stalled
    #: behind a hung slice before the detector fired ...
    stalled_units: int = 0
    #: ... soft-suspects that recovered before the window expired (the
    #: false-positive path: a flap must never cause a shrink) ...
    flaps: int = 0
    #: ... failures found by suspicion expiry, NOT an explicit report:
    #: "hang:3" / "silence:5", one per detected slice ...
    detections: List[str] = field(default_factory=list)
    #: ... detection latency per entry above, in liveness-clock units
    #: (dispatch-loop iterations in simulation) from injection to the
    #: error handler firing ...
    detect_latency: List[float] = field(default_factory=list)
    #: ... and fail-slow peers quarantined out of store rings mid-restore
    quarantines: List[str] = field(default_factory=list)
    #: cadence ticks whose snapshot was a no-op (paged serving state with
    #: an empty dirty-page set: nothing decoded since the last submit)
    snapshots_skipped: int = 0


# ---------------------------------------------------------------------------
# failure schedule
# ---------------------------------------------------------------------------


class FailureSchedule:
    """Deterministic injection plan: dispatch step -> physical slices to
    kill at that step's boundary. Always copies its input, so consuming the
    schedule never mutates a caller-owned dict (the old ``failures.pop``
    bug), and one dict can seed several runs. A victim repeated within one
    step is deduplicated (killing a slice twice is one failure, not two -
    repeats used to inflate ``FTReport.failures``)."""

    def __init__(
        self,
        failures: Union[None, "FailureSchedule", Mapping[int, Sequence[int]]] = None,
    ):
        if isinstance(failures, FailureSchedule):
            src = failures._by_step
        else:
            src = failures or {}
        self._by_step: Dict[int, List[int]] = {
            int(s): list(dict.fromkeys(v)) for s, v in dict(src).items() if v
        }

    @classmethod
    def parse(cls, spec: str) -> "FailureSchedule":
        """CLI syntax: comma list of ``step:physical_slice`` pairs.
        Whitespace around items or fields is tolerated; empty items
        (trailing/double commas) are skipped."""
        out: Dict[int, List[int]] = {}
        for item in filter(None, (s.strip() for s in (spec or "").split(","))):
            try:
                s, v = item.split(":")
                out.setdefault(int(s), []).append(int(v))
            except ValueError:
                raise ValueError(
                    f"bad failure injection {item!r}: expected "
                    "step:physical_slice (e.g. --inject-failure 5:0,9:2)"
                ) from None
        return cls(out)

    def take(self, step: int) -> List[int]:
        """Victims scheduled for ``step`` (consumed; replays do not re-kill)."""
        return self._by_step.pop(step, [])

    def pending(self) -> int:
        return sum(len(v) for v in self._by_step.values())

    def __bool__(self) -> bool:
        return bool(self._by_step)


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------


class FTSession:
    """Fault-tolerant executor for one :class:`ResilientProgram`.

    ``replay`` selects the message-recovery policy:

    - ``"log"``  (trainers): per-role step logs feed ``replay_plan`` - the
      promote path replays only the in-flight step(s), the restore path
      replays everything after the checkpoint;
    - ``"none"`` (servers / stateless apps): resume in place at the
      interrupted step - promoted replicas carry live state, lost work is
      the program's business (``repack_state`` re-queues it).
    """

    def __init__(
        self,
        program,
        *,
        n_slices: int,
        model_shards: int = 1,
        rdegree: float = 0.0,
        n_spares: int = 0,
        heal: Union[str, HealPolicy] = "none",
        devices: Optional[Sequence] = None,
        heartbeat_timeout: float = 1e9,
        stores: Union[None, RecoveryLadder, StateStore, Sequence[StateStore]] = None,
        checkpoint_every: int = 0,
        replay: str = "log",
        report: Optional[FTReport] = None,
        unit: str = "step",
        scrub=None,
        chaos: Union[None, ChaosSchedule, str] = None,
        suspicion_window: float = 0.0,
        progress_window: Optional[float] = None,
        rung_deadline_s: float = 0.0,
        chaos_base_latency_s: float = 0.05,
        suspect_fraction: float = 0.5,
    ):
        assert replay in ("log", "none"), replay
        import jax  # deferred: callers set XLA_FLAGS before first jax use

        devs = list(devices) if devices is not None else list(jax.devices())
        need = n_slices * model_shards
        assert len(devs) >= need, (
            f"need {need} devices, have {len(devs)} - launch in a subprocess "
            "with XLA_FLAGS=--xla_force_host_platform_device_count=N"
        )
        self.base_mesh = mesh_from_devices(
            np.array(devs[:need]).reshape(n_slices, model_shards),
            ("data", "model"),
        )
        self.program = program
        program.session = self
        # n_spares slices are reserved as warm standbys: they hold devices
        # on the base mesh but no cmp/rep role (and sit outside the shrunk
        # mesh) until the heal plane converts them
        self.world = WorldState.create(n_slices, rdegree, n_spares=n_spares)
        self.healer = Healer(heal)
        self.last_repair: Dict = {}
        self.last_heal = None
        # ---- gray-failure layer ------------------------------------------
        # suspicion_window > 0 turns the liveness half of the control plane
        # ON: the dispatch loop drives a deterministic logical clock (1.0
        # per iteration), every live slice heartbeats with its dispatch
        # step as the progress mark, and check() raises on suspicion
        # expiry - a hung slice enters the SAME error handler as a crash.
        self._now = 0.0
        self._liveness = suspicion_window > 0
        self._suspected: set = set()
        self.chaos = (
            ChaosSchedule.parse(chaos) if isinstance(chaos, str)
            else chaos if isinstance(chaos, ChaosSchedule)
            else ChaosSchedule(chaos)
        )
        self.chaos_state = ChaosState()
        if self.chaos and not self._liveness:
            raise ValueError(
                "a chaos schedule needs the liveness layer: set "
                "suspicion_window > 0 so gray failures can be detected"
            )
        if self._liveness:
            self.control = ControlPlane(
                heartbeat_timeout=suspicion_window,
                progress_timeout=progress_window,
                suspect_fraction=suspect_fraction,
                clock=lambda: self._now,
            )
        else:
            self.control = ControlPlane(heartbeat_timeout=heartbeat_timeout)
        if stores is None:
            self.ladder = RecoveryLadder([])
        elif isinstance(stores, RecoveryLadder):
            self.ladder = stores
        elif isinstance(stores, StateStore):
            self.ladder = RecoveryLadder([stores])
        else:
            self.ladder = RecoveryLadder(list(stores))
        self.checkpoint_every = checkpoint_every
        self.replay = replay
        self.report = report if report is not None else FTReport()
        self.unit = unit
        #: repro.scrub.ScrubPlane (or None): records each submit's digest
        #: reference - the extra majority-vote holder - and carries the
        #: scrub tolerance the corruption handler classifies with
        self.scrub = scrub
        self._sdc_pending = None
        self._sdc_retried: set = set()
        self.generation = 0
        self.logs: Dict[int, StepLog] = {}
        self.reset_logs()
        self.mesh = None
        self._regenerate()
        # deadline-bounded recovery: per-rung restore budget, and the
        # chaos plane's per-peer latency handed to every store that can
        # spend it against the armed deadline
        if rung_deadline_s > 0:
            self.ladder.rung_deadline_s = float(rung_deadline_s)
        self._known_quarantines: set = set()
        if self._liveness:
            latency = ChaosLatency(
                self.chaos_state, lambda: self._now,
                base_s=chaos_base_latency_s,
            )
            for s in self.ladder:
                set_lat = getattr(s, "set_latency", None)
                if set_lat is not None:
                    set_lat(latency)
            self._register_liveness(progress=-1.0)

    # ------------------------------------------------------------------
    # the liveness loop (gray-failure detection)
    # ------------------------------------------------------------------
    def _register_liveness(self, progress: float) -> None:
        """(Re-)admit every live slice into the liveness tables at the
        CURRENT clock: on start, and after each shrink - survivors' beat
        times aged by a stall must not trip the detector the instant
        dispatch resumes. Mesh slices carry a progress mark; spares beat
        without one (a standby has no dispatch frontier to fall behind),
        so only silence can convict it."""
        if not self._liveness:
            return
        gen = self.control.generation
        for p in self.world.live_physicals():
            self.control.register(p, generation=gen, progress=progress)
        for p in self.world.spares:
            self.control.register(p, generation=gen)

    def _liveness_tick(self, step: int) -> bool:
        """One liveness round per dispatch-loop iteration: activate chaos
        events scheduled for ``step``, advance the logical clock, and beat
        every live slice the way its active injections allow - a dropped
        victim stays silent, a hung victim beats WITHOUT progress (the
        alive-but-wedged signature), everyone else beats at ``step``.
        Returns True when a live mesh slice is hung: the world cannot
        dispatch this iteration (the loop spins on the detector instead of
        running the step - exactly what a real hang does to its
        collective partners)."""
        if not self._liveness:
            return False
        for ev in self.chaos.take(step):
            self.chaos_state.activate(ev, self._now)
            self.report.events.append(
                f"{self.unit} {step}: chaos {ev.kind} victim={ev.victim} "
                f"duration={ev.duration} factor={ev.factor}"
            )
        self._now += 1.0
        live = set(self.world.live_physicals())
        spares = set(self.world.spares)
        hung = self.chaos_state.hung(self._now) & live
        dropped = self.chaos_state.dropped(self._now) & (live | spares)
        gen = self.control.generation
        for p in sorted(live | spares):
            if p in dropped:
                continue  # the liveness channel is eating this one's beats
            if p in hung or p in spares:
                self.control.heartbeat(p, generation=gen)
            else:
                self.control.heartbeat(p, progress=float(step), generation=gen)
        # flap accounting: a soft suspect that cleared before its window
        # expired was a false positive the detector correctly did NOT
        # shrink on
        current = {s.slice_id for s in self.control.suspects()}
        recovered = self._suspected - current - self.control.reported()
        for p in sorted(recovered):
            self.report.flaps += 1
            self.report.events.append(
                f"{self.unit} {step}: flap slice={p} recovered before the "
                "suspicion window expired (no shrink)"
            )
        self._suspected = (self._suspected | current) - recovered
        return bool(hung)

    def _collect_quarantines(self, step: int) -> None:
        """Surface store-level fail-slow quarantines into the report."""
        for s in self.ladder:
            for peer, reason in dict(getattr(s, "quarantined", {}) or {}).items():
                key = (s.name, peer)
                if key not in self._known_quarantines:
                    self._known_quarantines.add(key)
                    self.report.quarantines.append(
                        f"{self.unit} {step}: {s.name} peer={peer} ({reason})"
                    )

    # ------------------------------------------------------------------
    # lifecycle pieces
    # ------------------------------------------------------------------
    def _regenerate(self) -> None:
        """Communicator regeneration: shrink the base mesh to the live
        slices and have the program re-lower its step."""
        self.mesh = shrink_mesh(self.base_mesh, self.world.live_physicals())
        self.program.build_step(self.mesh, self.world)

    def reset_logs(self) -> None:
        self.logs = (
            {r: StepLog(r) for r in range(self.world.topo.n_slices)}
            if self.replay == "log"
            else {}
        )

    def inject(self, victims: Sequence[int]) -> None:
        """Report failed physical slices to the control plane (the fault
        injector / SIGCHLD path). Spares are killable too - a standby
        host dies like any other."""
        for victim in victims:
            if victim in self.world.assignment or victim in self.world.spares:
                self.control.report_failure(victim)
                self.report.failures += 1

    def _record(self, step: int) -> None:
        src = self.world.topo.mirror_source()
        for role in range(self.world.topo.n_slices):
            s0, s1 = self.program.sample_range(step, src[role])
            self.logs.setdefault(role, StepLog(role)).record(
                StepRecord(
                    step=step, sample_start=s0, sample_end=s1,
                    collective_seq=step,
                )
            )

    def _checkpoint(self, step: int) -> None:
        # programs with dirty tracking (the paged serving engine) submit
        # only what changed - and skip the tick entirely when nothing did
        dirty = getattr(self.program, "snapshot_dirty", None)
        snap = dirty() if dirty is not None else self.program.snapshot()
        if not self.ladder:
            return
        if snap is None:
            if dirty is not None:
                self.report.snapshots_skipped += 1
            return
        state, meta = snap
        # pipelined: mutable leaves are captured synchronously, the
        # staging + store placement overlap the next dispatch unit on the
        # ladder's transfer plane (drained by recover() and run())
        self.ladder.submit_async(step, state, {"step": step, **meta})
        if self.scrub is not None:
            # the scrub plane digests the same submit (the program narrows
            # the tree to what the in-step scrub tables cover, e.g. params)
            view = getattr(self.program, "scrub_view", None)
            narrowed = view(state) if view else state
            if isinstance(narrowed, PagedBlob):
                self.scrub.record_pages(step, narrowed)
            else:
                self.scrub.record_submit(step, narrowed)

    def _restore(self) -> Optional[int]:
        """Walk the recovery ladder (cheapest surviving level first).
        Returns the restored step, or ``None`` when no level holds a
        recoverable snapshot - the caller decides between fresh-init
        (trainers) and resume-in-place (servers)."""
        snap = self.program.snapshot()
        if snap is None or not self.ladder:
            return None
        template, _ = snap
        got = self.ladder.restore(template)
        if got is None:
            return None
        self.program.restore(got.state, got.meta)
        # e.g. "L2:durable@step8[chain:3]" when the durable rung resolved
        # an on-disk delta chain across 3 step dirs
        tag = f"L{got.level}:{got.store}@step{got.step}"
        if got.detail:
            tag += f"[{got.detail}]"
        self.report.restored_from.append(tag)
        return got.step

    # ------------------------------------------------------------------
    # the error handler (paper Sec. VI)
    # ------------------------------------------------------------------
    def recover(self, step: int) -> Tuple[Dict, ReplayPlan]:
        """revoke -> agree -> repair -> (restore) -> heal -> repack ->
        regenerate -> message recovery. Returns (repair report, replay
        plan)."""
        t0 = time.perf_counter()
        # the recovery window reuses the transfer plane's barrier: any
        # pipelined submit still in flight lands BEFORE on_failure drops
        # dead holders and the restore walk consults the levels (the same
        # ordering the old synchronous submit gave for free). With the
        # gray-failure layer on, the barrier is BOUNDED by the rung
        # deadline: a wedged background submit must not eat the recovery
        # window - the walk restores from what already persisted.
        drain_timeout = (
            self.ladder.rung_deadline_s or None if self._liveness else None
        )
        if not self.ladder.drain(drain_timeout):
            self.report.events.append(
                f"{self.unit} {step}: stager wedged past {drain_timeout}s "
                "- recovering from already-persisted snapshots"
            )
        explicit = self.control.reported()
        self.control.revoke()
        failed = self.control.agree()
        # suspicion-expired failures (no explicit report): record what the
        # detector found and how long it took from injection to here
        for f in sorted(failed - explicit):
            self.report.failures += 1
            sus = next(
                (s for s in self.control.suspects() if s.slice_id == f), None)
            reason = sus.reason if sus is not None else "silence"
            self.report.detections.append(
                f"{'hang' if reason == 'stall' else 'silence'}:{f}")
            t_inj = self.chaos_state.start_time(f)
            self.report.detect_latency.append(
                self._now - t_inj if t_inj is not None else -1.0
            )
        self._suspected -= failed
        old_world = self.world
        # spare backfill preserves a lost role only if its state can be
        # re-established: trainers replay deterministically even from a
        # fresh init, servers need a recoverable snapshot in the ladder -
        # unless the program declares ``reinit_roles`` (the serving
        # gateway re-prefills a backfilled role's requests from their
        # pinned prefixes, so a zeroed slot is a valid starting state)
        use_spares = self.healer.enabled and (
            self.replay == "log"
            or bool(self.ladder)
            or getattr(self.program, "reinit_roles", False)
        )
        new_world, rep = old_world.repair(sorted(failed), use_spares=use_spares)
        self.last_repair = rep
        restored_step: Optional[int] = None

        # memory-resident store levels lose state that lived on the dead
        # hosts - told BEFORE the restore walk consults them
        self.ladder.on_failure(sorted(failed))

        self.report.promotes += len(rep["promoted"])
        if rep["lost_cmp"] or rep["backfilled"]:
            # unrecoverable by replication: walk the recovery ladder; the
            # trainers' last resort is a fresh init, servers without a
            # recoverable snapshot resume in place with the roles dropped.
            # (A backfilled role kept its id on a spare, but its state is
            # equally gone - same restore walk, no elastic shrink.)
            self.report.restarts += 1
            self.report.interruptions.append(step)
            restored_step = self._restore()
            if restored_step is None and self.replay == "log":
                self.program.init_fresh()
                restored_step = -1

        # re-replication (repro.heal): convert spares into replicas of the
        # most-exposed roles, so the next re-lower compiles the healed
        # topology; the clone source is the (possibly just-restored) state.
        # Backfilled spares ride the same partner-ring registration +
        # shard re-placement pass (AFTER the restore walk - the walk needs
        # the pre-heal placement; ONE rebalance per recovery window)
        self.last_heal = None
        if self.healer.enabled:
            new_world, hplan = self.healer.maybe_heal(
                new_world,
                snapshot=self.program.snapshot(),
                stores=self.ladder,
                step=step,
                extra_peers=[p for _, p in rep["backfilled"]],
            )
            if hplan:
                self.last_heal = hplan
                self.report.healed_replicas += len(hplan.actions)
                self.report.heals.append(f"{self.unit} {step}: {hplan.describe()}")

        # message recovery plan from the SURVIVORS' logs (paper Sec. VI-B:
        # "identify the collectives that every live process has completed")
        # - computed before the logs are re-keyed for the new world.
        if self.replay == "log":
            survivor_roles = [
                r
                for r in range(old_world.topo.n_slices)
                if old_world.assignment[r] not in failed
            ]
            live_logs = [self.logs[r] for r in survivor_roles if r in self.logs]
            plan = replay_plan(live_logs, step, restored_step=restored_step)
        elif restored_step is not None:
            # a server restored from the store plane: re-decode from the
            # snapshot so its state and output stream stay consistent
            plan = ReplayPlan(
                start_step=min(restored_step + 1, step), skip={},
                reason=f"store restore from step {restored_step}",
            )
        else:
            plan = ReplayPlan(start_step=step, skip={}, reason="resume in place")

        self.program.repack_state(old_world, new_world)
        self.world = new_world
        self.reset_logs()
        for log in self.logs.values():
            log.applied.update(range(0, plan.start_step))
        self._regenerate()
        self.control.shrink_complete(failed)
        self.generation = new_world.generation
        # survivors re-enter the liveness tables at the CURRENT clock (a
        # stall aged their last beats; the new window starts now), and any
        # fail-slow peer the restore walk quarantined is surfaced
        self._register_liveness(progress=float(step))
        self._collect_quarantines(step)
        # recovery-window notification (the serving gateway's failover
        # hook): the program sees the repair outcome + replay plan BEFORE
        # replay, so it can requeue in-flight requests from lost roles,
        # remap its slot table through ``rep["role_map"]``, and re-derive
        # capacity from the healed world - all while the window is closed
        on_recover = getattr(self.program, "on_recover", None)
        if on_recover is not None:
            on_recover(old_world, new_world, rep, plan)
        self.program.replay_inputs(plan)
        self.report.handler_seconds += time.perf_counter() - t0
        self.report.events.append(
            f"{self.unit} {step}: failed={sorted(failed)} "
            f"promoted={rep['promoted']} lost={rep['lost_cmp']} "
            f"backfilled={rep['backfilled']} "
            f"healed={[(a.cmp_role, a.spare) for a in self.last_heal.actions] if self.last_heal else []} "
            f"rdegree={self.world.topo.rdegree:.2f} "
            f"plan={plan.reason}@{plan.start_step}"
        )
        return rep, plan

    # ------------------------------------------------------------------
    # the corruption handler (beyond-paper: repro.scrub)
    # ------------------------------------------------------------------
    def report_corruption(self, step: int, evidence) -> None:
        """Called by the program from inside ``run_step`` when the step's
        scrub metrics flagged a mirrored-pair digest mismatch (a
        :class:`repro.scrub.ScrubEvidence`). The dispatch loop enters
        :meth:`recover_corruption` before counting the unit as done."""
        self._sdc_pending = evidence

    def recover_corruption(self, step: int) -> int:
        """detect -> classify -> vote -> (partial) restore -> replay.

        The poisoned update never landed (the data plane's corruption gate
        freezes params/opt on detection), so:

        - grad-space-only mismatch (param digest tables agree): transient
          flip - retry the unit once; it recurring escalates;
        - param-space mismatch (or a repeat): persistent - a majority vote
          over the param digest table + the scrub plane's last-submit
          reference names the victim, and the ladder's digest-guided
          partial restore moves ONLY the chunks whose bytes differ from
          the victim's view (``FTReport.sdc_bytes_moved`` vs the full
          blob). An inconclusive vote or unsupported ladder falls back to
          the full-blob restore walk. Either way the trainer replays from
          the restored step, reproducing the failure-free trajectory.

        Returns the step to resume dispatch from.
        """
        from repro.scrub.vote import majority_vote, mismatched_pairs

        ev, self._sdc_pending = self._sdc_pending, None
        t0 = time.perf_counter()
        self.report.sdc_detected += 1
        # any pipelined submit must land before the handler consults or
        # diffs against the stores (same barrier as the fail-stop window)
        self.ladder.drain()
        tol = float(getattr(self.scrub, "tol", 0.0) or 0.0)
        bad_pairs = (
            mismatched_pairs(ev.param_table, ev.pairs, tol=tol)
            if ev.param_table is not None and len(ev.param_table) else []
        )
        if not bad_pairs and step not in self._sdc_retried:
            # gradients disagreed but every param digest row matches: the
            # state is clean on all mirrors - a transient compute flip.
            # Retry the unit once; a deterministic step reruns clean.
            self._sdc_retried.add(step)
            self.report.sdc_transient += 1
            self.report.events.append(
                f"{self.unit} {step}: sdc-transient retry (sdc={ev.sdc:.3g})"
            )
            self.report.handler_seconds += time.perf_counter() - t0
            return step

        verdict = None
        if bad_pairs:
            reference = getattr(self.scrub, "reference", None)
            verdict = majority_vote(
                ev.param_table, bad_pairs[0], reference=reference, tol=tol
            )
        self.report.sdc_repairs += 1
        restored_step: Optional[int] = None
        if verdict is not None and verdict.conclusive and self.ladder:
            view_fn = getattr(self.program, "corrupted_view", None)
            got = self.ladder.restore_partial(
                view_fn()) if view_fn is not None else None
            if got is not None:
                self.program.restore(got.state, got.meta)
                restored_step = got.step
                self.report.sdc_bytes_moved += got.moved_bytes
                self.report.sdc_bytes_full += got.total_bytes
                self.report.restored_from.append(
                    f"L{got.level}:{got.store}@step{got.step}"
                    f"[partial:{got.moved_chunks}/{got.n_chunks}]"
                )
                self.report.events.append(
                    f"{self.unit} {step}: sdc-repair victim={verdict.victim} "
                    f"({verdict.reason}) chunks={verdict.poisoned_chunks.tolist()} "
                    f"moved={got.moved_bytes}/{got.total_bytes}B"
                )
        if restored_step is None:
            # inconclusive vote / no chunk-manifest level / layout drift:
            # corruption is never "probably fine" - full-blob restore
            self.report.restarts += 1
            restored_step = self._restore()
            if restored_step is None and self.replay == "log":
                self.program.init_fresh()
                restored_step = -1
            self.report.events.append(
                f"{self.unit} {step}: sdc-restart "
                f"({verdict.reason if verdict else 'no param mismatch'}) "
                f"restored_step={restored_step}"
            )
        clear = getattr(self.program, "clear_corruption", None)
        if clear is not None:
            clear(verdict)
        # same world, same mesh - but the restored state is host-resident:
        # one build_step re-places it (and re-lowers against the unchanged
        # groups), the corruption path's analogue of _regenerate
        self.program.build_step(self.mesh, self.world)

        if self.replay == "log":
            live_logs = [self.logs[r] for r in sorted(self.logs)]
            plan = replay_plan(live_logs, step, restored_step=restored_step)
        elif restored_step is not None and restored_step >= 0:
            plan = ReplayPlan(
                start_step=min(restored_step + 1, step), skip={},
                reason=f"sdc restore from step {restored_step}",
            )
        else:
            plan = ReplayPlan(start_step=step, skip={}, reason="sdc resume")
        self.reset_logs()
        for log in self.logs.values():
            log.applied.update(range(0, plan.start_step))
        self.program.replay_inputs(plan)
        self._collect_quarantines(step)
        self.report.handler_seconds += time.perf_counter() - t0
        return max(plan.start_step, 0)

    # ------------------------------------------------------------------
    # the dispatch loop (paper Fig. 7)
    # ------------------------------------------------------------------
    def run(
        self,
        steps: int,
        failures: Union[None, FailureSchedule, Mapping[int, Sequence[int]]] = None,
        *,
        start_step: int = 0,
    ) -> FTReport:
        """Dispatch units ``start_step .. steps-1``, injecting scheduled
        failures at unit boundaries (a communication-time detection) and
        recovering through :meth:`recover` on revocation."""
        schedule = (
            failures
            if isinstance(failures, FailureSchedule)
            else FailureSchedule(failures)
        )
        step = start_step
        while step < steps:
            self.inject(schedule.take(step))
            # one liveness round per iteration: chaos events activate,
            # the logical clock ticks, live slices beat. A hung mesh
            # slice stalls the world (no dispatch this iteration) - the
            # loop spins on the detector until suspicion expires and
            # check() raises, exactly like its collective partners would
            stalled = self._liveness_tick(step)
            try:
                self.control.check(self.generation)
            except (CommunicatorRevoked, ProcessFailed):
                _, plan = self.recover(step)
                replay_from = max(plan.start_step, 0)
                self.report.replayed_steps += max(0, step - replay_from)
                step = replay_from
                continue
            if stalled:
                self.report.stalled_units += 1
                continue

            t0 = time.perf_counter()
            self.program.run_step(step)
            self.report.app_seconds += time.perf_counter() - t0
            if self._sdc_pending is not None:
                # the scrub flagged this unit mid-step: its update was
                # gated in-graph, so it is NOT complete - classify and
                # repair, then resume (retry or replay) where the handler
                # says
                resume = self.recover_corruption(step)
                self.report.replayed_steps += max(0, step - resume)
                step = resume
                continue
            self.report.steps_completed += 1
            # time-at-risk: every unit dispatched below the configured
            # replication target accrues its mirror deficit
            self.report.exposure_steps += self.world.replica_deficit()
            if self.replay == "log":
                self._record(step)
            if (
                self.checkpoint_every
                and step > 0
                and step % self.checkpoint_every == 0
            ):
                self._checkpoint(step)
            step += 1
        # drain the transfer plane + background writers: the newest
        # snapshots must not die with the process on a daemon thread
        self.ladder.drain()
        return self.report
