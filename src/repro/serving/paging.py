"""Paged decode state: the serving KV cache as a table of token pages.

The dense serving cache ships whole per-role blobs every ``snapshot_every``
tick; this module re-layouts the decode state the way the paper's Sec. V
splits messages - into fixed-size parts that move independently - and
makes the parts *literally* the transfer plane's chunks. Every ``(slot,
leaf-group)`` pair owns a run of fixed-size token pages (``page_tokens``
positions each); the :class:`PageTable` tracks which pages exist, which
are dirty since the last submit, and which are shared:

- **append-only decode dirties only the tail page**: a step writes one
  position per active slot, so between cadence ticks only the page(s)
  covering ``[snap_count, count)`` change - everything else zero-encodes
  by key in ``xfer.delta`` and ships nothing (ReStore sub-blocking at the
  granularity where it actually pays);
- **windowed (ring) caches page over ring rows**: a leaf whose time
  capacity is the attention window wraps its writes (``pos % Smax``), so
  pages cover ring rows and the dirty set follows the modular write
  window - the same table, no special case downstream;
- **reset is a table edit**: freeing a slot drops its pages from the
  table and bumps the slot's owner uid - no full-tree ``at[].set(0)``
  rebuild (recurrent SSM/conv block leaves still zero on device: masking
  cannot hide a previous occupant's recurrent state);
- **prompt-prefix pages are shared**: pages that lie entirely inside a
  request's prompt are content-addressed by the token prefix that
  produced them (causal attention: K/V at position t depends only on
  tokens <= t), so concurrent requests with a common prompt prefix submit
  ONE copy. Shared pages are sealed by construction (non-ring leaves
  never rewrite a position) and refcounted across slots.

Page keys are the stable chunk identities the keyed delta encoder and the
durable chain anchors match on::

    {leaf_path}##u{uid}#p{idx}     private page of a slot (owner uid)
    {leaf_path}##h{prefix_hash}#p{idx}   shared prompt-prefix page
    {leaf_path}##u{uid}#blk        a slot's whole non-time block (SSM/cross)

The table also keeps the HOST page cache (``pages``): sealed host copies
the engine gathered from device. Entries are immutable once stored (dirty
pages are *rebound* to fresh gathers, never mutated in place), which is
the contract that lets ``xfer.plane`` stage a :class:`~repro.xfer.PagedBlob`
by reference instead of copying the whole state every tick.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np


@dataclass(frozen=True)
class CacheLeaf:
    """One cache leaf's paging geometry. ``smax`` is the time capacity
    (None for block leaves without a token axis - SSM conv/state, cross
    K/V); ``ring`` marks windowed leaves whose writes wrap at ``smax``."""

    path: str
    batch_axis: int
    smax: Optional[int]
    ring: bool


@dataclass(frozen=True)
class PageRef:
    """One page of one slot: where it lives in the dense layout and what
    key it submits under. ``t0``/``t1`` bound the time slice (None for
    block pages); ``shared`` marks content-addressed prompt-prefix pages."""

    key: str
    leaf: CacheLeaf
    index: int
    t0: Optional[int]
    t1: Optional[int]
    shared: bool


@dataclass
class SlotEntry:
    role: int
    lane: int
    uid: int
    count: int = 0
    #: host-page-cache freshness: what the last GATHER saw (dirty tracking)
    snap_count: int = 0
    snap_uid: int = -1
    #: ladder freshness: what the last SUBMIT shipped (settled tracking -
    #: the scrub plane may only compare pages the reference actually covers)
    sub_count: int = 0
    sub_uid: int = -1
    prompt_len: int = 0
    #: page index -> prefix hash, for pages shared across same-prompt slots
    shared: Dict[int, str] = field(default_factory=dict)
    #: prompt tokens (int list) while known; a restore rebuilds entries
    #: from meta without them (the recorded ``shared`` hashes keep existing
    #: shared keys stable; new pages simply stay private)
    prompt: Optional[List[int]] = None


def dirty_page_indices(c0: int, c1: int, smax: int, page: int) -> Set[int]:
    """Pages whose rows were written advancing a slot from ``c0`` to
    ``c1`` tokens in a ring of capacity ``smax``. For non-ring leaves
    (``smax`` >= any count) this is just the tail page(s); a wrap marks
    the modular write window; advancing a full ring marks every page."""
    if c1 <= c0:
        return set()
    if c1 - c0 >= smax:
        live_end = min(c1, smax)
        return set(range(-(-live_end // page)))
    a, b = c0 % smax, (c1 - 1) % smax
    spans = [(a, b)] if a <= b else [(0, b), (a, smax - 1)]
    out: Set[int] = set()
    for lo, hi in spans:
        out.update(range(lo // page, hi // page + 1))
    return out


def prefix_hash(tokens: Sequence[int], n: int) -> str:
    """Content address of the first ``n`` prompt tokens (the pages they
    produced are identical across slots - causal attention)."""
    arr = np.asarray(list(tokens[:n]), dtype=np.int64)
    return hashlib.sha1(arr.tobytes()).hexdigest()[:16]


class PageTable:
    """Slot -> page mapping + the sealed host page cache."""

    def __init__(self, page_tokens: int, *, prefix_share: bool = True):
        assert page_tokens > 0 and (page_tokens & (page_tokens - 1)) == 0, (
            f"page_tokens must be a positive power of two, got {page_tokens}"
        )
        self.page_tokens = int(page_tokens)
        self.prefix_share = bool(prefix_share)
        self.leaves: List[CacheLeaf] = []
        self.slots: Dict[Tuple[int, int], SlotEntry] = {}
        #: sealed host pages, keyed; entries are rebound, never mutated
        self.pages: Dict[str, np.ndarray] = {}
        #: shared-page refcounts: how many slots list the key in .shared
        self.refs: Dict[str, int] = {}
        self._uid_next = 0
        self._snap_sig: Optional[Tuple] = None

    # ---- geometry ----------------------------------------------------------
    def configure(self, leaves: Iterable[CacheLeaf]) -> None:
        self.leaves = list(leaves)

    # ---- slot lifecycle ----------------------------------------------------
    def ensure(self, role: int, lane: int) -> SlotEntry:
        e = self.slots.get((role, lane))
        if e is None:
            e = SlotEntry(role=role, lane=lane, uid=self._uid_next)
            self._uid_next += 1
            self.slots[(role, lane)] = e
        return e

    def note_prompt(self, role: int, lane: int, tokens: Sequence[int]) -> None:
        """Record a freshly-admitted request's prompt so pages fully inside
        it can be content-addressed and shared."""
        e = self.ensure(role, lane)
        e.prompt = [int(t) for t in tokens]
        e.prompt_len = len(e.prompt)

    def reset(self, slots: Iterable[Tuple[int, int]]) -> None:
        """Free slots: drop their private pages, release shared refs, bump
        the owner uid so the next occupant's pages get fresh keys."""
        for role, lane in slots:
            e = self.ensure(role, lane)
            self._drop_entry_pages(e)
            e.uid = self._uid_next
            self._uid_next += 1
            e.count = 0
            e.snap_count = 0
            e.snap_uid = -1
            e.sub_count = 0
            e.sub_uid = -1
            e.prompt_len = 0
            e.prompt = None
            e.shared = {}

    def _drop_entry_pages(self, e: SlotEntry) -> None:
        own = f"#u{e.uid}#"
        for k in [k for k in self.pages if own in k]:
            del self.pages[k]
        for p, h in e.shared.items():
            for leaf in self.leaves:
                key = self._shared_key(leaf, h, p)
                n = self.refs.get(key, 0) - 1
                if n <= 0:
                    self.refs.pop(key, None)
                    self.pages.pop(key, None)
                else:
                    self.refs[key] = n

    def remap(self, keep: Sequence[int], lanes: int) -> None:
        """Re-key slots after an elastic repack: new cmp role ``r``
        continues old role ``keep[r]``'s slots (uids - and therefore page
        keys - survive the renumbering, so the next submit still
        zero-encodes everything the failover did not touch). Slots of
        roles that did not survive drop their pages."""
        old = dict(self.slots)
        kept: Dict[Tuple[int, int], SlotEntry] = {}
        used: Set[Tuple[int, int]] = set()
        for r, old_r in enumerate(keep):
            for lane in range(lanes):
                e = old.get((old_r, lane))
                if e is not None:
                    used.add((old_r, lane))
                    e.role = r
                    kept[(r, lane)] = e
        for key, e in old.items():
            if key not in used:
                self._drop_entry_pages(e)
        self.slots = kept

    def invalidate(self) -> None:
        """Drop every sealed host page and force a full re-gather at the
        next snapshot: a repack/restore rewrote dense rows underneath the
        page cache (live bytes unchanged, masked tails zero-filled), so
        cached copies can no longer stand in for the device truth."""
        self.pages.clear()
        self._snap_sig = None
        for e in self.slots.values():
            e.snap_count = 0
            e.snap_uid = -1
            e.sub_count = 0
            e.sub_uid = -1

    # ---- keys --------------------------------------------------------------
    @staticmethod
    def _shared_key(leaf: CacheLeaf, h: str, index: int) -> str:
        return f"{leaf.path}##h{h}#p{index}"

    def _page_key(self, leaf: CacheLeaf, e: SlotEntry, index: int) -> Tuple[str, bool]:
        if (
            self.prefix_share
            and not leaf.ring
            and leaf.smax is not None
            and index in e.shared
        ):
            return self._shared_key(leaf, e.shared[index], index), True
        return f"{leaf.path}##u{e.uid}#p{index}", False

    # ---- page enumeration --------------------------------------------------
    def slot_pages(self, e: SlotEntry) -> List[PageRef]:
        """Every live page of one slot, in layout order."""
        P = self.page_tokens
        out: List[PageRef] = []
        for leaf in self.leaves:
            if leaf.smax is None:
                if e.count > 0:
                    out.append(PageRef(
                        key=f"{leaf.path}##u{e.uid}#blk", leaf=leaf,
                        index=0, t0=None, t1=None, shared=False,
                    ))
                continue
            live_end = min(e.count, leaf.smax)
            for p in range(-(-live_end // P)):
                key, shared = self._page_key(leaf, e, p)
                out.append(PageRef(
                    key=key, leaf=leaf, index=p,
                    t0=p * P, t1=min((p + 1) * P, leaf.smax), shared=shared,
                ))
        return out

    def _refresh_sharing(self, e: SlotEntry) -> None:
        """(Re)derive which of a slot's page indices are shareable: pages
        fully inside the prompt, on non-ring leaves. Ref-counted per leaf
        when first claimed."""
        if not self.prefix_share or e.prompt is None:
            return
        P = self.page_tokens
        for p in range(e.prompt_len // P):
            if p in e.shared:
                continue
            h = prefix_hash(e.prompt, (p + 1) * P)
            e.shared[p] = h
            for leaf in self.leaves:
                if leaf.smax is not None and not leaf.ring:
                    key = self._shared_key(leaf, h, p)
                    self.refs[key] = self.refs.get(key, 0) + 1

    def dirty_refs(self, e: SlotEntry) -> List[PageRef]:
        """The pages of ``e`` the next snapshot must gather fresh from
        device: pages written since the last submit, pages of a new owner
        uid, and pages missing from the host cache (post-invalidate).
        Sealed shared pages another slot already gathered are skipped."""
        self._refresh_sharing(e)
        fresh_owner = e.snap_uid != e.uid
        out: List[PageRef] = []
        for ref in self.slot_pages(e):
            if ref.shared and ref.key in self.pages:
                continue  # sealed + already gathered (possibly by a twin)
            if ref.key not in self.pages or fresh_owner:
                out.append(ref)
                continue
            if ref.leaf.smax is None:
                if e.count != e.snap_count:
                    out.append(ref)
                continue
            dirty = dirty_page_indices(
                e.snap_count, e.count, ref.leaf.smax, self.page_tokens
            )
            if ref.index in dirty:
                out.append(ref)
        return out

    # ---- submit bookkeeping ------------------------------------------------
    def signature(self) -> Tuple:
        return tuple(sorted(
            (r, l, e.uid, e.count) for (r, l), e in self.slots.items()
        ))

    def clean(self) -> bool:
        """True when the page set and every page's content are unchanged
        since the last :meth:`mark_submitted` - the cadence-skip test."""
        return self._snap_sig is not None and self.signature() == self._snap_sig

    def mark_gathered(self) -> None:
        """The host page cache now mirrors the live state (a snapshot()
        gather for a restore template or heal - NOT a ladder submit, so
        the cadence-skip signature is untouched)."""
        for e in self.slots.values():
            e.snap_count = e.count
            e.snap_uid = e.uid

    def mark_submitted(self) -> None:
        self.mark_gathered()
        for e in self.slots.values():
            e.sub_count = e.count
            e.sub_uid = e.uid
        self._snap_sig = self.signature()

    def settled_refs(self, e: SlotEntry) -> List[PageRef]:
        """The pages of ``e`` whose bytes are STABLE since the last ladder
        submit - the only pages the scrub plane's reference crcs can
        legitimately be compared against (a page the decode loop has
        since rewritten differs for honest reasons)."""
        if e.sub_uid != e.uid:
            return []
        out: List[PageRef] = []
        for ref in self.slot_pages(e):
            if ref.shared:
                out.append(ref)  # sealed by construction
                continue
            if ref.leaf.smax is None:
                if e.count == e.sub_count:
                    out.append(ref)
                continue
            dirty = dirty_page_indices(
                e.sub_count, e.count, ref.leaf.smax, self.page_tokens
            )
            if ref.index not in dirty:
                out.append(ref)
        return out

    # ---- invariants (the property tests' oracle) ---------------------------
    def check_invariants(self) -> None:
        """Slot->page bijection: every private page key belongs to exactly
        one live slot; shared refcounts match the slots listing them; no
        orphaned page bytes."""
        owners: Dict[str, Tuple[int, int]] = {}
        live_keys: Set[str] = set()
        want_refs: Dict[str, int] = {}
        for (r, l), e in self.slots.items():
            for ref in self.slot_pages(e):
                if ref.shared:
                    live_keys.add(ref.key)
                    continue
                prev = owners.get(ref.key)
                assert prev is None or prev == (r, l), (
                    f"page {ref.key} double-owned by {prev} and {(r, l)}"
                )
                owners[ref.key] = (r, l)
                live_keys.add(ref.key)
            for p, h in e.shared.items():
                for leaf in self.leaves:
                    if leaf.smax is not None and not leaf.ring:
                        want_refs[self._shared_key(leaf, h, p)] = (
                            want_refs.get(self._shared_key(leaf, h, p), 0) + 1
                        )
        for key, n in self.refs.items():
            assert want_refs.get(key) == n, (
                f"refcount drift for {key}: table={n} slots={want_refs.get(key)}"
            )
        for key in self.pages:
            assert key in live_keys or key in self.refs, (
                f"orphaned page bytes: {key}"
            )

    # ---- meta (JSON-safe, rides the snapshot manifests) --------------------
    def to_meta(self, rows: Dict[Tuple[int, int], int],
                mirror_rows: Dict[Tuple[int, int], int],
                n_rows: int) -> Dict:
        return {
            "page_tokens": self.page_tokens,
            "n_rows": int(n_rows),
            "slots": [
                {
                    "role": e.role, "lane": e.lane, "uid": e.uid,
                    "count": e.count, "prompt_len": e.prompt_len,
                    "row": int(rows[(e.role, e.lane)]),
                    "mirror_row": int(mirror_rows.get((e.role, e.lane), -1)),
                    "shared": {str(p): h for p, h in e.shared.items()},
                }
                for e in sorted(
                    self.slots.values(), key=lambda e: (e.role, e.lane)
                )
            ],
        }

    def load_meta(self, meta: Dict) -> None:
        """Adopt a snapshot's slot table (restore path). Page bytes are NOT
        adopted here - the engine scatters them into the dense cache and
        the next snapshot re-gathers (:meth:`invalidate` semantics)."""
        self.slots = {}
        self.pages.clear()
        self.refs.clear()
        self._snap_sig = None
        top = 0
        for s in meta["slots"]:
            e = SlotEntry(
                role=int(s["role"]), lane=int(s["lane"]), uid=int(s["uid"]),
                count=int(s["count"]), prompt_len=int(s["prompt_len"]),
                shared={int(p): h for p, h in s.get("shared", {}).items()},
            )
            self.slots[(e.role, e.lane)] = e
            top = max(top, e.uid + 1)
            for p, h in e.shared.items():
                for leaf in self.leaves:
                    if leaf.smax is not None and not leaf.ring:
                        key = self._shared_key(leaf, h, p)
                        self.refs[key] = self.refs.get(key, 0) + 1
        self._uid_next = max(self._uid_next, top)
