"""Batched serving engine with replica failover.

The serving analogue of the paper's replication: replica slices mirror
their partner's request stream (same tokens, same order), so their KV
caches / SSM states are bit-identical. When a computational slice dies,
the promoted replica continues decoding from its own live cache: requests
lose NOTHING - no prefill re-run, no token loss. Unreplicated slice
failures re-queue their requests (prefill re-run after elastic shrink).

The engine is a thin :class:`~repro.ft.program.ResilientProgram`: the
detect/revoke/agree/repair lifecycle lives in FTSession (``replay='none'``
- a server resumes in place); this module supplies only the decode data
plane and the serving-specific hook - ``repack_state``, which re-packs
cache rows so promoted replicas keep their mirrored caches across the
elastic shrink.

The decode step itself has no cross-slice collectives (the model axis is
GSPMD-managed), so the data plane stays failure-oblivious, exactly like the
paper's native-MPI plane.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs.base import ModelConfig, ReplicationConfig
from repro.core import data_plane as DP
from repro.dist.sharding import (
    cache_batch_axis,
    cache_shardings,
    param_shardings,
    path_str,
)
from repro.ft import FailureSchedule, FTReport, FTSession, ResilientProgram
from repro.models import model as M


@dataclass
class ServeReport(FTReport):
    """FTReport + serving counters. ``decode_seconds``/``failover_seconds``
    are the serving names for the unified app/handler split."""

    tokens_decoded: int = 0
    requeued_requests: int = 0

    @property
    def decode_seconds(self) -> float:
        return self.app_seconds

    @property
    def failover_seconds(self) -> float:
        return self.handler_seconds


class ServeEngine(ResilientProgram):
    def __init__(
        self,
        model_cfg: ModelConfig,
        *,
        n_slices: int,
        model_shards: int = 1,
        rdegree: float = 0.0,
        per_slice_batch: int = 2,
        max_len: int = 128,
        seed: int = 0,
        params=None,
    ):
        self.model_cfg = model_cfg
        self.repl = ReplicationConfig(rdegree=rdegree)
        self.per_slice_batch = per_slice_batch
        self.max_len = max_len
        self.params_host = params or M.init(jax.random.PRNGKey(seed), model_cfg)
        self.cache = None  # device cache after build_step; host copy mid-repair
        self.pos = 0
        self._cur: Optional[np.ndarray] = None
        self._out: List[np.ndarray] = []

        self.session = FTSession(
            self,
            n_slices=n_slices,
            model_shards=model_shards,
            rdegree=rdegree,
            replay="none",
            report=ServeReport(),
            unit="token",
        )

    # ---- convenience views over the session --------------------------------
    @property
    def world(self):
        return self.session.world

    @property
    def mesh(self):
        return self.session.mesh

    @property
    def report(self) -> ServeReport:
        return self.session.report

    @property
    def generation(self) -> int:
        return self.session.generation

    # ------------------------------------------------------------------
    # ResilientProgram hooks
    # ------------------------------------------------------------------
    def build_step(self, mesh, world) -> None:
        with set_mesh(mesh):
            pshard = param_shardings(self.params_host, mesh, self.model_cfg)
            self.params = jax.device_put(self.params_host, pshard)
            if self.cache is None:
                enc_len = 64 if self.model_cfg.enc_layers else 0
                cache_host = M.init_cache(
                    self.model_cfg,
                    world.topo.n_slices * self.per_slice_batch,
                    max_len=self.max_len,
                    enc_len=enc_len,
                    dtype=jnp.float32,
                )
            else:
                cache_host = self.cache  # survivors' mirrored caches (host copy)
            cshard = cache_shardings(cache_host, mesh, shard_batch=True)
            self.cache = jax.device_put(cache_host, cshard)
            self.step_fn = DP.build_serve_step(
                self.model_cfg, self.repl, mesh, world,
                shard_batch=True, donate=False, cache_example=self.cache,
            )

    def run_step(self, t: int) -> None:
        fed = self._mirror_tokens(self._cur)
        with set_mesh(self.mesh):
            next_fed, self.cache = self.step_fn(
                self.params, self.cache, jnp.asarray(fed), jnp.int32(self.pos)
            )
        next_fed = np.asarray(next_fed)
        # computational slices' outputs are authoritative
        order = self.world.roles_in_mesh_order()
        n_comp = self.world.topo.n_comp
        by_role = {
            r: next_fed[i * self.per_slice_batch : (i + 1) * self.per_slice_batch]
            for i, r in enumerate(order)
        }
        cmp_next = np.stack([by_role[c] for c in range(n_comp)])
        self._out.append(cmp_next[..., 0])
        self._cur = cmp_next
        self.pos += 1
        self.report.tokens_decoded += n_comp * self.per_slice_batch

    def repack_state(self, old_world, new_world) -> None:
        """Promoted replicas keep their caches: re-pack cache rows so the
        new mesh order draws each role's cache from the physical slice that
        now owns it; unreplicated losses re-queue their requests."""
        cache_host = jax.tree.map(np.asarray, self.cache)  # survivors' caches
        old_pos = old_world.mesh_position()
        new_order = new_world.roles_in_mesh_order()
        b = self.per_slice_batch

        def repack(path, arr):
            axis = cache_batch_axis(path, arr.ndim)
            rows = []
            for r in new_order:
                phys = new_world.assignment[r]
                src_row = old_pos[phys]
                rows.append(
                    np.take(arr, range(src_row * b, (src_row + 1) * b), axis=axis)
                )
            return np.concatenate(rows, axis=axis)

        flat, treedef = jax.tree_util.tree_flatten_with_path(cache_host)
        self.cache = jax.tree_util.tree_unflatten(
            treedef, [repack(path_str(kp), leaf) for kp, leaf in flat]
        )
        lost_roles = old_world.topo.n_comp - new_world.topo.n_comp
        self.report.requeued_requests += lost_roles * b
        if self._cur is not None:
            self._cur = self._cur[: new_world.topo.n_comp]

    # ------------------------------------------------------------------
    def _mirror_tokens(self, cmp_tokens: np.ndarray) -> np.ndarray:
        """Lay out per-cmp-slice request tokens in mesh order, mirroring the
        partner's stream onto replica slices."""
        src = self.world.topo.mirror_source()
        order = self.world.roles_in_mesh_order()
        return np.concatenate([cmp_tokens[src[r]] for r in order], axis=0)

    def decode(self, steps: int, prompt_tokens: Optional[np.ndarray] = None,
               failures: Optional[Dict[int, List[int]]] = None) -> np.ndarray:
        """Greedy-decode ``steps`` tokens for every request slot. Returns
        (n_comp * per_slice_batch, steps) generated ids."""
        n_comp = self.world.topo.n_comp
        if prompt_tokens is None:
            prompt_tokens = np.ones(
                (n_comp, self.per_slice_batch, 1), dtype=np.int32
            )
        self._cur = prompt_tokens[:, :, -1:]
        self._out = []
        self.session.run(steps, FailureSchedule(failures))
        out = self._out
        if not out:
            return np.zeros((n_comp, self.per_slice_batch, 0), np.int32)
        # elastic shrink mid-decode can reduce rows; align on the survivors
        rows = min(o.shape[0] for o in out)
        return np.stack([o[:rows] for o in out], axis=-1)
