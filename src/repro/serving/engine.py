"""Batched serving engine with replica failover.

The serving analogue of the paper's replication: replica slices mirror
their partner's request stream (same tokens, same order), so their KV
caches / SSM states are bit-identical. When a computational slice dies,
the promoted replica continues decoding from its own live cache: requests
lose NOTHING - no prefill re-run, no token loss. Unreplicated slice
failures re-queue their requests (prefill re-run after elastic shrink).

The engine is a thin :class:`~repro.ft.program.ResilientProgram`: the
detect/revoke/agree/repair lifecycle lives in FTSession (``replay='none'``
- a server resumes in place); this module supplies only the decode data
plane and the serving-specific hooks - ``repack_state``, which re-packs
cache rows so promoted replicas keep their mirrored caches across the
elastic shrink, and KV-cache ``snapshot``/``restore`` through the
``repro.store`` plane (``snapshot_every`` submits the decode state to a
K-way sharded partner-memory store, so an UNmirrored slice loss rewinds
to the last snapshot and re-decodes instead of cold-starting decode
state; the re-decoded tokens are bit-identical - greedy decode is
deterministic).

The decode step itself has no cross-slice collectives (the model axis is
GSPMD-managed), so the data plane stays failure-oblivious, exactly like the
paper's native-MPI plane.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs.base import ModelConfig, ReplicationConfig
from repro.core import data_plane as DP
from repro.dist.sharding import (
    cache_batch_axis,
    cache_shardings,
    param_shardings,
    path_str,
)
from repro.ft import FailureSchedule, FTReport, FTSession, ResilientProgram
from repro.models import model as M
from repro.store import DurableStore, PartnerMemoryStore, RecoveryLadder
from repro.xfer import TransferPlane


@dataclass
class ServeReport(FTReport):
    """FTReport + serving counters. ``decode_seconds``/``failover_seconds``
    are the serving names for the unified app/handler split."""

    tokens_decoded: int = 0
    requeued_requests: int = 0

    @property
    def decode_seconds(self) -> float:
        return self.app_seconds

    @property
    def failover_seconds(self) -> float:
        return self.handler_seconds


class ServeEngine(ResilientProgram):
    def __init__(
        self,
        model_cfg: ModelConfig,
        *,
        n_slices: int,
        model_shards: int = 1,
        rdegree: float = 0.0,
        spares: int = 0,
        heal: str = "none",
        per_slice_batch: int = 2,
        max_len: int = 128,
        seed: int = 0,
        params=None,
        snapshot_every: int = 0,
        partner_redundancy: int = 2,
        stores: Optional[RecoveryLadder] = None,
        delta: str = "none",
        checkpoint_dir: Optional[str] = None,
        durable_delta: str = "none",
        durable_max_chain: int = 4,
        slot_granular: bool = False,
    ):
        self.model_cfg = model_cfg
        self.repl = ReplicationConfig(rdegree=rdegree)
        self.per_slice_batch = per_slice_batch
        self.max_len = max_len
        self.params_host = params or M.init(jax.random.PRNGKey(seed), model_cfg)
        self.cache = None  # device cache after build_step; host copy mid-repair
        self.pos = 0
        self._cur: Optional[np.ndarray] = None
        self._out: List[np.ndarray] = []
        self._out_streams: List[List[int]] = []
        self.snapshot_every = snapshot_every
        # slot-granular decode (the serving gateway's substrate): every
        # (cmp role, lane) slot advances its OWN sequence position, so the
        # continuous batcher can free a slot at EOS and admit the next
        # queued request mid-decode. ``slot_pos`` is (n_comp, lanes) int32;
        # ``slot_active`` marks slots with a live (unfinished) request -
        # failover requeue accounting charges only those.
        self.slot_granular = slot_granular
        self.slot_pos: Optional[np.ndarray] = None
        self.slot_active: Optional[np.ndarray] = None

        # decode-state plane: K-way striped partner memory on the shared
        # repro.xfer plane, so a snapshot survives losses that take live
        # caches with them; KV snapshots pipeline behind decode steps, and
        # ``delta`` encodes a mostly-append cache cheaply (rows past the
        # decode position never change -> zero chunks). ``checkpoint_dir``
        # stacks a durable rung under the memory level so the decode state
        # survives whole-process death too; ``durable_delta`` puts the
        # append-only cache's zero chunks on disk as delta chains instead
        # of full snapshots every cadence tick.
        assert (delta == "none" and durable_delta == "none"
                and checkpoint_dir is None) or (stores is None and snapshot_every), (
            "delta/durable_delta/checkpoint_dir configure the default "
            "snapshot ladder: they need snapshot_every > 0, and an explicit "
            "stores= ladder carries its own plane/levels"
        )
        if stores is None and snapshot_every:
            assert durable_delta == "none" or checkpoint_dir, (
                "durable_delta configures the on-disk DurableStore - it "
                "needs checkpoint_dir, or the flag silently stores nothing"
            )
            levels = [
                PartnerMemoryStore(range(n_slices), redundancy=partner_redundancy)
            ]
            if checkpoint_dir:
                levels.append(DurableStore(checkpoint_dir, delta=durable_delta,
                                           max_chain=durable_max_chain))
            stores = RecoveryLadder(levels, xfer=TransferPlane(delta=delta))

        self.session = FTSession(
            self,
            n_slices=n_slices,
            model_shards=model_shards,
            rdegree=rdegree,
            n_spares=spares,
            heal=heal,
            stores=stores,
            checkpoint_every=snapshot_every,
            replay="none",
            report=ServeReport(),
            unit="token",
        )
        # cmp role -> original request-stream id; shrinks with the world,
        # letting decode() align outputs across elastic transitions
        self._streams: List[int] = list(range(self.world.topo.n_comp))

    # ---- convenience views over the session --------------------------------
    @property
    def world(self):
        return self.session.world

    @property
    def mesh(self):
        return self.session.mesh

    @property
    def report(self) -> ServeReport:
        return self.session.report

    @property
    def generation(self) -> int:
        return self.session.generation

    # ------------------------------------------------------------------
    # ResilientProgram hooks
    # ------------------------------------------------------------------
    def build_step(self, mesh, world) -> None:
        with set_mesh(mesh):
            pshard = param_shardings(self.params_host, mesh, self.model_cfg)
            self.params = jax.device_put(self.params_host, pshard)
            if self.cache is None:
                enc_len = 64 if self.model_cfg.enc_layers else 0
                cache_host = M.init_cache(
                    self.model_cfg,
                    world.topo.n_slices * self.per_slice_batch,
                    max_len=self.max_len,
                    enc_len=enc_len,
                    dtype=jnp.float32,
                )
            else:
                cache_host = self.cache  # survivors' mirrored caches (host copy)
            cshard = cache_shardings(cache_host, mesh, shard_batch=True)
            self.cache = jax.device_put(cache_host, cshard)
            self.step_fn = DP.build_serve_step(
                self.model_cfg, self.repl, mesh, world,
                shard_batch=True, donate=False, cache_example=self.cache,
                per_slot_pos=self.slot_granular,
            )
        if self.slot_active is None:
            shape = (world.topo.n_comp, self.per_slice_batch)
            self.slot_active = np.ones(shape, dtype=bool)
            if self.slot_granular:
                self.slot_pos = np.zeros(shape, dtype=np.int32)
                self.slot_active[:] = False  # gateway marks slots on bind

    def run_step(self, t: int) -> None:
        fed = self._mirror_tokens(self._cur)
        with set_mesh(self.mesh):
            next_fed, self.cache = self.step_fn(
                self.params, self.cache, jnp.asarray(fed), jnp.int32(self.pos)
            )
        next_fed = np.asarray(next_fed)
        # computational slices' outputs are authoritative
        order = self.world.roles_in_mesh_order()
        n_comp = self.world.topo.n_comp
        by_role = {
            r: next_fed[i * self.per_slice_batch : (i + 1) * self.per_slice_batch]
            for i, r in enumerate(order)
        }
        cmp_next = np.stack([by_role[c] for c in range(n_comp)])
        self._out.append(cmp_next[..., 0])
        self._out_streams.append(list(self._streams))
        self._cur = cmp_next
        self.pos += 1
        self.report.tokens_decoded += n_comp * self.per_slice_batch

    # ---- slot-granular decode (the gateway's substrate) --------------------
    @property
    def n_lanes(self) -> int:
        return self.per_slice_batch

    def step_slots(self, fed: np.ndarray) -> np.ndarray:
        """One decode step with per-slot positions. ``fed`` is
        (n_comp, lanes) int32 - each slot's next input token (a prompt
        token while prefilling, the last generated token while decoding, a
        pad for idle lanes). Returns the (n_comp, lanes) greedy next
        tokens and advances every slot's position. Replica slices mirror
        their partner's tokens AND positions, so mirrored cache rows stay
        bit-identical and a promote carries in-flight slots for free."""
        assert self.slot_granular, "step_slots needs ServeEngine(slot_granular=True)"
        order = self.world.roles_in_mesh_order()
        src = self.world.topo.mirror_source()
        n_comp = self.world.topo.n_comp
        b = self.per_slice_batch
        fed_full = np.concatenate([fed[src[r]] for r in order])[:, None]
        pos_full = np.concatenate([self.slot_pos[src[r]] for r in order])
        with set_mesh(self.mesh):
            next_fed, self.cache = self.step_fn(
                self.params, self.cache,
                jnp.asarray(fed_full.astype(np.int32)),
                jnp.asarray(pos_full.astype(np.int32)),
            )
        next_fed = np.asarray(next_fed)
        by_role = {
            r: next_fed[i * b : (i + 1) * b, 0] for i, r in enumerate(order)
        }
        out = np.stack([by_role[c] for c in range(n_comp)])
        self.slot_pos += 1
        self.report.tokens_decoded += int(self.slot_active.sum())
        return out

    def reset_slots(self, slots: List[tuple]) -> None:
        """Zero the cache rows of ``slots`` ((cmp_role, lane) pairs) and
        rewind their positions to 0 - a freed slot becomes a fresh
        sequence for the next admitted request. The mirror row of each
        role's replica is zeroed too (mirrored rows must stay
        bit-identical, and SSM/conv state is recurrent: masking alone
        cannot hide a previous occupant's state the way the position mask
        hides stale KV entries)."""
        if not slots:
            return
        pos = self.world.mesh_position()
        b = self.per_slice_batch
        rows: List[int] = []
        for role, lane in slots:
            self.slot_pos[role, lane] = 0
            rows.append(pos[self.world.assignment[role]] * b + lane)
            partner = self.world.topo.partner_of(role)
            if partner is not None:
                rows.append(pos[self.world.assignment[partner]] * b + lane)
        idx = jnp.asarray(sorted(set(rows)))

        def zero_rows(kp, arr):
            axis = cache_batch_axis(path_str(kp), arr.ndim)
            moved = jnp.moveaxis(arr, axis, 0)
            return jnp.moveaxis(moved.at[idx].set(0), 0, axis)

        self.cache = jax.tree_util.tree_map_with_path(zero_rows, self.cache)

    # ---- decode-state snapshots (the repro.store plane) --------------------
    def snapshot(self):
        """KV cache + in-flight tokens, submitted to the recovery ladder on
        the ``snapshot_every`` cadence and used as the restore template.
        Leaves are handed over as-is (device arrays are immutable, ``_cur``
        is rebound each step): the store's staging pass makes the one host
        copy, not us."""
        if self.cache is None:
            return None
        state = {"cache": self.cache}
        if self._cur is not None:
            state["cur"] = self._cur
        meta = {"pos": self.pos}
        if self.slot_granular:
            meta["slot_pos"] = self.slot_pos.tolist()
        return state, meta

    def restore(self, state, meta) -> None:
        """Adopt a snapshot (host arrays, pre-failure world layout); the
        following ``repack_state``/``build_step`` re-pack and re-place it
        onto the shrunk world."""
        self.cache = state["cache"]
        if "cur" in state:
            self._cur = np.asarray(state["cur"])
        self.pos = int(meta["pos"])
        if "slot_pos" in meta:
            self.slot_pos = np.asarray(meta["slot_pos"], dtype=np.int32)

    def replay_inputs(self, plan) -> None:
        """Drop output tokens past the replay point - re-decode regenerates
        them bit-identically (greedy, deterministic)."""
        del self._out[plan.start_step:]
        del self._out_streams[plan.start_step:]

    def repack_state(self, old_world, new_world) -> None:
        """Promoted replicas keep their caches: re-pack cache rows so the
        new mesh order draws each role's cache from the physical slice that
        now owns it; unreplicated losses without a restorable snapshot
        re-queue their requests. ``self.cache`` is either the survivors'
        live cache or a just-restored snapshot - both in old-world layout.

        Spares that entered the world this recovery have no old rows:

        - a HEALED replica warms its mirrored KV cache from its partner's
          rows (the partner's snapshot is exactly what a mirror holds);
        - a BACKFILLED cmp role takes the restored snapshot's rows for the
          old role it continues (the dead physical's rows are still present
          in the old-layout snapshot).
        """
        cache_host = jax.tree.map(np.asarray, self.cache)
        old_pos = old_world.mesh_position()
        new_order = new_world.roles_in_mesh_order()
        # new cmp role -> old cmp role (identity unless a lost role forced
        # renumbering); backfilled roles resolve through it
        role_map = self.session.last_repair.get("role_map", {})
        b = self.per_slice_batch

        def src_row(r: int) -> int:
            phys = new_world.assignment[r]
            if phys in old_pos:
                return old_pos[phys]
            topo = new_world.topo
            if r >= topo.n_comp:  # healed replica: its partner's rows
                return src_row(topo.replica_of(r))
            # backfilled cmp: the restored snapshot's rows for the old role
            return old_pos[old_world.assignment[role_map[r]]]

        def repack(kp, arr):
            axis = cache_batch_axis(path_str(kp), arr.ndim)
            rows = [
                np.take(arr, range(src_row(r) * b, (src_row(r) + 1) * b), axis=axis)
                for r in new_order
            ]
            return np.concatenate(rows, axis=axis)

        self.cache = jax.tree_util.tree_map_with_path(repack, cache_host)
        # requeue accounting: only LIVE (unfinished) slots on the lost
        # roles re-enter the queue - a slot whose sequence already hit
        # EOS/max-len has nothing left to requeue (the old
        # ``lost_roles * b`` charged finished sequences too). Legacy
        # whole-batch decode never clears ``slot_active``, so its count is
        # unchanged.
        lost = self.session.last_repair.get("lost_cmp", [])
        self.report.requeued_requests += int(self.slot_active[lost].sum())
        # each surviving cmp role keeps ITS stream (the dead role's row is
        # dropped wherever it sat, not always at the tail; a backfilled
        # role continues the old role's stream from the restored snapshot)
        keep = [
            self._old_cmp_role(old_world, new_world.assignment[r], role_map.get(r))
            for r in range(new_world.topo.n_comp)
        ]
        self._streams = [self._streams[r] for r in keep]
        self.slot_active = self.slot_active[keep]
        if self.slot_pos is not None:
            self.slot_pos = self.slot_pos[keep]
        if self._cur is not None:
            self._cur = np.stack([self._cur[r] for r in keep])

    @staticmethod
    def _old_cmp_role(old_world, phys: int, backfilled_from=None) -> int:
        """The old-world cmp role whose token stream physical ``phys``
        carried (a promoted replica carried its mirrored partner's; a
        backfilled spare carries the lost role's)."""
        role = old_world.role_of_physical(phys)
        if role is None:
            return backfilled_from
        if role >= old_world.topo.n_comp:
            role = old_world.topo.replica_of(role)
        return role

    # ------------------------------------------------------------------
    def _mirror_tokens(self, cmp_tokens: np.ndarray) -> np.ndarray:
        """Lay out per-cmp-slice request tokens in mesh order, mirroring the
        partner's stream onto replica slices."""
        src = self.world.topo.mirror_source()
        order = self.world.roles_in_mesh_order()
        return np.concatenate([cmp_tokens[src[r]] for r in order], axis=0)

    def decode(self, steps: int, prompt_tokens: Optional[np.ndarray] = None,
               failures: Optional[Dict[int, List[int]]] = None) -> np.ndarray:
        """Greedy-decode ``steps`` tokens for every request slot. Returns
        (n_comp * per_slice_batch, steps) generated ids."""
        assert not self.slot_granular, (
            "slot-granular engines are driven by repro.serving.gateway - "
            "lockstep decode() shares one position across the batch"
        )
        n_comp = self.world.topo.n_comp
        if prompt_tokens is None:
            prompt_tokens = np.ones(
                (n_comp, self.per_slice_batch, 1), dtype=np.int32
            )
        self._cur = prompt_tokens[:, :, -1:]
        self._out = []
        self._out_streams = []
        self.session.run(steps, FailureSchedule(failures))
        if not self._out:
            return np.zeros((n_comp, self.per_slice_batch, 0), np.int32)
        # elastic shrink mid-decode can drop streams anywhere in the batch;
        # align every token column on the streams that finished the run
        final = self._streams
        cols = [
            o[[streams.index(s) for s in final]]
            for streams, o in zip(self._out_streams, self._out)
        ]
        return np.stack(cols, axis=-1)
