"""Batched serving engine with replica failover.

The serving analogue of the paper's replication: replica slices mirror
their partner's request stream (same tokens, same order), so their KV
caches / SSM states are bit-identical. When a computational slice dies,
the promoted replica continues decoding from its own live cache: requests
lose NOTHING - no prefill re-run, no token loss. Unreplicated slice
failures re-queue their requests (prefill re-run after elastic shrink).

The engine is a thin :class:`~repro.ft.program.ResilientProgram`: the
detect/revoke/agree/repair lifecycle lives in FTSession (``replay='none'``
- a server resumes in place); this module supplies only the decode data
plane and the serving-specific hooks - ``repack_state``, which re-packs
cache rows so promoted replicas keep their mirrored caches across the
elastic shrink, and KV-cache ``snapshot``/``restore`` through the
``repro.store`` plane (``snapshot_every`` submits the decode state to a
K-way sharded partner-memory store, so an UNmirrored slice loss rewinds
to the last snapshot and re-decodes instead of cold-starting decode
state; the re-decoded tokens are bit-identical - greedy decode is
deterministic).

The decode state itself is PAGED by default (``page_tokens`` > 0): the
dense cache stays the compute layout on device, but everything that
*moves* - snapshots, partner stripes, durable delta chains, heal warm-up,
corruption splices - moves at the granularity of fixed-size token pages
tracked by :class:`~repro.serving.paging.PageTable`. Pages ARE the
transfer plane's chunks (``xfer.chunk_pages``), so an append-only decode
ships only its dirtied tail pages per cadence tick, a clean tick ships
nothing at all, and requests sharing a prompt prefix ship ONE copy of the
prefix pages. ``page_tokens=0`` keeps the legacy whole-tree snapshot path
(the benchmarks' dense baseline).

The decode step itself has no cross-slice collectives (the model axis is
GSPMD-managed), so the data plane stays failure-oblivious, exactly like the
paper's native-MPI plane.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs.base import ModelConfig, ReplicationConfig
from repro.core import data_plane as DP
from repro.dist.sharding import (
    cache_batch_axis,
    cache_shardings,
    param_shardings,
    path_str,
)
from repro.ft import FailureSchedule, FTReport, FTSession, ResilientProgram
from repro.models import model as M
from repro.models.layers import gather_cache_page, scatter_cache_page
from repro.serving.paging import CacheLeaf, PageTable
from repro.store import DurableStore, PartnerMemoryStore, RecoveryLadder
from repro.xfer import PagedBlob, TransferPlane
from repro.xfer.chunking import leaf_bytes


@dataclass
class ServeReport(FTReport):
    """FTReport + serving counters. ``decode_seconds``/``failover_seconds``
    are the serving names for the unified app/handler split."""

    tokens_decoded: int = 0
    requeued_requests: int = 0

    @property
    def decode_seconds(self) -> float:
        return self.app_seconds

    @property
    def failover_seconds(self) -> float:
        return self.handler_seconds


class ServeEngine(ResilientProgram):
    def __init__(
        self,
        model_cfg: ModelConfig,
        *,
        n_slices: int,
        model_shards: int = 1,
        rdegree: float = 0.0,
        spares: int = 0,
        heal: str = "none",
        per_slice_batch: int = 2,
        max_len: int = 128,
        seed: int = 0,
        params=None,
        snapshot_every: int = 0,
        partner_redundancy: int = 2,
        stores: Optional[RecoveryLadder] = None,
        delta: str = "none",
        checkpoint_dir: Optional[str] = None,
        durable_delta: str = "none",
        durable_max_chain: int = 4,
        slot_granular: bool = False,
        page_tokens: int = 128,
        prefix_share: bool = True,
        scrub=None,
    ):
        self.model_cfg = model_cfg
        self.repl = ReplicationConfig(rdegree=rdegree)
        self.per_slice_batch = per_slice_batch
        self.max_len = max_len
        self.params_host = params or M.init(jax.random.PRNGKey(seed), model_cfg)
        self.cache = None  # device cache after build_step; host copy mid-repair
        self.pos = 0
        self._cur: Optional[np.ndarray] = None
        self._out: List[np.ndarray] = []
        self._out_streams: List[List[int]] = []
        self.snapshot_every = snapshot_every
        # paged decode state: the page table tracks slot -> page mapping,
        # dirty pages since the last submit, and shared prompt-prefix
        # pages; 0 = legacy dense whole-tree snapshots (bench baseline)
        self.table: Optional[PageTable] = (
            PageTable(page_tokens, prefix_share=prefix_share)
            if page_tokens else None
        )
        #: repack accounting: bytes actually copied to warm rows that are
        #: NEW to the world (backfilled/healed spares) vs what copying the
        #: full dense rows would have moved - the heal warm-up saving
        self.heal_warm_bytes = 0
        self.heal_warm_bytes_full = 0
        # slot-granular decode (the serving gateway's substrate): every
        # (cmp role, lane) slot advances its OWN sequence position, so the
        # continuous batcher can free a slot at EOS and admit the next
        # queued request mid-decode. ``slot_pos`` is (n_comp, lanes) int32;
        # ``slot_active`` marks slots with a live (unfinished) request -
        # failover requeue accounting charges only those.
        self.slot_granular = slot_granular
        self.slot_pos: Optional[np.ndarray] = None
        self.slot_active: Optional[np.ndarray] = None

        # decode-state plane: K-way striped partner memory on the shared
        # repro.xfer plane, so a snapshot survives losses that take live
        # caches with them; KV snapshots pipeline behind decode steps, and
        # ``delta`` encodes a mostly-append cache cheaply (rows past the
        # decode position never change -> zero chunks). ``checkpoint_dir``
        # stacks a durable rung under the memory level so the decode state
        # survives whole-process death too; ``durable_delta`` puts the
        # append-only cache's zero chunks on disk as delta chains instead
        # of full snapshots every cadence tick.
        assert (delta == "none" and durable_delta == "none"
                and checkpoint_dir is None) or (stores is None and snapshot_every), (
            "delta/durable_delta/checkpoint_dir configure the default "
            "snapshot ladder: they need snapshot_every > 0, and an explicit "
            "stores= ladder carries its own plane/levels"
        )
        if stores is None and snapshot_every:
            assert durable_delta == "none" or checkpoint_dir, (
                "durable_delta configures the on-disk DurableStore - it "
                "needs checkpoint_dir, or the flag silently stores nothing"
            )
            levels = [
                PartnerMemoryStore(range(n_slices), redundancy=partner_redundancy)
            ]
            if checkpoint_dir:
                levels.append(DurableStore(checkpoint_dir, delta=durable_delta,
                                           max_chain=durable_max_chain))
            stores = RecoveryLadder(levels, xfer=TransferPlane(delta=delta))

        self.session = FTSession(
            self,
            n_slices=n_slices,
            model_shards=model_shards,
            rdegree=rdegree,
            n_spares=spares,
            heal=heal,
            stores=stores,
            checkpoint_every=snapshot_every,
            replay="none",
            report=ServeReport(),
            unit="token",
            scrub=scrub,
        )
        # cmp role -> original request-stream id; shrinks with the world,
        # letting decode() align outputs across elastic transitions
        self._streams: List[int] = list(range(self.world.topo.n_comp))

    # ---- convenience views over the session --------------------------------
    @property
    def world(self):
        return self.session.world

    @property
    def mesh(self):
        return self.session.mesh

    @property
    def report(self) -> ServeReport:
        return self.session.report

    @property
    def generation(self) -> int:
        return self.session.generation

    # ------------------------------------------------------------------
    # ResilientProgram hooks
    # ------------------------------------------------------------------
    def build_step(self, mesh, world) -> None:
        with set_mesh(mesh):
            pshard = param_shardings(self.params_host, mesh, self.model_cfg)
            self.params = jax.device_put(self.params_host, pshard)
            if self.cache is None:
                enc_len = 64 if self.model_cfg.enc_layers else 0
                cache_host = M.init_cache(
                    self.model_cfg,
                    world.topo.n_slices * self.per_slice_batch,
                    max_len=self.max_len,
                    enc_len=enc_len,
                    dtype=jnp.float32,
                )
            else:
                cache_host = self.cache  # survivors' mirrored caches (host copy)
            cshard = cache_shardings(cache_host, mesh, shard_batch=True)
            self.cache = jax.device_put(cache_host, cshard)
            self.step_fn = DP.build_serve_step(
                self.model_cfg, self.repl, mesh, world,
                shard_batch=True, donate=False, cache_example=self.cache,
                per_slot_pos=self.slot_granular,
            )
        if self.slot_active is None:
            shape = (world.topo.n_comp, self.per_slice_batch)
            self.slot_active = np.ones(shape, dtype=bool)
            if self.slot_granular:
                self.slot_pos = np.zeros(shape, dtype=np.int32)
                self.slot_active[:] = False  # gateway marks slots on bind
        if self.table is not None and not self.table.leaves:
            # derive each leaf's paging geometry ONCE (the leaf set is
            # fixed for the job's life; only the batch extent shrinks)
            flat, _ = jax.tree_util.tree_flatten_with_path(self.cache)
            leaves = []
            for kp, arr in flat:
                p = path_str(kp)
                b_ax = cache_batch_axis(p, arr.ndim)
                timed = p.split("/")[-1] in ("k", "v") and "cross" not in p
                smax = int(arr.shape[b_ax + 1]) if timed else None
                leaves.append(CacheLeaf(
                    path=p, batch_axis=b_ax, smax=smax,
                    ring=bool(timed and smax < self.max_len),
                ))
            self.table.configure(leaves)

    def run_step(self, t: int) -> None:
        fed = self._mirror_tokens(self._cur)
        with set_mesh(self.mesh):
            next_fed, self.cache = self.step_fn(
                self.params, self.cache, jnp.asarray(fed), jnp.int32(self.pos)
            )
        next_fed = np.asarray(next_fed)
        # computational slices' outputs are authoritative
        order = self.world.roles_in_mesh_order()
        n_comp = self.world.topo.n_comp
        by_role = {
            r: next_fed[i * self.per_slice_batch : (i + 1) * self.per_slice_batch]
            for i, r in enumerate(order)
        }
        cmp_next = np.stack([by_role[c] for c in range(n_comp)])
        self._out.append(cmp_next[..., 0])
        self._out_streams.append(list(self._streams))
        self._cur = cmp_next
        self.pos += 1
        self.report.tokens_decoded += n_comp * self.per_slice_batch

    # ---- slot-granular decode (the gateway's substrate) --------------------
    @property
    def n_lanes(self) -> int:
        return self.per_slice_batch

    def step_slots(self, fed: np.ndarray) -> np.ndarray:
        """One decode step with per-slot positions. ``fed`` is
        (n_comp, lanes) int32 - each slot's next input token (a prompt
        token while prefilling, the last generated token while decoding, a
        pad for idle lanes). Returns the (n_comp, lanes) greedy next
        tokens and advances every slot's position. Replica slices mirror
        their partner's tokens AND positions, so mirrored cache rows stay
        bit-identical and a promote carries in-flight slots for free."""
        assert self.slot_granular, "step_slots needs ServeEngine(slot_granular=True)"
        order = self.world.roles_in_mesh_order()
        src = self.world.topo.mirror_source()
        n_comp = self.world.topo.n_comp
        b = self.per_slice_batch
        fed_full = np.concatenate([fed[src[r]] for r in order])[:, None]
        pos_full = np.concatenate([self.slot_pos[src[r]] for r in order])
        with set_mesh(self.mesh):
            next_fed, self.cache = self.step_fn(
                self.params, self.cache,
                jnp.asarray(fed_full.astype(np.int32)),
                jnp.asarray(pos_full.astype(np.int32)),
            )
        next_fed = np.asarray(next_fed)
        by_role = {
            r: next_fed[i * b : (i + 1) * b, 0] for i, r in enumerate(order)
        }
        out = np.stack([by_role[c] for c in range(n_comp)])
        self.slot_pos += 1
        self.report.tokens_decoded += int(self.slot_active.sum())
        return out

    def reset_slots(self, slots: List[tuple]) -> None:
        """Free ``slots`` ((cmp_role, lane) pairs): rewind their positions
        to 0 so a freed slot becomes a fresh sequence for the next admitted
        request. The mirror row of each role's replica is handled too
        (mirrored rows must stay bit-identical).

        The dense path zeroes every cache row of the slot. The paged path
        zeroes ONLY the recurrent block leaves (SSM conv/ssm state, cross
        K/V): masking alone cannot hide a previous occupant's recurrent
        state, but it hides stale attention K/V entries exactly (masked
        scores are position-based and underflow to 0.0 weight in fp32
        regardless of the stale bytes) - so the attention time leaves stay
        untouched and the reset is a page-table edit, not a full-tree
        ``at[idx].set(0)`` rebuild."""
        if not slots:
            return
        pos = self.world.mesh_position()
        b = self.per_slice_batch
        rows: List[int] = []
        for role, lane in slots:
            self.slot_pos[role, lane] = 0
            rows.append(pos[self.world.assignment[role]] * b + lane)
            partner = self.world.topo.partner_of(role)
            if partner is not None:
                rows.append(pos[self.world.assignment[partner]] * b + lane)
        idx = jnp.asarray(sorted(set(rows)))
        timed = (
            {leaf.path for leaf in self.table.leaves if leaf.smax is not None}
            if self.table is not None else frozenset()
        )

        def zero_rows(kp, arr):
            p = path_str(kp)
            if p in timed:
                return arr  # masked exactly; the table edit frees the pages
            axis = cache_batch_axis(p, arr.ndim)
            moved = jnp.moveaxis(arr, axis, 0)
            return jnp.moveaxis(moved.at[idx].set(0), 0, axis)

        self.cache = jax.tree_util.tree_map_with_path(zero_rows, self.cache)
        if self.table is not None:
            self.table.reset(slots)

    # ---- paged decode state (pages ARE the transfer chunks) ----------------
    def _slot_row(self, role: int, lane: int) -> int:
        pos = self.world.mesh_position()
        return pos[self.world.assignment[role]] * self.per_slice_batch + lane

    def _mirror_row(self, role: int, lane: int) -> int:
        partner = self.world.topo.partner_of(role)
        if partner is None:
            return -1
        pos = self.world.mesh_position()
        return pos[self.world.assignment[partner]] * self.per_slice_batch + lane

    def note_prompt(self, slot: Tuple[int, int], tokens: Sequence[int]) -> None:
        """Pin the prompt a freshly-bound slot is about to prefill, so the
        page table can content-address (and share) its prefix pages."""
        if self.table is not None:
            self.table.note_prompt(slot[0], slot[1], tokens)

    def _sync_counts(self) -> None:
        """Mirror the engine's position state into the page table (slot
        entries exist lazily: lockstep engines never bind slots)."""
        for role in range(self.world.topo.n_comp):
            for lane in range(self.per_slice_batch):
                e = self.table.ensure(role, lane)
                e.count = (
                    int(self.slot_pos[role, lane])
                    if self.slot_granular else self.pos
                )

    def _cache_by_path(self) -> Dict[str, object]:
        flat, _ = jax.tree_util.tree_flatten_with_path(self.cache)
        return {path_str(kp): arr for kp, arr in flat}

    def _gather_pages(self) -> None:
        """Pull every dirty/missing page off the live cache into the
        table's sealed host page cache. Only the pages the decode loop
        actually touched since the last gather move over the host link -
        the append-only common case is ONE tail page per slot per leaf."""
        by_path = self._cache_by_path()
        for e in self.table.slots.values():
            refs = self.table.dirty_refs(e)
            if not refs:
                continue
            row = self._slot_row(e.role, e.lane)
            for ref in refs:
                arr = by_path[ref.leaf.path]
                page = gather_cache_page(
                    arr, ref.leaf.batch_axis, row, ref.t0, ref.t1
                )
                self.table.pages[ref.key] = np.asarray(page)
        self.table.mark_gathered()

    def _page_blob(self) -> PagedBlob:
        blob = PagedBlob()
        for e in self.table.slots.values():
            for ref in self.table.slot_pages(e):
                blob[ref.key] = self.table.pages[ref.key]
        return blob

    def _paged_meta(self) -> Dict:
        rows, mrows = {}, {}
        for role, lane in self.table.slots:
            rows[(role, lane)] = self._slot_row(role, lane)
            mrows[(role, lane)] = self._mirror_row(role, lane)
        n_rows = self.world.topo.n_slices * self.per_slice_batch
        meta: Dict = {
            "pos": self.pos,
            "paged": self.table.to_meta(rows, mrows, n_rows),
        }
        if self.slot_granular:
            meta["slot_pos"] = self.slot_pos.tolist()
        if self._cur is not None:
            meta["cur"] = np.asarray(self._cur).tolist()
        return meta

    # ---- decode-state snapshots (the repro.store plane) --------------------
    def snapshot(self):
        """Decode state + in-flight tokens: the restore template and the
        heal plane's clone source - always the FULL state.

        Paged engines return a :class:`~repro.xfer.PagedBlob` of every
        live page (replica mirror rows are NOT shipped: the restore
        re-derives them from the computational rows - the mirror
        invariant); everything positional rides in ``meta``. Dense
        engines hand the device tree over as-is: the store's staging pass
        makes the one host copy, not us."""
        if self.cache is None:
            return None
        if self.table is None:
            state = {"cache": self.cache}
            if self._cur is not None:
                state["cur"] = self._cur
            meta = {"pos": self.pos}
            if self.slot_granular:
                meta["slot_pos"] = self.slot_pos.tolist()
            return state, meta
        self._sync_counts()
        self._gather_pages()
        return self._page_blob(), self._paged_meta()

    def snapshot_dirty(self):
        """The cadence-path snapshot: ``None`` when NOTHING changed since
        the last submitted snapshot (an idle gateway between admissions) -
        the session accounts the skip in ``FTReport.snapshots_skipped``.
        Otherwise the full live page set; the keyed delta encoder
        zero-encodes the clean pages, so only dirtied tail pages move."""
        if self.table is None:
            return self.snapshot()
        if self.cache is None:
            return None
        self._sync_counts()
        if self.table.clean():
            return None
        self._gather_pages()
        blob, meta = self._page_blob(), self._paged_meta()
        self.table.mark_submitted()
        return blob, meta

    def restore(self, state, meta) -> None:
        """Adopt a snapshot (host arrays, pre-failure world layout); the
        following ``repack_state``/``build_step`` re-pack and re-place it
        onto the shrunk world.

        A paged snapshot scatters its live pages into a zeroed dense host
        cache at the rows the submit recorded, re-derives every replica
        mirror row from its computational row, and rebuilds the page
        table from the manifest - then invalidates the host page cache so
        the next snapshot re-gathers from ground truth."""
        if self.table is None or not isinstance(state, PagedBlob):
            self.cache = state["cache"]
            if "cur" in state:
                self._cur = np.asarray(state["cur"])
            self.pos = int(meta["pos"])
            if "slot_pos" in meta:
                self.slot_pos = np.asarray(meta["slot_pos"], dtype=np.int32)
            return
        pm = meta["paged"]
        enc_len = 64 if self.model_cfg.enc_layers else 0
        host = jax.tree.map(
            lambda a: np.zeros(a.shape, np.asarray(a).dtype),
            M.init_cache(self.model_cfg, int(pm["n_rows"]),
                         max_len=self.max_len, enc_len=enc_len,
                         dtype=jnp.float32),
        )
        flat, _ = jax.tree_util.tree_flatten_with_path(host)
        by_path = {path_str(kp): arr for kp, arr in flat}
        self.table.load_meta(pm)
        for s in pm["slots"]:
            e = self.table.slots[(int(s["role"]), int(s["lane"]))]
            row, mrow = int(s["row"]), int(s["mirror_row"])
            for ref in self.table.slot_pages(e):
                page = state.get(ref.key)
                if page is None:
                    continue
                arr = by_path[ref.leaf.path]
                scatter_cache_page(arr, ref.leaf.batch_axis, row,
                                   np.asarray(page, dtype=arr.dtype),
                                   ref.t0, ref.t1)
            if mrow >= 0:
                for leaf in self.table.leaves:
                    arr = by_path[leaf.path]
                    scatter_cache_page(
                        arr, leaf.batch_axis, mrow,
                        gather_cache_page(arr, leaf.batch_axis, row),
                    )
        self.cache = host
        self.pos = int(meta["pos"])
        if "slot_pos" in meta:
            self.slot_pos = np.asarray(meta["slot_pos"], dtype=np.int32)
        if meta.get("cur") is not None:
            self._cur = np.asarray(meta["cur"], dtype=np.int32)

    # ---- SDC scrubbing at page granularity (repro.scrub) -------------------
    def scrub_kv(self) -> Optional[Dict]:
        """One scrub pass over the decode state's SETTLED pages: gather
        them fresh off the live cache (never trust the host page cache
        here - it is what a snapshot would ship, not ground truth),
        compare per-page crc32 against the scrub plane's reference from
        the last ladder submit, and majority-vote each mismatch 2-of-3
        with the replica's mirror row as the live second voter:

        - cmp != ref, mirror == ref  -> the cmp row is the victim;
        - cmp != ref, mirror == cmp  -> the rows agree with each other:
          the reference is the odd one out - counted transient, no repair;
        - all three differ           -> no majority: repair (safe choice).

        A confirmed corruption is spliced back through
        ``ladder.restore_partial`` - the keyed page cut means ONLY the
        poisoned pages (plus pages the submit had that the live state
        lost) move; the state rolls back to the submit step and re-decodes
        bit-identically. Mirror-row divergence without a cmp mismatch is
        the in-step scrub tables' territory, not this pass's.

        Returns a summary dict, or None without a page reference to
        compare against."""
        scrub = self.session.scrub
        if self.table is None or scrub is None or scrub.page_reference is None:
            return None
        ref = scrub.page_reference
        self._sync_counts()
        by_path = self._cache_by_path()
        fresh = PagedBlob()
        corrupt: List[str] = []
        transient = 0
        checked = 0
        for e in self.table.slots.values():
            row = self._slot_row(e.role, e.lane)
            for pref in self.table.settled_refs(e):
                want = ref.get(pref.key)
                if want is None:
                    continue
                arr = by_path[pref.leaf.path]
                page = np.asarray(gather_cache_page(
                    arr, pref.leaf.batch_axis, row, pref.t0, pref.t1))
                fresh[pref.key] = page
                checked += 1
                pcrc = zlib.crc32(leaf_bytes(page))
                if pcrc == want:
                    continue
                mrow = self._mirror_row(e.role, e.lane)
                if mrow >= 0:
                    mpage = np.asarray(gather_cache_page(
                        arr, pref.leaf.batch_axis, mrow, pref.t0, pref.t1))
                    mcrc = zlib.crc32(leaf_bytes(mpage))
                    if mcrc != want and mcrc == pcrc:
                        transient += 1
                        self.report.sdc_transient += 1
                        continue
                corrupt.append(pref.key)
        out = {"checked": checked, "corrupt": list(corrupt),
               "transient": transient, "repaired": False, "moved_bytes": 0}
        if not corrupt:
            return out
        self.report.sdc_detected += 1
        self.report.events.append(
            f"token {self.pos}: kv scrub flagged {len(corrupt)} page(s)")
        got = (self.session.ladder.restore_partial(fresh)
               if self.session.ladder else None)
        if got is None:
            return out
        self.restore(got.state, dict(got.meta))
        self.build_step(self.session.mesh, self.world)
        self.report.sdc_repairs += 1
        self.report.sdc_bytes_moved += got.moved_bytes
        self.report.sdc_bytes_full += got.total_bytes
        out.update(repaired=True, moved_bytes=got.moved_bytes,
                   total_bytes=got.total_bytes, step=got.step)
        return out

    def replay_inputs(self, plan) -> None:
        """Drop output tokens past the replay point - re-decode regenerates
        them bit-identically (greedy, deterministic)."""
        del self._out[plan.start_step:]
        del self._out_streams[plan.start_step:]

    def repack_state(self, old_world, new_world) -> None:
        """Promoted replicas keep their caches: re-pack cache rows so the
        new mesh order draws each role's cache from the physical slice that
        now owns it; unreplicated losses without a restorable snapshot
        re-queue their requests. ``self.cache`` is either the survivors'
        live cache or a just-restored snapshot - both in old-world layout.

        Spares that entered the world this recovery have no old rows:

        - a HEALED replica warms its mirrored KV cache from its partner's
          rows (the partner's snapshot is exactly what a mirror holds);
        - a BACKFILLED cmp role takes the restored snapshot's rows for the
          old role it continues (the dead physical's rows are still present
          in the old-layout snapshot).

        Paged engines move ONLY each slot's live pages (time leaves trimmed
        to the slot's position, masked tails zero-filled) and account what
        warming the world's NEW rows cost in ``heal_warm_bytes`` vs the
        dense ``heal_warm_bytes_full``; page keys survive the renumbering
        (uids travel with their slots), so the next cadence submit still
        zero-encodes everything the failover did not touch.
        """
        cache_host = jax.tree.map(np.asarray, self.cache)
        old_pos = old_world.mesh_position()
        new_order = new_world.roles_in_mesh_order()
        # new cmp role -> old cmp role (identity unless a lost role forced
        # renumbering); backfilled roles resolve through it
        role_map = self.session.last_repair.get("role_map", {})
        b = self.per_slice_batch

        def src_row(r: int) -> int:
            phys = new_world.assignment[r]
            if phys in old_pos:
                return old_pos[phys]
            topo = new_world.topo
            if r >= topo.n_comp:  # healed replica: its partner's rows
                return src_row(topo.replica_of(r))
            # backfilled cmp: the restored snapshot's rows for the old role
            return old_pos[old_world.assignment[role_map[r]]]

        # each surviving cmp role keeps ITS stream (the dead role's row is
        # dropped wherever it sat, not always at the tail; a backfilled
        # role continues the old role's stream from the restored snapshot)
        keep = [
            self._old_cmp_role(old_world, new_world.assignment[r], role_map.get(r))
            for r in range(new_world.topo.n_comp)
        ]
        if self.table is None:
            def repack(kp, arr):
                axis = cache_batch_axis(path_str(kp), arr.ndim)
                rows = [
                    np.take(arr, range(src_row(r) * b, (src_row(r) + 1) * b),
                            axis=axis)
                    for r in new_order
                ]
                return np.concatenate(rows, axis=axis)

            self.cache = jax.tree_util.tree_map_with_path(repack, cache_host)
        else:
            self.cache = self._repack_paged(
                cache_host, new_world, new_order, old_pos, src_row, keep
            )
        # requeue accounting: only LIVE (unfinished) slots on the lost
        # roles re-enter the queue - a slot whose sequence already hit
        # EOS/max-len has nothing left to requeue (the old
        # ``lost_roles * b`` charged finished sequences too). Legacy
        # whole-batch decode never clears ``slot_active``, so its count is
        # unchanged.
        lost = self.session.last_repair.get("lost_cmp", [])
        self.report.requeued_requests += int(self.slot_active[lost].sum())
        self._streams = [self._streams[r] for r in keep]
        self.slot_active = self.slot_active[keep]
        if self.slot_pos is not None:
            self.slot_pos = self.slot_pos[keep]
        if self._cur is not None:
            self._cur = np.stack([self._cur[r] for r in keep])
        if self.table is not None:
            self.table.remap(keep, b)
            self.table.invalidate()

    def _repack_paged(self, cache_host, new_world, new_order, old_pos,
                      src_row, keep):
        """Build the new-world dense cache by scattering each slot's LIVE
        pages into zeroed rows: time leaves copy ``[0, min(count, smax))``
        only (the masked tail is zero-filled - stream-identical), block
        leaves copy whole. Rows whose physical slice is NEW to the world
        (a backfilled or healed spare) are the heal warm-up traffic the
        bench prices: live-page bytes moved vs the full dense rows."""
        b = self.per_slice_batch
        topo = new_world.topo
        new_rows = topo.n_slices * b

        def zero_like(kp, arr):
            axis = cache_batch_axis(path_str(kp), arr.ndim)
            shp = list(arr.shape)
            shp[axis] = new_rows
            return np.zeros(shp, arr.dtype)

        new_cache = jax.tree_util.tree_map_with_path(zero_like, cache_host)
        old_flat, _ = jax.tree_util.tree_flatten_with_path(cache_host)
        old_by = {path_str(kp): arr for kp, arr in old_flat}
        new_flat, _ = jax.tree_util.tree_flatten_with_path(new_cache)
        new_by = {path_str(kp): arr for kp, arr in new_flat}
        for i, r in enumerate(new_order):
            c = r if r < topo.n_comp else topo.replica_of(r)
            old_c = keep[c]
            fresh = new_world.assignment[r] not in old_pos
            for lane in range(b):
                count = (
                    int(self.slot_pos[old_c, lane])
                    if self.slot_granular else self.pos
                )
                srow = src_row(r) * b + lane
                drow = i * b + lane
                for leaf in self.table.leaves:
                    src, dst = old_by[leaf.path], new_by[leaf.path]
                    row_bytes = (
                        src.size // src.shape[leaf.batch_axis]
                    ) * src.dtype.itemsize
                    if leaf.smax is None:
                        scatter_cache_page(
                            dst, leaf.batch_axis, drow,
                            gather_cache_page(src, leaf.batch_axis, srow),
                        )
                        moved = row_bytes
                    else:
                        live = min(count, leaf.smax)
                        moved = 0
                        if live > 0:
                            page = gather_cache_page(
                                src, leaf.batch_axis, srow, 0, live
                            )
                            scatter_cache_page(
                                dst, leaf.batch_axis, drow, page, 0, live
                            )
                            moved = page.nbytes
                    if fresh:
                        self.heal_warm_bytes += moved
                        self.heal_warm_bytes_full += row_bytes
        return new_cache

    @staticmethod
    def _old_cmp_role(old_world, phys: int, backfilled_from=None) -> int:
        """The old-world cmp role whose token stream physical ``phys``
        carried (a promoted replica carried its mirrored partner's; a
        backfilled spare carries the lost role's)."""
        role = old_world.role_of_physical(phys)
        if role is None:
            return backfilled_from
        if role >= old_world.topo.n_comp:
            role = old_world.topo.replica_of(role)
        return role

    # ------------------------------------------------------------------
    def _mirror_tokens(self, cmp_tokens: np.ndarray) -> np.ndarray:
        """Lay out per-cmp-slice request tokens in mesh order, mirroring the
        partner's stream onto replica slices."""
        src = self.world.topo.mirror_source()
        order = self.world.roles_in_mesh_order()
        return np.concatenate([cmp_tokens[src[r]] for r in order], axis=0)

    def decode(self, steps: int, prompt_tokens: Optional[np.ndarray] = None,
               failures: Optional[Dict[int, List[int]]] = None) -> np.ndarray:
        """Greedy-decode ``steps`` tokens for every request slot. Returns
        (n_comp * per_slice_batch, steps) generated ids."""
        assert not self.slot_granular, (
            "slot-granular engines are driven by repro.serving.gateway - "
            "lockstep decode() shares one position across the batch"
        )
        n_comp = self.world.topo.n_comp
        if prompt_tokens is None:
            prompt_tokens = np.ones(
                (n_comp, self.per_slice_batch, 1), dtype=np.int32
            )
        self._cur = prompt_tokens[:, :, -1:]
        self._out = []
        self._out_streams = []
        self.session.run(steps, FailureSchedule(failures))
        if not self._out:
            return np.zeros((n_comp, self.per_slice_batch, 0), np.int32)
        # elastic shrink mid-decode can drop streams anywhere in the batch;
        # align every token column on the streams that finished the run
        final = self._streams
        cols = [
            o[[streams.index(s) for s in final]]
            for streams, o in zip(self._out_streams, self._out)
        ]
        return np.stack(cols, axis=-1)
