"""Batched serving engine with replica failover.

The serving analogue of the paper's replication: replica slices mirror
their partner's request stream (same tokens, same order), so their KV
caches / SSM states are bit-identical. When a computational slice dies,
the promoted replica continues decoding from its own live cache: requests
lose NOTHING - no prefill re-run, no token loss. Unreplicated slice
failures re-queue their requests (prefill re-run after elastic shrink).

The decode step itself has no cross-slice collectives (the model axis is
GSPMD-managed), so the data plane stays failure-oblivious, exactly like the
paper's native-MPI plane.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType, Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ReplicationConfig
from repro.core import data_plane as DP
from repro.core.control_plane import ControlPlane, CommunicatorRevoked, ProcessFailed
from repro.core.elastic import shrink_mesh
from repro.core.replication import WorldState
from repro.dist.sharding import cache_shardings, param_shardings
from repro.models import model as M


@dataclass
class ServeReport:
    tokens_decoded: int = 0
    decode_seconds: float = 0.0
    failover_seconds: float = 0.0
    promotes: int = 0
    requeued_requests: int = 0
    events: List[str] = field(default_factory=list)


class ServeEngine:
    def __init__(
        self,
        model_cfg: ModelConfig,
        *,
        n_slices: int,
        model_shards: int = 1,
        rdegree: float = 0.0,
        per_slice_batch: int = 2,
        max_len: int = 128,
        seed: int = 0,
        params=None,
    ):
        n_dev = len(jax.devices())
        assert n_dev >= n_slices * model_shards
        self.model_cfg = model_cfg
        self.repl = ReplicationConfig(rdegree=rdegree)
        self.per_slice_batch = per_slice_batch
        self.max_len = max_len
        self.base_mesh = Mesh(
            np.array(jax.devices()[: n_slices * model_shards]).reshape(
                n_slices, model_shards
            ),
            ("data", "model"),
            axis_types=(AxisType.Auto, AxisType.Auto),
        )
        self.world = WorldState.create(n_slices, rdegree)
        self.control = ControlPlane(heartbeat_timeout=1e9)
        self.report = ServeReport()
        self.generation = 0

        self.params_host = params or M.init(jax.random.PRNGKey(seed), model_cfg)
        self.mesh: Mesh = None
        self.cache = None
        self.pos = 0
        self._rebuild(fresh_cache=True)

    # ------------------------------------------------------------------
    def _rows(self) -> int:
        return self.world.topo.n_slices * self.per_slice_batch

    def _rebuild(self, fresh_cache: bool = False) -> None:
        live = self.world.live_physicals()
        self.mesh = shrink_mesh(self.base_mesh, live)
        with jax.set_mesh(self.mesh):
            pshard = param_shardings(self.params_host, self.mesh, self.model_cfg)
            self.params = jax.device_put(self.params_host, pshard)
            if fresh_cache or self.cache is None:
                enc_len = 64 if self.model_cfg.enc_layers else 0
                cache_host = M.init_cache(
                    self.model_cfg, self._rows(), max_len=self.max_len,
                    enc_len=enc_len, dtype=jnp.float32,
                )
            else:
                cache_host = self.cache  # survivors' mirrored caches (host copy)
            cshard = cache_shardings(cache_host, self.mesh, shard_batch=True)
            self.cache = jax.device_put(cache_host, cshard)
            self.step_fn = DP.build_serve_step(
                self.model_cfg, self.repl, self.mesh, self.world,
                shard_batch=True, donate=False, cache_example=self.cache,
            )

    # ------------------------------------------------------------------
    def _mirror_tokens(self, cmp_tokens: np.ndarray) -> np.ndarray:
        """Lay out per-cmp-slice request tokens in mesh order, mirroring the
        partner's stream onto replica slices."""
        topo = self.world.topo
        src = topo.mirror_source()
        order = self.world.roles_in_mesh_order()
        return np.concatenate([cmp_tokens[src[r]] for r in order], axis=0)

    def decode(self, steps: int, prompt_tokens: Optional[np.ndarray] = None,
               failures: Optional[Dict[int, List[int]]] = None) -> np.ndarray:
        """Greedy-decode ``steps`` tokens for every request slot. Returns
        (n_comp * per_slice_batch, steps) generated ids."""
        failures = dict(failures or {})
        topo = self.world.topo
        n_comp = topo.n_comp
        if prompt_tokens is None:
            prompt_tokens = np.ones(
                (n_comp, self.per_slice_batch, 1), dtype=np.int32
            )
        cur = prompt_tokens[:, :, -1:]
        out: List[np.ndarray] = []
        t = 0
        while t < steps:
            if t in failures:
                for v in failures.pop(t):
                    if v in self.world.assignment:
                        self.control.report_failure(v)
            try:
                self.control.check(self.generation)
            except (CommunicatorRevoked, ProcessFailed):
                self._failover(t)
                topo = self.world.topo
                n_comp = topo.n_comp
                cur = cur[:n_comp]
                continue

            fed = self._mirror_tokens(cur)
            t0 = time.perf_counter()
            with jax.set_mesh(self.mesh):
                next_fed, self.cache = self.step_fn(
                    self.params, self.cache, jnp.asarray(fed), jnp.int32(self.pos)
                )
            next_fed = np.asarray(next_fed)
            self.report.decode_seconds += time.perf_counter() - t0
            # computational slices' outputs are authoritative
            order = self.world.roles_in_mesh_order()
            by_role = {
                r: next_fed[i * self.per_slice_batch : (i + 1) * self.per_slice_batch]
                for i, r in enumerate(order)
            }
            cmp_next = np.stack([by_role[c] for c in range(n_comp)])
            out.append(cmp_next[..., 0])
            cur = cmp_next
            self.pos += 1
            self.report.tokens_decoded += n_comp * self.per_slice_batch
            t += 1
        if not out:
            return np.zeros((n_comp, self.per_slice_batch, 0), np.int32)
        # elastic shrink mid-decode can reduce rows; align on the survivors
        rows = min(o.shape[0] for o in out)
        return np.stack([o[:rows] for o in out], axis=-1)

    # ------------------------------------------------------------------
    def _failover(self, t: int) -> None:
        """Repair the serving world: promoted replicas keep their caches."""
        t0 = time.perf_counter()
        self.control.revoke()
        failed = self.control.agree()
        cache_host = jax.tree.map(np.asarray, self.cache)  # survivors' caches
        old_world = self.world
        new_world, rep = self.world.repair(sorted(failed))
        self.report.promotes += len(rep["promoted"])
        self.report.requeued_requests += len(rep["lost_cmp"]) * self.per_slice_batch

        # re-pack cache rows: new mesh order draws each role's cache from the
        # physical slice that now owns it (promoted replicas carry theirs)
        old_pos = old_world.mesh_position()
        new_order = new_world.roles_in_mesh_order()

        def repack(arr):
            # arr (..., B_old_total, ...) with batch at axis 1 (stacked caches)
            b = self.per_slice_batch
            rows = []
            for r in new_order:
                phys = new_world.assignment[r]
                src_row = old_pos[phys]
                rows.append(arr[:, src_row * b : (src_row + 1) * b])
            return np.concatenate(rows, axis=1)

        cache_host = jax.tree.map(repack, cache_host)
        self.world = new_world
        self.cache = cache_host
        self._rebuild(fresh_cache=False)
        self.control.shrink_complete(failed)
        self.generation = new_world.generation
        self.report.failover_seconds += time.perf_counter() - t0
        self.report.events.append(
            f"token {t}: failed={sorted(failed)} promoted={rep['promoted']} "
            f"lost={rep['lost_cmp']}"
        )
