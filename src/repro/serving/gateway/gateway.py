"""ServeGateway - the request router over a slot-granular ServeEngine.

The gateway IS the session's program: it wraps the engine's data-plane
hooks and owns the request lifecycle around them -

- ``submit`` -> :class:`AdmissionQueue` (bounded, :class:`QueueFull`
  backpressure beyond ``max_queue``);
- each serve step: admit scheduled arrivals, refill freed slots from the
  queue (:class:`ContinuousBatcher`), one ``step_slots`` decode, stream
  the outputs;
- ``on_recover`` (the session's recovery-window notification, fired after
  repack/regenerate and before replay): in-flight requests whose role
  died unmirrored are pulled off the batcher and requeued AT THE FRONT
  with their streamed prefix pinned; surviving bindings are remapped
  through the repair's role renumbering; backfilled roles' slots are
  zeroed so re-prefill starts from a fresh sequence. Promoted replicas
  carry their slots' mirrored caches - their requests never notice.

Greedy decode is deterministic and slot rows are computationally
independent, so a requeued request's re-generated tokens match what the
client already streamed byte-for-byte (the batcher verifies this), and
the stream continues with zero duplicated or dropped tokens: the paper's
Sec. I "drop the failed processes and continue" made client-invisible.

``reinit_roles = True`` tells FTSession that spare backfill is safe
without a recovery-ladder restore: a zeroed slot is a valid starting
state because the gateway re-prefills from pinned prefixes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.ft import FailureSchedule, ResilientProgram
from repro.serving.gateway.batcher import ContinuousBatcher
from repro.serving.gateway.queue import (
    AdmissionQueue,
    QueueFull,
    Request,
    RequestStream,
)
from repro.serving.gateway.registry import StallSentinel, WorkerRegistry


def validate_bounds(max_queue: int, max_batch_slots: Optional[int],
                    page_tokens: Optional[int] = None) -> None:
    """Reject nonsensical gateway bounds loudly (zero/negative queues or
    slot caps would deadlock admission or the batcher; a bad page size
    would corrupt the slot->page mapping far from the flag that set it).
    ``page_tokens`` must be a positive power of two (page extents must
    tile the ring capacities evenly); the dense legacy layout is an
    engine-API baseline (``ServeEngine(page_tokens=0)`` - the bench
    oracle), not a CLI mode."""
    if max_queue < 1:
        raise ValueError(f"--max-queue must be >= 1, got {max_queue}")
    if max_batch_slots is not None and max_batch_slots < 1:
        raise ValueError(
            f"--max-batch-slots must be >= 1 (or unset), got {max_batch_slots}"
        )
    if page_tokens is not None:
        if page_tokens < 1:
            raise ValueError(
                f"--page-tokens must be >= 1, got {page_tokens}"
            )
        if page_tokens & (page_tokens - 1):
            raise ValueError(
                f"--page-tokens must be a power of two, got {page_tokens}"
            )


@dataclass
class GatewayStats:
    steps: int = 0
    idle_steps: int = 0
    completed: int = 0
    requeues: int = 0
    recoveries: int = 0
    stall_evictions: int = 0


class ServeGateway(ResilientProgram):
    #: spare backfill needs no ladder restore - requeued requests
    #: re-prefill from their pinned prefixes onto zeroed slots
    reinit_roles = True

    def __init__(
        self,
        engine,
        *,
        max_queue: int = 64,
        max_batch_slots: Optional[int] = None,
        verify_replay: bool = True,
        stall_window: Optional[int] = None,
    ):
        validate_bounds(max_queue, max_batch_slots)
        assert engine.slot_granular, (
            "ServeGateway drives slot-granular engines - build the "
            "ServeEngine with slot_granular=True"
        )
        assert not engine.session.ladder, (
            "the gateway recovers by requeue (snapshot() is None) - drop "
            "snapshot_every/stores from the engine"
        )
        self.engine = engine
        self.session = engine.session
        # the gateway takes the engine's place as the session's program:
        # run_step/on_recover wrap the engine's data-plane hooks
        self.session.program = self
        self.registry = WorkerRegistry(engine.n_lanes)
        self.registry.sync(engine.world)
        self.session.healer.on_capacity = self.registry.on_heal
        self.queue = AdmissionQueue(max_queue)
        self.batcher = ContinuousBatcher(
            engine, self.registry, max_slots=max_batch_slots,
            verify_replay=verify_replay,
        )
        #: fail-slow eviction: a cmp role whose bound slots stop advancing
        #: for > stall_window serve steps is reported to the control plane
        #: as failed - the SAME recovery window that handles crashes then
        #: requeues its requests (deadline-bounded failover for gray
        #: workers). None = crash-detection only.
        self.sentinel = StallSentinel(stall_window) if stall_window else None
        self.stats = GatewayStats()
        self.streams: Dict[int, RequestStream] = {}
        self._next_rid = 0
        self._arrivals: Dict[int, List[Request]] = {}  # step -> requests
        self._step = 0

    # ---- client API --------------------------------------------------------
    def submit(
        self,
        prompt,
        max_new: int,
        eos_id: Optional[int] = None,
        at_step: Optional[int] = None,
    ) -> RequestStream:
        """Admit a generation request. Raises :class:`QueueFull` when the
        admission queue is at capacity (the backpressure signal) and
        ``ValueError`` on requests the engine could never serve.

        ``at_step`` defers admission to a future serve step (an arrival
        process for benchmarks); a deferred arrival that meets a full
        queue is rejected by finishing its stream with reason
        ``"rejected"`` instead of raising mid-serve.
        """
        prompt = tuple(int(t) for t in prompt)
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if len(prompt) + max_new > self.engine.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new}) exceeds the "
                f"engine's max_len ({self.engine.max_len})"
            )
        rid = self._next_rid
        self._next_rid += 1
        step = self._step if at_step is None else at_step
        stream = RequestStream(rid, submitted_step=step)
        req = Request(rid=rid, prompt=prompt, max_new=max_new,
                      eos_id=eos_id, stream=stream)
        self.streams[rid] = stream
        if at_step is None or at_step <= self._step:
            self.queue.admit(req)  # may raise QueueFull - caller backs off
        else:
            self._arrivals.setdefault(at_step, []).append(req)
        return stream

    def pending(self) -> int:
        """Requests not yet finished: queued, in-flight, or scheduled."""
        return (
            len(self.queue)
            + len(self.batcher.states)
            + sum(len(v) for v in self._arrivals.values())
        )

    def serve(
        self,
        max_steps: int,
        failures: Union[None, FailureSchedule, Dict[int, List[int]]] = None,
    ) -> GatewayStats:
        """Run the serve loop until every submitted request finishes (or
        ``max_steps`` serve steps elapse), injecting scheduled failures at
        step boundaries. Resumable: call again after more ``submit``s."""
        schedule = (
            failures if isinstance(failures, FailureSchedule)
            else FailureSchedule(failures)
        )
        while self._step < max_steps and (self.pending() or schedule):
            t = self._step
            self.session.run(t + 1, schedule, start_step=t)
            self._step = t + 1
        return self.stats

    # ---- ResilientProgram hooks (the session's view) -----------------------
    def build_step(self, mesh, world) -> None:
        self.engine.build_step(mesh, world)

    def run_step(self, t: int) -> None:
        for req in self._arrivals.pop(t, []):
            try:
                self.queue.admit(req)
            except QueueFull:
                req.stream.finish("rejected", t)
        self.batcher.refill(self.queue, t)
        self.stats.steps += 1
        if not self.batcher.states:
            self.stats.idle_steps += 1
            return
        fed = self.batcher.build_fed()
        out = self.engine.step_slots(fed)
        finished = self.batcher.consume(out, t)
        self.stats.completed += len(finished)
        if self.sentinel is not None:
            self._observe_stalls()
        self.registry.check()

    def _observe_stalls(self) -> None:
        """One stall observation per serve step: max ``fed`` per bound cmp
        role. A role the sentinel convicts is reported to the control
        plane as its PHYSICAL slice - ``session.run``'s next dispatch
        guard then opens the ordinary recovery window (repack, requeue,
        spare backfill), evicting the slow worker exactly like a dead
        one."""
        progress: Dict[int, int] = {}
        for st in self.batcher.states.values():
            role = st.slot[0]
            progress[role] = max(progress.get(role, -1), st.fed)
        for role in self.sentinel.observe(progress):
            phys = self.engine.world.assignment[role]
            self.session.control.report_failure(phys)
            self.stats.stall_evictions += 1

    def snapshot(self):
        """No ladder snapshots: the gateway's recovery currency is the
        requeue (pinned prefixes re-prefill deterministically)."""
        return None

    def repack_state(self, old_world, new_world) -> None:
        self.engine.repack_state(old_world, new_world)

    def replay_inputs(self, plan) -> None:
        self.engine.replay_inputs(plan)

    # ---- the failover hook -------------------------------------------------
    def on_recover(self, old_world, new_world, rep, plan) -> None:
        """Recovery-window notification (after repack + regenerate, before
        replay): requeue the dead unmirrored roles' in-flight requests and
        re-derive the slot table for the new world."""
        role_map: Dict[int, int] = rep.get("role_map", {})  # new -> old
        old_to_new = {old: new for new, old in role_map.items()}
        backfilled_new = [r for r, _ in rep.get("backfilled", [])]
        backfilled_old = {role_map[r] for r in backfilled_new}
        dead_old = set(rep.get("lost_cmp", [])) | backfilled_old

        # engine.repack_state already charged lost_cmp slots to
        # report.requeued_requests; backfilled roles survive the repack
        # (their slot rows carry over) so their victims are charged here
        n_backfill_victims = sum(
            1 for st in self.batcher.states.values()
            if st.slot[0] in backfilled_old
        )
        victims = self.batcher.evict_roles(dead_old)  # (role, lane) order
        self.engine.report.requeued_requests += n_backfill_victims

        # surviving bindings follow the repair's dense renumbering; the
        # registry re-derives the pool from the healed world and re-adopts
        # the remapped assignment
        self.registry.sync(new_world)
        self.batcher.remap_roles(old_to_new)
        self.registry.rebind(self.batcher.bound_map())

        # a backfilled role's rows are the dead slice's stale state: zero
        # them and mark the lanes free - requeued victims re-prefill onto
        # fresh sequences wherever the next refill binds them
        if backfilled_new:
            fresh = [
                (r, lane)
                for r in backfilled_new
                for lane in range(self.registry.lanes)
            ]
            self.engine.reset_slots(fresh)
            for slot in fresh:
                self.engine.slot_active[slot] = False

        # front-priority requeue, preserving (role, lane) order at the head
        for req in reversed(victims):
            req.requeues += 1
            self.queue.requeue(req)
        self.stats.requeues += len(victims)
        self.stats.recoveries += 1
        if self.sentinel is not None:
            self.sentinel.reset()  # roles renumbered: stall marks are stale
        self.registry.check()

    # ---- reporting ---------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        rep = self.engine.report
        ttfts = [
            s.ttft_steps() for s in self.streams.values()
            if s.ttft_steps() is not None
        ]
        return {
            "steps": self.stats.steps,
            "idle_steps": self.stats.idle_steps,
            "completed": self.stats.completed,
            "admitted": self.queue.admitted,
            "rejected": self.queue.rejected,
            "requeues": self.stats.requeues,
            "recoveries": self.stats.recoveries,
            "stall_evictions": self.stats.stall_evictions,
            "tokens_decoded": rep.tokens_decoded,
            "requeued_requests": rep.requeued_requests,
            "ttft_p50_steps": float(np.percentile(ttfts, 50)) if ttfts else 0.0,
            "ttft_p99_steps": float(np.percentile(ttfts, 99)) if ttfts else 0.0,
            **self._page_stats(),
        }

    def _page_stats(self) -> Dict[str, float]:
        """Paged-state occupancy of the live pool (empty for dense
        engines): how many pages the bound slots reference, how many of
        those are shared prompt-prefix pages, and the dedupe ratio
        (references served per distinct shared page)."""
        table = getattr(self.engine, "table", None)
        if table is None:
            return {}
        # a gateway without a snapshot ladder never gathers pages, so pull
        # the live slot positions (and claim shareable prefix pages) here -
        # idempotent, and exactly what a snapshot gather would have done
        sync = getattr(self.engine, "_sync_counts", None)
        if sync is not None:
            sync()
        total = shared = 0
        for e in table.slots.values():
            table._refresh_sharing(e)
            for ref in table.slot_pages(e):
                total += 1
                shared += bool(ref.shared)
        distinct = len(table.refs)
        return {
            "pages_live": total,
            "pages_shared_refs": shared,
            "pages_shared_distinct": distinct,
            "prefix_dedupe_ratio": (shared / distinct) if distinct else 0.0,
        }
