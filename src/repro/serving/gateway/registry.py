"""Elastic worker/slot registry - the gateway's live view of the pool.

Workers are physical slices with a serving kind: ``cmp`` slices own
decode slots (one per lane), ``replica`` slices mirror a partner (no
slots of their own - they are the FT plane), ``spare`` slices stand by.
The registry is re-derived from the :class:`WorldState` on every recovery
window (:meth:`sync`), and the heal plane's capacity callback
(:meth:`on_heal`, wired to ``Healer.on_capacity``) records healed
replicas and spare backfills re-registering LIVE - the
``WorldState.heal()`` -> gateway-capacity path, the same shape as an
elastic worker pool where recovered hosts rejoin mid-serve.

Slot ids are ``(cmp_role, lane)``; ``bind``/``release`` keep the
slot -> request assignment an injection (one request per slot, one slot
per request) that :meth:`check` asserts - the property suite's bijection
invariant.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

Slot = Tuple[int, int]  # (cmp_role, lane)


@dataclass
class Worker:
    physical: int
    role: Optional[int]  # cmp/rep role id; None for spares
    kind: str  # "cmp" | "replica" | "spare"


class StallSentinel:
    """Fail-slow watchdog over the decode pool: one observation per serve
    step maps each cmp role WITH bound slots to a progress mark (the max
    ``fed`` across its slots). A role whose mark stops advancing for more
    than ``window`` consecutive observations is stalled - the gray-failure
    analogue of a crashed worker. The gateway reports it to the control
    plane so the ordinary recovery/requeue machinery evicts it instead of
    letting its streams wedge forever.

    Deliberately clock-free (the observation count IS the clock) and pure
    over its inputs, so the stall policy is unit-testable without a
    gateway. Roles absent from an observation (no bound slots) are
    forgotten: an idle role is not a stalled one.
    """

    def __init__(self, window: int):
        if window < 1:
            raise ValueError(f"stall window must be >= 1, got {window}")
        self.window = int(window)
        self._marks: Dict[int, Tuple[int, int]] = {}  # role -> (mark, obs last advanced)
        self._obs = 0

    def observe(self, role_progress: Dict[int, int]) -> List[int]:
        self._obs += 1
        stalled: List[int] = []
        for role, mark in role_progress.items():
            last = self._marks.get(role)
            if last is None or mark > last[0]:
                self._marks[role] = (mark, self._obs)
            elif self._obs - last[1] > self.window:
                stalled.append(role)
                # re-arm: one conviction per elapsed window, not one per
                # observation (recovery usually intervenes first anyway)
                self._marks[role] = (mark, self._obs)
        for role in list(self._marks):
            if role not in role_progress:
                del self._marks[role]
        return sorted(stalled)

    def reset(self) -> None:
        """Recovery window: the repair renumbered roles, every mark is
        stale - restart the stall clock for the new world."""
        self._marks = {}
        self._obs = 0


class WorkerRegistry:
    def __init__(self, lanes: int):
        assert lanes >= 1, lanes
        self.lanes = lanes
        self.n_comp = 0
        self.workers: Dict[int, Worker] = {}
        self.events: List[str] = []
        self._bound: Dict[Slot, int] = {}  # slot -> rid
        self.generation = -1

    # ---- pool membership ---------------------------------------------------
    def sync(self, world) -> None:
        """Re-derive the worker table from a (possibly just-repaired and
        healed) world. Bindings are NOT carried over - the gateway rebinds
        surviving requests through the repair's role renumbering."""
        topo = world.topo
        self.workers = {}
        for c in topo.cmp_roles():
            self.workers[world.assignment[c]] = Worker(world.assignment[c], c, "cmp")
        for r in topo.rep_roles():
            self.workers[world.assignment[r]] = Worker(world.assignment[r], r, "replica")
        for s in world.spares:
            self.workers[s] = Worker(s, None, "spare")
        self.n_comp = topo.n_comp
        self.generation = world.generation
        self._bound = {}

    def on_heal(self, world, plan, fresh: List[int]) -> None:
        """Capacity callback (``Healer.on_capacity``): new physicals
        entered the world inside this recovery window - healed replicas
        re-arming the failover pool, backfilled spares growing the decode
        pool back to width. Logged here; :meth:`sync` (which runs after
        the window's repack) folds them into the worker table."""
        healed = {a.spare: a.cmp_role for a in plan.actions} if plan else {}
        for p in fresh:
            if p in healed:
                self.events.append(
                    f"gen {world.generation}: phys {p} re-registered as "
                    f"replica of cmp {healed[p]} (heal)"
                )
            else:
                self.events.append(
                    f"gen {world.generation}: phys {p} backfilled into the "
                    "decode pool (spare promote)"
                )

    # ---- slots -------------------------------------------------------------
    @property
    def n_slots(self) -> int:
        return self.n_comp * self.lanes

    def slots(self) -> List[Slot]:
        return [(c, l) for c in range(self.n_comp) for l in range(self.lanes)]

    def free_slots(self) -> List[Slot]:
        return [s for s in self.slots() if s not in self._bound]

    def bind(self, slot: Slot, rid: int) -> None:
        assert slot not in self._bound, f"slot {slot} already bound"
        assert 0 <= slot[0] < self.n_comp and 0 <= slot[1] < self.lanes, slot
        self._bound[slot] = rid

    def release(self, slot: Slot) -> int:
        return self._bound.pop(slot)

    def rebind(self, bound: Dict[Slot, int]) -> None:
        """Install a full slot->request assignment after a recovery
        window's renumbering (validated like per-slot binds)."""
        self._bound = {}
        for slot, rid in bound.items():
            self.bind(slot, rid)

    def bound(self) -> Dict[Slot, int]:
        return dict(self._bound)

    def check(self) -> None:
        """Assignment invariants: every bound slot names a live cmp role
        and lane, and the slot -> request map is injective both ways."""
        rids = list(self._bound.values())
        assert len(rids) == len(set(rids)), f"request bound twice: {self._bound}"
        for (c, l) in self._bound:
            assert 0 <= c < self.n_comp, f"slot on dead role {c}"
            assert 0 <= l < self.lanes, f"lane {l} out of range"
        kinds = [w.kind for w in self.workers.values()]
        assert kinds.count("cmp") == self.n_comp, (self.workers, self.n_comp)
