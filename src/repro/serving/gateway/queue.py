"""Admission queue + the request/stream objects it carries.

The queue is the gateway's backpressure point: NEW requests are admitted
FIFO up to ``max_queue`` and rejected loudly beyond it (:class:`QueueFull`
- the client's signal to back off). Failover REQUEUES bypass both the
bound and the FIFO order: a request whose slot died re-enters at the
front with its already-streamed prefix pinned, so it re-prefills before
fresh work is admitted and its client stream resumes with zero duplicated
or dropped tokens. Dropping a requeue would silently lose an accepted
request, so requeues are always accepted.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Tuple


class QueueFull(RuntimeError):
    """Admission rejected: the bounded queue is at capacity (backpressure)."""


class RequestStream:
    """Per-request client-visible output stream.

    ``tokens`` only ever grows, one generated id per index, each emitted
    exactly once (the monotonic cursor): across failovers the batcher
    suppresses re-generated tokens below the cursor and the stream
    continues byte-identically from where the client last read.
    """

    def __init__(self, rid: int, submitted_step: int):
        self.rid = rid
        self.tokens: List[int] = []
        self.done = False
        self.finish_reason: Optional[str] = None
        self.submitted_step = submitted_step
        self.first_token_step: Optional[int] = None
        self.finished_step: Optional[int] = None
        self.submitted_t = time.perf_counter()
        self.first_token_t: Optional[float] = None

    @property
    def cursor(self) -> int:
        """Number of generated tokens the client has seen."""
        return len(self.tokens)

    def ttft_steps(self) -> Optional[int]:
        """Time-to-first-token in decode steps (None until the first
        token lands)."""
        if self.first_token_step is None:
            return None
        return self.first_token_step - self.submitted_step

    # ---- batcher-side (package-internal) -----------------------------------
    def emit(self, tok: int, step: int) -> None:
        assert not self.done, f"emit on finished stream {self.rid}"
        if self.first_token_step is None:
            self.first_token_step = step
            self.first_token_t = time.perf_counter()
        self.tokens.append(int(tok))

    def finish(self, reason: str, step: int) -> None:
        self.done = True
        self.finish_reason = reason
        self.finished_step = step


@dataclass
class Request:
    """One admitted generation request. ``stream`` is the client handle;
    ``prefix`` (prompt + everything already streamed) is what a requeued
    request re-prefills from - the pin that makes failover invisible."""

    rid: int
    prompt: Tuple[int, ...]
    max_new: int
    eos_id: Optional[int] = None
    stream: RequestStream = None
    requeues: int = 0
    arrivals: List[int] = field(default_factory=list)  # bind steps (TTFT trail)

    @property
    def prefix(self) -> Tuple[int, ...]:
        return tuple(self.prompt) + tuple(self.stream.tokens)


class AdmissionQueue:
    """Bounded FIFO with front-priority requeues."""

    def __init__(self, max_queue: int = 64):
        assert max_queue >= 1, max_queue
        self.max_queue = max_queue
        self._q: Deque[Request] = deque()
        self.admitted = 0
        self.rejected = 0
        self.requeued = 0

    def admit(self, req: Request) -> None:
        """FIFO admission of a new request; raises :class:`QueueFull` at
        capacity (the backpressure signal - nothing is silently dropped)."""
        if len(self._q) >= self.max_queue:
            self.rejected += 1
            raise QueueFull(
                f"admission queue full ({self.max_queue}); retry later"
            )
        self._q.append(req)
        self.admitted += 1

    def requeue(self, req: Request) -> None:
        """Front-priority re-entry for a request whose slot died. Always
        accepted: the request was already admitted, and dropping it here
        would turn a masked failure into a lost request."""
        self._q.appendleft(req)
        self.requeued += 1

    def pop(self) -> Optional[Request]:
        return self._q.popleft() if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def __iter__(self):
        return iter(self._q)
