"""Continuous batcher - slot-level request scheduling over the engine.

Instead of fixed-width lockstep waves (every sequence decodes ``steps``
tokens and the whole batch turns over at once), each ``(cmp_role, lane)``
slot runs its own sequence: a slot frees the moment its request hits
EOS/max-new and is refilled from the admission queue on the NEXT step,
while its neighbours keep decoding at their own depths (the engine's
per-slot positions make a freed slot a fresh sequence - zeroed rows,
position 0).

Prefill is folded into the same stepping: a freshly bound request feeds
its prefix (prompt + any pinned, already-streamed tokens from a previous
incarnation) one token per step; outputs below the stream's cursor are
re-generations and are suppressed (greedy decode is deterministic, so
they are verified byte-equal to what the client already saw), and the
first output past the cursor continues the client stream with zero
duplicated or dropped tokens - failover-transparent resume.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.gateway.queue import AdmissionQueue, Request
from repro.serving.gateway.registry import Slot, WorkerRegistry

PAD_TOKEN = 0


@dataclass
class SlotState:
    """A request bound to a slot. ``fed`` counts prefix/sequence tokens
    already fed to the engine: the slot's engine position equals ``fed``,
    and the output after feeding index ``i`` predicts sequence index
    ``i + 1`` (prompt indices are skipped, generated indices below the
    stream cursor are replay-verified, the rest are emitted)."""

    req: Request
    slot: Slot
    fed: int = 0
    bound_step: int = 0


class ContinuousBatcher:
    def __init__(
        self,
        engine,
        registry: WorkerRegistry,
        max_slots: Optional[int] = None,
        verify_replay: bool = True,
    ):
        self.engine = engine
        self.registry = registry
        self.max_slots = max_slots  # None = every slot the world offers
        self.verify_replay = verify_replay
        self.states: Dict[int, SlotState] = {}  # rid -> state
        self.refills = 0

    # ------------------------------------------------------------------
    def _slot_budget(self) -> int:
        cap = self.registry.n_slots
        if self.max_slots is not None:
            cap = min(cap, self.max_slots)
        return cap - len(self.states)

    def refill(self, queue: AdmissionQueue, step: int) -> List[int]:
        """Bind queued requests onto free slots (front of the queue first,
        lowest slot first). Each bind resets the slot to a fresh sequence.
        Returns the rids bound this step."""
        bound: List[int] = []
        free = self.registry.free_slots()
        fresh: List[Tuple[Slot, Request]] = []
        while queue and free and self._slot_budget() > 0:
            req = queue.pop()
            slot = free.pop(0)
            self.registry.bind(slot, req.rid)
            self.states[req.rid] = SlotState(req=req, slot=slot, bound_step=step)
            req.arrivals.append(step)
            fresh.append((slot, req))
            bound.append(req.rid)
        if fresh:
            self.engine.reset_slots([s for s, _ in fresh])
            note = getattr(self.engine, "note_prompt", None)
            for slot, req in fresh:
                self.engine.slot_active[slot] = True
                if note is not None:
                    # pin the request's full prefix (prompt + pinned
                    # replay tokens from a previous incarnation) so the
                    # paged engine can content-address the prefix pages
                    # and share them across same-prefix requests
                    note(slot, req.prefix)
            self.refills += len(fresh)
        return bound

    def build_fed(self) -> np.ndarray:
        """The (n_comp, lanes) token matrix for the next engine step: each
        bound slot's next sequence token; PAD for idle lanes."""
        fed = np.full(
            (self.registry.n_comp, self.registry.lanes), PAD_TOKEN, np.int32
        )
        for st in self.states.values():
            seq = st.req.prefix
            assert st.fed < len(seq), (st.req.rid, st.fed, len(seq))
            fed[st.slot] = seq[st.fed]
        return fed

    def consume(self, out: np.ndarray, step: int) -> List[Request]:
        """Distribute one step's outputs. Emits past-cursor tokens,
        replay-verifies re-generated ones, finishes sequences at
        EOS/max-new and frees their slots. Returns finished requests."""
        finished: List[Request] = []
        for rid in sorted(self.states):
            st = self.states[rid]
            req, stream = st.req, st.req.stream
            tok = int(out[st.slot])
            predicted = st.fed + 1  # sequence index this output predicts
            st.fed = predicted
            gen_idx = predicted - len(req.prompt)
            if gen_idx < 0:
                continue  # still feeding prompt tokens
            if gen_idx < stream.cursor:
                # re-generation of a pinned, already-streamed token: the
                # client saw it - suppress, and prove the resumed sequence
                # is byte-identical to what was served before the failure
                if self.verify_replay:
                    assert tok == stream.tokens[gen_idx], (
                        f"request {rid}: replayed token {gen_idx} diverged "
                        f"({tok} != {stream.tokens[gen_idx]})"
                    )
                continue
            stream.emit(tok, step)
            if req.eos_id is not None and tok == req.eos_id:
                self._finish(st, "eos", step)
                finished.append(req)
            elif stream.cursor >= req.max_new:
                self._finish(st, "max_new", step)
                finished.append(req)
        return finished

    def _finish(self, st: SlotState, reason: str, step: int) -> None:
        st.req.stream.finish(reason, step)
        self.registry.release(st.slot)
        self.engine.slot_active[st.slot] = False
        del self.states[st.req.rid]

    # ---- failover ----------------------------------------------------------
    def evict_roles(self, old_roles) -> List[Request]:
        """Pull every in-flight request off ``old_roles`` (old-world cmp
        ids whose slot state is gone: truly lost roles and spare-backfilled
        ones). Returned in (role, lane) order - the gateway requeues them
        at the queue front in that order."""
        victims = sorted(
            (st for st in self.states.values() if st.slot[0] in old_roles),
            key=lambda st: st.slot,
        )
        for st in victims:
            del self.states[st.req.rid]
        return [st.req for st in victims]

    def remap_roles(self, old_to_new: Dict[int, int]) -> None:
        """Apply a repair's cmp-role renumbering to surviving bindings
        (evict_roles must have removed dead-role states first)."""
        for st in self.states.values():
            st.slot = (old_to_new[st.slot[0]], st.slot[1])

    def bound_map(self) -> Dict[Slot, int]:
        return {st.slot: rid for rid, st in self.states.items()}
