"""repro.serving.gateway - the serving front door.

Owns the full request lifecycle over a slot-granular
:class:`~repro.serving.engine.ServeEngine`:

- :class:`AdmissionQueue` - bounded admission with backpressure; failover
  requeues re-enter at the FRONT with their streamed prefix pinned;
- :class:`WorkerRegistry` - the elastic worker/slot pool, re-derived from
  the ``WorldState`` each recovery window and grown live by the heal
  plane's capacity callback;
- :class:`ContinuousBatcher` - slots free as sequences hit EOS/max-new
  and refill from the queue mid-decode (no lockstep waves);
- :class:`ServeGateway` - the request API + the failover-transparent
  recovery hooks that make the FT plane invisible to clients.
"""
from repro.serving.gateway.batcher import ContinuousBatcher
from repro.serving.gateway.gateway import ServeGateway, validate_bounds
from repro.serving.gateway.queue import (
    AdmissionQueue,
    QueueFull,
    Request,
    RequestStream,
)
from repro.serving.gateway.registry import StallSentinel, Worker, WorkerRegistry

__all__ = [
    "AdmissionQueue",
    "ContinuousBatcher",
    "QueueFull",
    "Request",
    "RequestStream",
    "ServeGateway",
    "StallSentinel",
    "Worker",
    "WorkerRegistry",
    "validate_bounds",
]
