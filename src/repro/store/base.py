"""The ``StateStore`` protocol - one API for every recovery-state plane.

ReStore (Huebner et al., 2022) argues that sub-second restore needs a
dedicated storage layer with an explicit submit/load API rather than
checkpoint logic scattered through the application. This module is that
layer's contract; the three backends map to the multi-level scheme the
paper's recovery model assumes (Sec. III-A / VI):

- level 0 ``LiveCloneStore``    - device-resident 3-phase clone (the
  process-image transfer, dynamic replica rebirth);
- level 1 ``PartnerMemoryStore`` - host-memory snapshots sharded K-way
  across surviving slices (ReStore-style redundancy);
- level 2 ``DurableStore``      - serialized npz + manifest on disk,
  double-buffered async writes, atomic publish, optional ref-counted
  on-disk delta chains with a bounded restore depth.

A store holds ``(step, state, meta)`` snapshots. ``state`` is any pytree;
serializing backends flatten it with :func:`flatten_with_paths` and
rebuild it against a template with :func:`unflatten_like` - the single
flatten/unflatten implementation in the repo (the checkpointer, the
serving cache repack and the clone verifier all used to hand-roll their
own).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.dist.sharding import path_str
from repro.xfer.chunking import PagedBlob
from repro.xfer.plane import stage_tree

PyTree = Any

#: what ``load`` returns: (step, state pytree, meta dict)
Restored = Tuple[int, PyTree, Dict]


def flatten_with_paths(tree: PyTree) -> Dict[str, np.ndarray]:
    """Flatten a pytree to ``{path: host ndarray}`` - the transfer plane's
    staging pass (:func:`repro.xfer.plane.stage_tree`), re-exported here
    because it is the ``StateStore`` serialization contract."""
    return stage_tree(tree)


def unflatten_like(template: PyTree, arrays: Dict[str, np.ndarray]) -> PyTree:
    """Rebuild ``template``'s structure from a path -> array mapping,
    coercing each leaf to the template's dtype/shape. A paged template
    rebuilds as a :class:`PagedBlob` of whatever pages the mapping holds -
    its page set is data, not structure (a restore may legitimately carry
    more or fewer pages than the template snapshot did)."""
    if isinstance(template, PagedBlob):
        return PagedBlob(arrays)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, leaf in flat:
        arr = arrays[path_str(kp)]
        leaves.append(np.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class StateStore:
    """Base class / contract for recovery-state backends.

    ``level`` orders backends in a :class:`~repro.store.ladder.RecoveryLadder`
    (lower = faster restore, tried first); ``name`` labels restore events
    and benchmark rows.
    """

    level: int = 99
    name: str = "store"
    #: True for backends whose submit only needs the flattened host blob;
    #: the RecoveryLadder then stages the state to host ONCE and fans the
    #: same blob out to every such level via :meth:`submit_blob`
    consumes_blob: bool = False

    # ---- writes ------------------------------------------------------------
    def submit(self, step: int, state: PyTree, meta: Optional[Dict] = None) -> None:
        """Snapshot ``state`` for ``step``. Must not mutate ``state`` and
        must capture its value before returning (callers mutate in place)."""
        raise NotImplementedError

    def submit_blob(self, step: int, blob: Dict[str, np.ndarray],
                    meta: Optional[Dict] = None) -> None:
        """Snapshot an already-staged host blob (``consumes_blob`` backends
        only). The blob's arrays are shared read-only with other levels."""
        raise NotImplementedError

    def wait(self) -> None:
        """Block until every submitted snapshot is fully persisted."""

    # ---- reads -------------------------------------------------------------
    def load(self, template: PyTree, step: Optional[int] = None) -> Optional[Restored]:
        """Newest (or requested) recoverable snapshot, or ``None``."""
        raise NotImplementedError

    def steps(self) -> List[int]:
        """Steps with a (possibly partial) snapshot, ascending."""
        raise NotImplementedError

    # ---- space management --------------------------------------------------
    def drop(self, step: int) -> None:
        """Forget the snapshot at ``step`` (no-op if absent)."""

    def trim(self, keep: int) -> None:
        """Keep only the newest ``keep`` snapshots."""

    # ---- failure plumbing --------------------------------------------------
    def on_failure(self, dead_physicals) -> None:
        """Failed physical slices were agreed dead; drop state that lived
        on them (memory stores). Default: durable/local stores unaffected."""
