"""RecoveryLadder: the ordered restore policy over a set of StateStores.

``FTSession._restore`` used to hand-roll the partner -> durable -> fresh
ladder (and the serving engine had no ladder at all); this object owns it:

- ``submit`` fans a snapshot out to every level (each store captures the
  state before returning, so one host staging pass feeds all of them);
- ``restore`` walks the levels in ascending ``level`` order (cheapest
  first), takes the first recoverable snapshot, optionally cross-verifies
  it, and records a :class:`RestoreAttempt` per level so benchmarks and
  reports can price each rung;
- ``on_failure`` forwards the agreed-dead physical slices to every store
  so memory-resident levels drop state that died with its host *before*
  the restore walk consults them.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.store.base import PyTree, StateStore, flatten_with_paths


@dataclass
class RestoreAttempt:
    level: int
    store: str
    ok: bool
    step: Optional[int] = None
    seconds: float = 0.0
    error: str = ""


@dataclass
class LadderRestore:
    """A successful restore: which rung served it, and the full walk."""

    level: int
    store: str
    step: int
    state: PyTree
    meta: Dict
    attempts: List[RestoreAttempt] = field(default_factory=list)


class RecoveryLadder:
    def __init__(self, stores: Sequence[StateStore]):
        self.stores: List[StateStore] = sorted(stores, key=lambda s: s.level)
        levels = [s.level for s in self.stores]
        assert len(set(levels)) == len(levels), f"duplicate ladder levels: {levels}"
        self.attempts: List[RestoreAttempt] = []  # last restore's walk

    # ---- accessors ---------------------------------------------------------
    def store(self, level: int) -> Optional[StateStore]:
        return next((s for s in self.stores if s.level == level), None)

    def levels(self) -> List[int]:
        return [s.level for s in self.stores]

    def __iter__(self):
        return iter(self.stores)

    def __bool__(self) -> bool:
        return bool(self.stores)

    # ---- writes ------------------------------------------------------------
    def submit(self, step: int, state: PyTree, meta: Optional[Dict] = None,
               levels: Optional[Sequence[int]] = None) -> None:
        """Fan the snapshot out to every (selected) level. Blob-consuming
        backends share ONE host staging pass: the state is flattened once
        and the same read-only blob feeds them all."""
        blob = None
        for s in self.stores:
            if levels is not None and s.level not in levels:
                continue
            if s.consumes_blob:
                if blob is None:
                    blob = flatten_with_paths(state)
                s.submit_blob(step, blob, meta)
            else:
                s.submit(step, state, meta)

    def wait(self) -> None:
        for s in self.stores:
            s.wait()

    def trim(self, keep: int) -> None:
        for s in self.stores:
            s.trim(keep)

    # ---- failure plumbing --------------------------------------------------
    def on_failure(self, dead_physicals: Sequence[int]) -> None:
        for s in self.stores:
            s.on_failure(dead_physicals)

    # ---- the ladder walk ---------------------------------------------------
    def restore(self, template: PyTree, step: Optional[int] = None
                ) -> Optional[LadderRestore]:
        """First recoverable snapshot, cheapest level first. ``None`` means
        every rung came up empty (the caller's fresh-init of last resort)."""
        self.attempts = []
        for s in self.stores:
            t0 = time.perf_counter()
            try:
                got = s.load(template, step=step)
                err = ""
            except Exception as e:  # a torn rung must not mask deeper ones
                got, err = None, f"{type(e).__name__}: {e}"
            dt = time.perf_counter() - t0
            if got is None:
                self.attempts.append(RestoreAttempt(
                    level=s.level, store=s.name, ok=False, seconds=dt, error=err
                ))
                continue
            rstep, state, meta = got
            self.attempts.append(RestoreAttempt(
                level=s.level, store=s.name, ok=True, step=rstep, seconds=dt
            ))
            return LadderRestore(
                level=s.level, store=s.name, step=rstep, state=state,
                meta=meta, attempts=list(self.attempts),
            )
        return None
