"""RecoveryLadder: the ordered restore policy over a set of StateStores.

``FTSession._restore`` used to hand-roll the partner -> durable -> fresh
ladder (and the serving engine had no ladder at all); this object owns it:

- ``submit`` fans a snapshot out to every level (each store captures the
  state before returning, so one host staging pass feeds all of them);
- ``submit_async`` is the pipelined fast path: mutable leaves are captured
  synchronously, then staging + placement run on the ladder's
  :class:`~repro.xfer.TransferPlane` stager, overlapping the next train
  step; ``drain`` is the barrier (reused by ``FTSession.run``'s teardown
  and the recovery window before the restore walk);
- ``restore`` walks the levels in ascending ``level`` order (cheapest
  first), takes the first recoverable snapshot, optionally cross-verifies
  it, and records a :class:`RestoreAttempt` per level so benchmarks and
  reports can price each rung;
- ``on_failure`` forwards the agreed-dead physical slices to every store
  so memory-resident levels drop state that died with its host *before*
  the restore walk consults them.
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.store.base import PyTree, StateStore, unflatten_like
from repro.xfer.chunking import ChunkedBlob, chunk_blob, leaf_bytes
from repro.xfer.deadline import Deadline
from repro.xfer.plane import TransferPlane, capture_tree, stage_tree


@dataclass
class RestoreAttempt:
    level: int
    store: str
    ok: bool
    step: Optional[int] = None
    seconds: float = 0.0
    error: str = ""
    #: backend-specific resolution note (e.g. the durable delta plane's
    #: "chain:3" - how many step dirs the chain restore read)
    detail: str = ""


@dataclass
class LadderRestore:
    """A successful restore: which rung served it, and the full walk."""

    level: int
    store: str
    step: int
    state: PyTree
    meta: Dict
    attempts: List[RestoreAttempt] = field(default_factory=list)
    detail: str = ""


@dataclass
class PartialRestore:
    """A digest-guided partial restore: the snapshot state reassembled by
    moving ONLY the chunks whose bytes differ from the caller's current
    state (ReStore-style partial recovery). ``moved_bytes`` vs
    ``total_bytes`` is the headline saving the sdc benchmarks report."""

    level: int
    store: str
    step: int
    state: PyTree
    meta: Dict
    n_chunks: int
    moved_chunks: int
    moved_bytes: int
    total_bytes: int


class RecoveryLadder:
    def __init__(self, stores: Sequence[StateStore],
                 *, xfer: Optional[TransferPlane] = None,
                 rung_deadline_s: float = 0.0):
        self.stores: List[StateStore] = sorted(stores, key=lambda s: s.level)
        levels = [s.level for s in self.stores]
        assert len(set(levels)) == len(levels), f"duplicate ladder levels: {levels}"
        self.attempts: List[RestoreAttempt] = []  # last restore's walk
        #: per-rung restore budget in seconds (0 = unbounded, the
        #: pre-gray-failure behavior): each rung's load gets its own fresh
        #: Deadline, so one stalled rung falls through instead of eating
        #: the whole recovery window
        self.rung_deadline_s = float(rung_deadline_s)
        # ONE transfer plane per ladder: chunk-consuming levels adopt it so
        # a submit's striping/delta/pipelining config is set in one place
        self.xfer = xfer if xfer is not None else TransferPlane()
        for s in self.stores:
            adopt = getattr(s, "adopt_plane", None)
            if adopt is not None:
                adopt(self.xfer)

    # ---- accessors ---------------------------------------------------------
    def store(self, level: int) -> Optional[StateStore]:
        return next((s for s in self.stores if s.level == level), None)

    def levels(self) -> List[int]:
        return [s.level for s in self.stores]

    def __iter__(self):
        return iter(self.stores)

    def __bool__(self) -> bool:
        return bool(self.stores)

    # ---- writes ------------------------------------------------------------
    def submit(self, step: int, state: PyTree, meta: Optional[Dict] = None,
               levels: Optional[Sequence[int]] = None,
               _private: bool = False) -> None:
        """Fan the snapshot out to every (selected) level. Blob-consuming
        backends share ONE host staging pass: the state is flattened once
        and the same read-only blob feeds them all. ``_private`` marks a
        tree the ladder already owns (a capture_tree result staged by
        submit_async) whose mutable leaves need no second copy."""
        blob = None
        for s in self.stores:
            if levels is not None and s.level not in levels:
                continue
            if s.consumes_blob:
                if blob is None:
                    blob = stage_tree(state, copy=not _private)
                s.submit_blob(step, blob, meta)
            else:
                s.submit(step, state, meta)

    def submit_async(self, step: int, state: PyTree, meta: Optional[Dict] = None,
                     levels: Optional[Sequence[int]] = None) -> None:
        """Pipelined submit: capture the mutable leaves NOW (the
        capture-before-return contract), then stage + place on the
        background stager so the caller's next step overlaps the state
        movement. Falls back to the synchronous path when the plane's
        pipelining is off (e.g. programs that donate step buffers)."""
        if not self.stores:
            return
        if not self.xfer.pipeline:
            self.submit(step, state, meta, levels)
            return
        captured = capture_tree(state)
        self.xfer.submit_async(
            lambda: self.submit(step, captured, meta, levels, _private=True)
        )

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Barrier: every pipelined submit has executed and every store
        has persisted what it was handed. Reused by ``FTSession.run``'s
        teardown and by the recovery window BEFORE ``on_failure``/restore
        consult the stores. A ``timeout`` bounds the stager half of the
        barrier (the gray-failure guard against a wedged background
        submit); returns False when submits were still in flight at the
        timeout - the stores are then drained best-effort and the caller
        restores from whatever is already persisted."""
        ok = self.xfer.drain(timeout)
        for s in self.stores:
            s.wait()
        return ok

    def wait(self) -> None:
        self.drain()

    def trim(self, keep: int) -> None:
        for s in self.stores:
            s.trim(keep)

    # ---- failure plumbing --------------------------------------------------
    def on_failure(self, dead_physicals: Sequence[int]) -> None:
        for s in self.stores:
            s.on_failure(dead_physicals)

    # ---- the ladder walk ---------------------------------------------------
    def restore(self, template: PyTree, step: Optional[int] = None
                ) -> Optional[LadderRestore]:
        """First recoverable snapshot, cheapest level first. ``None`` means
        every rung came up empty (the caller's fresh-init of last resort).

        With ``rung_deadline_s`` set, each rung's load is armed with a
        fresh :class:`~repro.xfer.Deadline` (stores that accept one via
        ``set_deadline``): a stalled or fail-slow gather surfaces as a
        DeadlineExceeded on that rung - caught here like any torn rung -
        and the walk falls through to the next level within the budget
        instead of wedging the recovery window."""
        self.attempts = []
        for s in self.stores:
            t0 = time.perf_counter()
            set_dl = getattr(s, "set_deadline", None)
            if set_dl is not None and self.rung_deadline_s > 0:
                set_dl(Deadline(self.rung_deadline_s))
            if hasattr(s, "last_restore_info"):
                s.last_restore_info = ""  # don't report a stale detail
            try:
                got = s.load(template, step=step)
                err = ""
            except Exception as e:  # a torn rung must not mask deeper ones
                got, err = None, f"{type(e).__name__}: {e}"
            finally:
                if set_dl is not None:
                    set_dl(None)
            dt = time.perf_counter() - t0
            if got is None:
                self.attempts.append(RestoreAttempt(
                    level=s.level, store=s.name, ok=False, seconds=dt, error=err,
                    detail=str(getattr(s, "last_restore_info", "") or ""),
                ))
                continue
            rstep, state, meta = got
            detail = str(getattr(s, "last_restore_info", "") or "")
            self.attempts.append(RestoreAttempt(
                level=s.level, store=s.name, ok=True, step=rstep, seconds=dt,
                detail=detail,
            ))
            return LadderRestore(
                level=s.level, store=s.name, step=rstep, state=state,
                meta=meta, attempts=list(self.attempts), detail=detail,
            )
        return None

    def restore_partial(self, current: PyTree, step: Optional[int] = None
                        ) -> Optional[PartialRestore]:
        """Reassemble a snapshot by fetching ONLY the chunks whose bytes
        differ from ``current`` (per-chunk crc against the submit's
        recorded fingerprints) and splicing them into ``current``'s own
        bytes - the recovery path for a named-victim corruption, where
        most of the victim's state is still good.

        ``current`` is the corrupted slice's view of its state (it doubles
        as the restore template). Walks chunk-manifest-capable levels
        cheapest-first; returns None when none can serve it (layout drift,
        lost chunks, pre-crc entries) - the caller then falls back to the
        full-blob :meth:`restore`. The result is byte-identical to a full
        restore of the same step (modulo the crc32 content-address caveat
        shared by every fingerprint-diff scheme)."""
        blob = stage_tree(current)
        for s in self.stores:
            manifest = getattr(s, "chunk_manifest", None)
            load_chunks = getattr(s, "load_chunks", None)
            if manifest is None or load_chunks is None:
                continue
            got = manifest(step)
            if got is None:
                continue
            mstep, entry = got
            if entry.get("keys") is not None:
                got = self._splice_pages(s, load_chunks, blob, mstep, entry,
                                         current)
                if got is None:
                    continue
                return got
            cb = chunk_blob(blob, entry["chunk_bytes"])
            if (cb.layout != tuple(entry["layout"])
                    or cb.n_chunks != entry["n_chunks"]
                    or cb.n_chunks != len(entry["crcs"])):
                continue  # state shape drifted since the submit: full walk
            raws = [c.raw() for c in cb.chunks]
            stale = [
                ci for ci, raw in enumerate(raws)
                if zlib.crc32(raw) != entry["crcs"][ci]
            ]
            fetched = load_chunks(mstep, stale)
            if fetched is None:
                continue  # a needed chunk lost every holder: full walk
            for ci, raw in fetched.items():
                raws[ci] = raw
            state = unflatten_like(current, ChunkedBlob(
                layout=cb.layout, chunk_bytes=cb.chunk_bytes, chunks=cb.chunks
            ).to_blob(raws))
            return PartialRestore(
                level=s.level, store=s.name, step=mstep, state=state,
                meta=dict(entry["meta"]), n_chunks=cb.n_chunks,
                moved_chunks=len(stale),
                moved_bytes=sum(r.nbytes for r in fetched.values()),
                total_bytes=cb.total_bytes,
            )
        return None

    def _splice_pages(self, s: StateStore, load_chunks, blob, mstep: int,
                      entry: Dict, current: PyTree
                      ) -> Optional[PartialRestore]:
        """The paged half of :meth:`restore_partial`: chunks are pages
        matched BY KEY, so a poisoned page is named directly and the
        rebuilt state is the snapshot's own page set - pages the caller's
        table has that the snapshot lacks simply drop (the snapshot is the
        authority), and a page the caller lost entirely is just stale."""
        raws: List[Optional[np.ndarray]] = []
        stale: List[int] = []
        for ci, spec in enumerate(entry["layout"]):
            arr = blob.get(spec.path)
            b = None if arr is None else leaf_bytes(np.asarray(arr))
            if (b is None or b.nbytes != spec.nbytes
                    or zlib.crc32(b) != entry["crcs"][ci]):
                stale.append(ci)
                b = None
            raws.append(b)
        fetched = load_chunks(mstep, stale)
        if fetched is None:
            return None  # a needed page lost every holder: full walk
        for ci, raw in fetched.items():
            raws[ci] = raw
        state = unflatten_like(current, ChunkedBlob(
            layout=tuple(entry["layout"]), chunk_bytes=entry["chunk_bytes"],
            keys=entry["keys"],
        ).to_blob(raws))
        return PartialRestore(
            level=s.level, store=s.name, step=mstep, state=state,
            meta=dict(entry["meta"]), n_chunks=len(entry["layout"]),
            moved_chunks=len(stale),
            moved_bytes=sum(r.nbytes for r in fetched.values()),
            total_bytes=sum(spec.nbytes for spec in entry["layout"]),
        )
