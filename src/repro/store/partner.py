"""Level-1 partner-memory snapshots with ReStore-style K-way sharding.

The old ``PartnerStore`` held ONE full copy of the state on ONE partner
host - if the computational slice and its partner failed together (a
mirrored-pair loss, the paper's unmaskable case), level 1 was gone and
recovery fell all the way to disk. ReStore's fix, adopted here: shard the
snapshot across *all* surviving slices' host memories and replicate each
shard onto ``redundancy`` distinct peers. A snapshot then survives any
failure that leaves at least one holder of every shard alive - in
particular the double failure of a mirrored pair, whose two physicals
never co-hold a shard's only copies unless the world has shrunk to the
pair itself.

Placement: with live peers ``p_0 < ... < p_{n-1}``, shard ``s`` is held by
``p_{(s+j) mod n}`` for ``j in 0..K-1`` (consecutive-ring placement, the
ReStore default). Leaves are round-robined into ``n`` shards in sorted
path order, so any submit is reconstructible from the manifest alone.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.store.base import PyTree, Restored, StateStore, flatten_with_paths, unflatten_like


class PartnerMemoryStore(StateStore):
    level = 1
    consumes_blob = True

    def __init__(self, peers: Iterable[int], *, redundancy: int = 2, keep: int = 2):
        assert redundancy >= 1
        self.redundancy = redundancy
        self.keep = keep
        self._live: List[int] = sorted(set(int(p) for p in peers))
        assert self._live, "need at least one peer host"
        # peer -> {(step, shard) -> {path: array}}
        self._mem: Dict[int, Dict[Tuple[int, int], Dict[str, np.ndarray]]] = {
            p: {} for p in self._live
        }
        # step -> {"n_shards": int, "meta": dict}
        self._manifest: Dict[int, Dict] = {}
        self._lock = threading.Lock()
        self.name = f"partner[k{redundancy}]"

    # ---- writes ------------------------------------------------------------
    def submit(self, step: int, state: PyTree, meta: Optional[Dict] = None) -> None:
        self.submit_blob(step, flatten_with_paths(state), meta)

    def submit_blob(self, step: int, blob: Dict[str, np.ndarray],
                    meta: Optional[Dict] = None) -> None:
        with self._lock:
            self._place_locked(step, blob, dict(meta or {}))
            self._trim_locked(self.keep)

    def _place_locked(self, step: int, blob: Dict[str, np.ndarray],
                      meta: Dict) -> None:
        """Shard ``blob`` over the CURRENT ring. Any prior placement of the
        step is purged first: replay can resubmit a step after the world
        shrank (and rebalance re-places after it grew) - stale shards from
        the old ring must not be gathered alongside the new ones."""
        self._drop_locked(step)
        live = list(self._live)
        n = len(live)
        k = min(self.redundancy, n)
        shards: List[Dict[str, np.ndarray]] = [{} for _ in range(n)]
        for i, path in enumerate(sorted(blob)):
            shards[i % n][path] = blob[path]
        self._manifest[step] = {"n_shards": n, "meta": meta}
        for s, shard in enumerate(shards):
            for j in range(k):
                self._mem[live[(s + j) % n]][(step, s)] = shard

    # ---- reads -------------------------------------------------------------
    def load(self, template: PyTree, step: Optional[int] = None) -> Optional[Restored]:
        with self._lock:
            candidates = [step] if step is not None else sorted(self._manifest, reverse=True)
            for cand in candidates:
                if cand not in self._manifest:
                    continue
                blob = self._gather_locked(cand)
                if blob is not None:
                    meta = dict(self._manifest[cand]["meta"])
                    return cand, unflatten_like(template, blob), meta
        return None

    def _gather_locked(self, step: int) -> Optional[Dict[str, np.ndarray]]:
        """All shards of ``step`` from surviving holders, or None if any
        shard lost every copy."""
        n = self._manifest[step]["n_shards"]
        blob: Dict[str, np.ndarray] = {}
        for s in range(n):
            part = next(
                (m[(step, s)] for m in self._mem.values() if (step, s) in m), None
            )
            if part is None:
                return None
            blob.update(part)
        return blob

    def recoverable(self, step: int) -> bool:
        """True if every shard of ``step`` still has a surviving holder."""
        with self._lock:
            return step in self._manifest and self._gather_locked(step) is not None

    def steps(self) -> List[int]:
        with self._lock:
            return sorted(self._manifest)

    def latest_step(self) -> int:
        with self._lock:
            return max(self._manifest, default=-1)

    # ---- space management --------------------------------------------------
    def drop(self, step: int) -> None:
        with self._lock:
            self._drop_locked(step)

    def _drop_locked(self, step: int) -> None:
        self._manifest.pop(step, None)
        for m in self._mem.values():
            for key in [k for k in m if k[0] == step]:
                del m[key]

    def trim(self, keep: int) -> None:
        with self._lock:
            self._trim_locked(keep)

    def _trim_locked(self, keep: int) -> None:
        for s in sorted(self._manifest)[:-keep] if keep else []:
            self._drop_locked(s)

    # ---- failure plumbing --------------------------------------------------
    def on_failure(self, dead_physicals: Sequence[int]) -> None:
        """Dead peers' host memories are gone: drop their shard copies and
        stop placing new shards on them."""
        with self._lock:
            for p in dead_physicals:
                self._mem.pop(p, None)
            self._live = [p for p in self._live if p in self._mem]

    # ---- heal plumbing (repro.heal pair re-registration) --------------------
    def register_peers(self, peers: Iterable[int]) -> None:
        """Admit peers into the ring (idempotent): a healed replica or a
        backfilled spare brings fresh host memory that new shard placements
        should use. Existing snapshots keep their recorded placement until
        :meth:`rebalance` re-places them."""
        with self._lock:
            for p in peers:
                p = int(p)
                if p not in self._mem:
                    self._mem[p] = {}
            self._live = sorted(self._mem)

    def rebalance(self) -> List[int]:
        """Re-place every still-recoverable snapshot onto the CURRENT ring,
        restoring the K-way redundancy that deaths eroded (ReStore's
        re-distribution step after the ring changes). Snapshots that
        already lost a shard entirely are left as-is (nothing to gather).
        Returns the re-placed steps."""
        with self._lock:
            replaced = []
            for step in sorted(self._manifest):
                blob = self._gather_locked(step)
                if blob is None:
                    continue
                meta = self._manifest[step]["meta"]
                self._place_locked(step, blob, meta)
                replaced.append(step)
            return replaced
