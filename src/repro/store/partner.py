"""Level-1 partner-memory snapshots: striped chunks, K-way redundancy.

The old ``PartnerStore`` held ONE full copy of the state on ONE partner
host; PR 2 sharded it ReStore-style but still placed whole per-leaf
shards under one global lock - a submit blocked every concurrent ``load``
for the full blob copy, and one shard could be as large as the biggest
leaf. This version moves placement to the ``repro.xfer`` plane:

- the staged blob is cut into fixed-size chunks and **striped**
  round-robin across the live ring (the paper's Sec. V message splitting:
  every partner receives its part in parallel, none waits for a
  whole-blob send), with each chunk replicated onto ``redundancy``
  consecutive peers (ReStore's placement, per chunk);
- placement is **fine-grained**: the global lock now only guards ring +
  manifest metadata (O(1) critical sections); chunk placement takes
  per-peer locks one chunk at a time, so ``load``/``steps`` never wait on
  a blob copy (``coarse_lock=True`` keeps the old whole-submit lock for
  A/B benchmarking);
- submits optionally **delta-encode** each chunk against the previous
  submit (``xfer.delta``, verified byte-exact per chunk at encode time).

A snapshot survives any failure that leaves >= 1 holder of every chunk
alive - in particular a mirrored-pair double failure, whose two physicals
never co-hold a chunk's only copies unless the world shrank to the pair.

The manifest entry for a step is installed *after* its chunks are placed,
so a concurrent gather either sees the complete placement or none of it.
"""
from __future__ import annotations

import threading
import time
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.store.base import PyTree, Restored, StateStore, flatten_with_paths, unflatten_like
from repro.xfer.chunking import Chunk, ChunkedBlob, PagedBlob, stripe_holders
from repro.xfer.deadline import Deadline, DeadlineExceeded, backoff_delays
from repro.xfer.plane import TransferPlane


def _chunk_crcs(cb: ChunkedBlob) -> List[int]:
    """zlib.crc32 per PRE-encode raw chunk: the exact byte-space
    fingerprints a digest-guided partial restore diffs against (the
    in-step fp digests detect and vote; these name the bytes to move)."""
    return [zlib.crc32(c.raw()) for c in cb.chunks]


class _SlowHolder(Exception):
    """Internal: every surviving holder of some chunk is too slow to
    serve it within the gather's remaining deadline budget. Carries the
    culprit so ``load`` can quarantine by NAME, then retry against the
    ring minus the culprit."""

    def __init__(self, peer: int, delay_s: float):
        super().__init__(f"peer {peer} too slow ({delay_s:.3f}s/chunk)")
        self.peer = peer
        self.delay_s = delay_s


class PartnerMemoryStore(StateStore):
    level = 1
    consumes_blob = True

    def __init__(self, peers: Iterable[int], *, redundancy: int = 2, keep: int = 2,
                 xfer: Optional[TransferPlane] = None, coarse_lock: bool = False):
        assert redundancy >= 1
        self.redundancy = redundancy
        self.keep = keep
        self.coarse_lock = coarse_lock
        self._live: List[int] = sorted(set(int(p) for p in peers))
        assert self._live, "need at least one peer host"
        # peer -> {(step, chunk_index) -> Chunk}
        self._mem: Dict[int, Dict[Tuple[int, int], Chunk]] = {
            p: {} for p in self._live
        }
        self._peer_locks: Dict[int, threading.Lock] = {
            p: threading.Lock() for p in self._live
        }
        # step -> {"n_chunks", "layout", "chunk_bytes", "meta"}
        self._manifest: Dict[int, Dict] = {}
        # guards ring topology + manifest ONLY (short critical sections);
        # lock order is always meta -> peer
        self._meta_lock = threading.Lock()
        self._plane = xfer
        self._delta = xfer.delta_encoder() if xfer else None
        self.name = f"partner[k{redundancy}]"
        #: accounting of the last submit (the xfer benchmarks read these)
        self.last_chunked: Optional[ChunkedBlob] = None
        #: peer -> reason, for peers evicted as fail-slow (not dead: their
        #: chunks are purged like a death, but heal re-admission forgives)
        self.quarantined: Dict[int, str] = {}
        #: what the last load did beyond the happy path (ladder detail)
        self.last_restore_info: str = ""
        # gray-failure plumbing: injected/observed per-peer latency and the
        # per-rung deadline the RecoveryLadder arms around a restore
        self._latency = None  # object with read_delay(peer) -> seconds
        self._deadline: Optional[Deadline] = None

    # ---- gray-failure plumbing ---------------------------------------------
    def set_latency(self, latency) -> None:
        """Install a per-peer latency source (``read_delay(peer) ->
        seconds``) - the chaos plane's fail-slow injection, or a real
        deployment's observed per-peer fetch ewma."""
        self._latency = latency

    def set_deadline(self, deadline: Optional[Deadline]) -> None:
        """Arm/disarm the deadline the next gathers spend against (the
        RecoveryLadder sets this around a rung's restore)."""
        self._deadline = deadline

    def quarantine(self, peer: int, reason: str) -> None:
        """Evict a fail-slow peer from the ring: purge its placements via
        the same path a death takes (ring-shrink), but record it as
        quarantined - the peer is alive, and :meth:`register_peers` can
        re-admit it (heal forgives; the next slow gather re-convicts)."""
        with self._meta_lock:
            p = int(peer)
            self.quarantined[p] = reason
            # never shrink the ring to zero: a lone slow peer is recorded
            # (and its gathers keep failing to the next rung) but future
            # submits still need SOMEWHERE to stripe
            if p in self._mem and len(self._mem) > 1:
                self._mem.pop(p, None)
                self._peer_locks.pop(p, None)
                self._live = [q for q in self._live if q in self._mem]

    # ---- plane plumbing ----------------------------------------------------
    def adopt_plane(self, plane: TransferPlane) -> None:
        """Called by the RecoveryLadder so every chunk-consuming level
        shares ITS plane (one chunking pass, one config). A store that
        already owns a plane keeps it."""
        if self._plane is None:
            self._plane = plane
            self._delta = plane.delta_encoder()

    def _ensure_plane(self) -> TransferPlane:
        if self._plane is None:
            self._plane = TransferPlane()
            self._delta = self._plane.delta_encoder()
        return self._plane

    # ---- writes ------------------------------------------------------------
    def submit(self, step: int, state: PyTree, meta: Optional[Dict] = None) -> None:
        self.submit_blob(step, flatten_with_paths(state), meta)

    def submit_blob(self, step: int, blob: Dict[str, np.ndarray],
                    meta: Optional[Dict] = None) -> None:
        """Stripe ``blob`` over the CURRENT ring. Any prior placement of
        the step is purged first: replay can resubmit a step after the
        world shrank (and rebalance re-places after it grew) - stale
        chunks from the old ring must not be gathered alongside new ones."""
        plane = self._ensure_plane()
        if self.coarse_lock:
            with self._meta_lock:
                live = list(self._live)
                raw_cb = plane.chunked(blob, min_chunks=len(live))
                crcs = _chunk_crcs(raw_cb)
                cb = self._delta.encode(raw_cb)
                self._place_locked(step, cb, dict(meta or {}), live, crcs)
                self._trim_locked(self.keep)
            self.last_chunked = cb
            return
        with self._meta_lock:
            live = list(self._live)
            self._drop_locked(step)
        # the expensive part - chunk, delta-encode, place - runs WITHOUT
        # the metadata lock: concurrent loads proceed against older steps.
        # Chunk fingerprints are taken on the PRE-encode raw chunks (the
        # submitted bytes - what a partial restore diffs against), never on
        # delta payloads
        raw_cb = plane.chunked(blob, min_chunks=len(live))
        crcs = _chunk_crcs(raw_cb)
        cb = self._delta.encode(raw_cb)
        self._place_fine(step, cb, dict(meta or {}), live, crcs)
        with self._meta_lock:
            self._trim_locked(self.keep)
        self.last_chunked = cb

    @staticmethod
    def _entry(cb: ChunkedBlob, meta: Dict,
               crcs: Optional[List[int]] = None) -> Dict:
        return {
            "n_chunks": cb.n_chunks,
            "layout": cb.layout,
            "chunk_bytes": cb.chunk_bytes,
            "keys": cb.keys,
            "crcs": list(crcs) if crcs is not None else None,
            "meta": meta,
        }

    @staticmethod
    def _expect_size(entry: Dict, ci: int, total: int) -> int:
        """Expected raw size of chunk ``ci``: a page's own size for the
        keyed (paged) cut, else the byte-stream slice."""
        if entry.get("keys") is not None:
            return entry["layout"][ci].nbytes
        cb_size = entry["chunk_bytes"]
        return min(cb_size, total - ci * cb_size)

    def _place_locked(self, step: int, cb: ChunkedBlob, meta: Dict,
                      live: List[int], crcs: Optional[List[int]] = None) -> None:
        """Whole-submit placement under the metadata lock (the pre-xfer
        behavior, kept behind ``coarse_lock`` for contention A/B runs)."""
        self._drop_locked(step)
        for chunk in cb.chunks:
            for peer in stripe_holders(chunk.index, live, self.redundancy):
                mem = self._mem.get(peer)
                if mem is not None:
                    mem[(step, chunk.index)] = chunk
        self._manifest[step] = self._entry(cb, meta, crcs)

    def _place_fine(self, step: int, cb: ChunkedBlob, meta: Dict,
                    live: List[int], crcs: Optional[List[int]] = None) -> None:
        """Per-chunk placement (no metadata lock held), manifest installed
        LAST so gathers see the placement complete or not at all."""
        for chunk in cb.chunks:
            for peer in stripe_holders(chunk.index, live, self.redundancy):
                self._store_chunk(peer, (step, chunk.index), chunk)
        with self._meta_lock:
            self._manifest[step] = self._entry(cb, meta, crcs)

    def _store_chunk(self, peer: int, key: Tuple[int, int], chunk: Chunk) -> None:
        """Place ONE chunk under that peer's lock (the fine-grained unit).
        A peer that died mid-placement simply drops the write - the step
        stays unrecoverable until resubmitted, exactly as if the death had
        preceded the submit."""
        lock = self._peer_locks.get(peer)
        mem = self._mem.get(peer)
        if lock is None or mem is None:
            return
        with lock:
            mem[key] = chunk

    # ---- reads -------------------------------------------------------------
    def load(self, template: PyTree, step: Optional[int] = None) -> Optional[Restored]:
        """Newest (or requested) recoverable snapshot. Gathers run without
        the metadata lock, so a concurrent submit/trim can invalidate a
        candidate mid-gather; a failed gather whose manifest entry was
        REPLACED meanwhile is transient (retried with exponential backoff
        against the fresh manifest), while one whose entry is intact is a
        genuine chunk loss (a dead holder) and falls through to older
        candidates.

        Gray failures: when a rung deadline is armed (:meth:`set_deadline`)
        each gather spends chunk-fetch latency against its budget. A chunk
        whose every holder is too slow to serve within the remaining
        budget QUARANTINES the slow peer (ring-shrink purge, by name) and
        retries against the survivors - redundancy K >= 2 then serves from
        a healthy holder; K = 1 degrades to chunk loss and the ladder
        falls to the next rung. A hard-blown budget raises
        :class:`DeadlineExceeded` naming the quarantined culprits."""
        self.last_restore_info = ""
        quarantined_now: List[int] = []
        delays = backoff_delays(5)
        for attempt in range(5):
            if self._deadline is not None and self._deadline.exceeded():
                raise DeadlineExceeded(
                    f"partner gather blew its deadline "
                    f"({self._deadline.budget_s:.3f}s) at attempt {attempt}",
                    culprits=quarantined_now,
                )
            with self._meta_lock:
                candidates = (
                    [step] if step is not None
                    else sorted(self._manifest, reverse=True)
                )
                entries = {
                    s: self._manifest[s] for s in candidates if s in self._manifest
                }
            if not entries:
                return None
            transient = False
            for cand, entry in entries.items():
                try:
                    blob = self._gather(cand, entry)
                except _SlowHolder as slow:
                    self.quarantine(
                        slow.peer,
                        f"fail-slow: {slow.delay_s:.3f}s/chunk vs deadline",
                    )
                    if slow.peer in quarantined_now:
                        # still the only holder after quarantine (the ring
                        # can't purge its last member): retrying can never
                        # help and the budget can't pay its latency
                        raise DeadlineExceeded(
                            f"sole holder peer {slow.peer} too slow "
                            f"({slow.delay_s:.3f}s/chunk) for the "
                            f"{self._deadline.budget_s:.3f}s budget",
                            culprits=quarantined_now,
                        ) from None
                    quarantined_now.append(slow.peer)
                    self.last_restore_info = f"quarantined:{quarantined_now}"
                    transient = True
                    break  # ring changed: re-list candidates and retry
                if blob is not None:
                    return cand, unflatten_like(template, blob), dict(entry["meta"])
                with self._meta_lock:
                    if self._manifest.get(cand) is entry:
                        continue  # intact manifest, missing chunk: lost
                transient = True
            if not transient:
                return None
            if attempt < len(delays):
                time.sleep(delays[attempt])
        return None

    def _gather(self, step: int, entry: Dict) -> Optional[Dict[str, np.ndarray]]:
        """All chunks of ``step`` from surviving holders, or None if any
        chunk lost every copy. Reads are lock-free: chunk objects are
        immutable once placed and per-peer dict lookups are atomic. A
        gather racing a resubmit that RE-CHUNKED the step (the ring
        changed) can mix chunks from the new placement with the old
        manifest entry; every chunk's byte size is validated against the
        entry's layout before reassembly, so a torn gather degrades to
        None (``load`` then retries against the fresh manifest) instead
        of reconstructing misaligned bytes.

        Holder choice is latency-aware: each chunk is fetched from its
        healthiest surviving holder, and the injected/observed fetch
        latency is charged to the armed deadline. A chunk that can ONLY
        be served slower than the remaining budget raises
        :class:`_SlowHolder` *before* paying the cost, keeping the
        unspent budget for the post-quarantine retry."""
        with self._meta_lock:
            mems = list(self._mem.items())
        total = sum(s.nbytes for s in entry["layout"])
        cb_size = entry["chunk_bytes"]
        chunks: List[Chunk] = []
        raws: List[np.ndarray] = []  # decoded ONCE: validated then reused
        for ci in range(entry["n_chunks"]):
            part = self._fetch_chunk(mems, (step, ci))
            if part is None:
                return None
            raw = part.raw()
            if raw.nbytes != self._expect_size(entry, ci, total):
                return None  # chunk from a different (re-chunked) placement
            chunks.append(part)
            raws.append(raw)
        return ChunkedBlob(
            layout=entry["layout"], chunk_bytes=cb_size, chunks=chunks,
            keys=entry.get("keys"),
        ).to_blob(raws)

    def _fetch_chunk(self, mems: List[Tuple[int, Dict[Tuple[int, int], Chunk]]],
                     key: Tuple[int, int]) -> Optional[Chunk]:
        """One chunk from the healthiest holder that fits the budget."""
        holders = [(p, m[key]) for p, m in mems if key in m]
        if not holders:
            return None
        if self._latency is None:
            return holders[0][1]
        costed = sorted(
            ((self._latency.read_delay(p), p, c) for p, c in holders),
            key=lambda x: x[0],
        )
        delay, peer, chunk = costed[0]
        if (self._deadline is not None and delay > 0
                and self._deadline.would_exceed(delay)):
            raise _SlowHolder(peer, delay)
        if self._deadline is not None and delay > 0:
            self._deadline.charge(delay)
        return chunk

    # ---- chunk-addressed reads (repro.scrub digest-guided partial restore) --
    def chunk_manifest(self, step: Optional[int] = None
                       ) -> Optional[Tuple[int, Dict]]:
        """(step, manifest entry) of the newest (or requested) submit that
        recorded per-chunk fingerprints - the diff target of a partial
        restore. Entries predating the crc field (or rebalanced onto a
        different chunk count) return None: partial restore then falls
        back to the full-blob walk."""
        with self._meta_lock:
            candidates = (
                [step] if step is not None else sorted(self._manifest, reverse=True)
            )
            for s in candidates:
                entry = self._manifest.get(s)
                if entry is not None and entry.get("crcs") is not None:
                    return s, dict(entry)
        return None

    def load_chunks(self, step: int, indices: Sequence[int]
                    ) -> Optional[Dict[int, np.ndarray]]:
        """Raw bytes of just the requested chunks of ``step`` - the unit a
        digest-guided partial restore actually moves. Same holder walk and
        size validation as :meth:`_gather`; None if the step is unknown or
        any requested chunk lost every copy."""
        with self._meta_lock:
            entry = self._manifest.get(step)
            mems = list(self._mem.items())
        if entry is None:
            return None
        total = sum(s.nbytes for s in entry["layout"])
        cb_size = entry["chunk_bytes"]
        out: Dict[int, np.ndarray] = {}
        for ci in indices:
            ci = int(ci)
            if not 0 <= ci < entry["n_chunks"]:
                return None
            try:
                part = self._fetch_chunk(mems, (step, ci))
            except _SlowHolder as slow:
                # partial restore has a cheap fallback (the full-blob
                # walk): quarantine the culprit and bail rather than retry
                self.quarantine(
                    slow.peer,
                    f"fail-slow: {slow.delay_s:.3f}s/chunk vs deadline",
                )
                self.last_restore_info = f"quarantined:[{slow.peer}]"
                return None
            if part is None:
                return None
            raw = part.raw()
            if raw.nbytes != self._expect_size(entry, ci, total):
                return None
            out[ci] = raw
        return out

    def recoverable(self, step: int) -> bool:
        """True if every chunk of ``step`` still has a surviving holder."""
        with self._meta_lock:
            entry = self._manifest.get(step)
            if entry is None:
                return False
            mems = list(self._mem.values())
        return all(
            any((step, ci) in m for m in mems) for ci in range(entry["n_chunks"])
        )

    def steps(self) -> List[int]:
        with self._meta_lock:
            return sorted(self._manifest)

    def latest_step(self) -> int:
        with self._meta_lock:
            return max(self._manifest, default=-1)

    # ---- space management --------------------------------------------------
    def drop(self, step: int) -> None:
        with self._meta_lock:
            self._drop_locked(step)

    def _drop_locked(self, step: int) -> None:
        self._manifest.pop(step, None)
        for peer, m in self._mem.items():
            with self._peer_locks[peer]:
                for key in [k for k in m if k[0] == step]:
                    del m[key]

    def trim(self, keep: int) -> None:
        with self._meta_lock:
            self._trim_locked(keep)

    def _trim_locked(self, keep: int) -> None:
        for s in sorted(self._manifest)[:-keep] if keep else []:
            self._drop_locked(s)

    # ---- failure plumbing --------------------------------------------------
    def on_failure(self, dead_physicals: Sequence[int]) -> None:
        """Dead peers' host memories are gone: drop their chunk copies and
        stop striping onto them."""
        with self._meta_lock:
            for p in dead_physicals:
                self._mem.pop(p, None)
                self._peer_locks.pop(p, None)
                self.quarantined.pop(int(p), None)  # dead trumps slow
            self._live = [p for p in self._live if p in self._mem]

    # ---- heal plumbing (repro.heal pair re-registration) --------------------
    def register_peers(self, peers: Iterable[int]) -> None:
        """Admit peers into the ring (idempotent): a healed replica or a
        backfilled spare brings fresh host memory that new chunk stripes
        should use. Existing snapshots keep their recorded placement until
        :meth:`rebalance` re-places them."""
        with self._meta_lock:
            for p in peers:
                p = int(p)
                self.quarantined.pop(p, None)  # re-admission forgives
                if p not in self._mem:
                    self._mem[p] = {}
                    self._peer_locks[p] = threading.Lock()
            self._live = sorted(self._mem)

    def rebalance(self) -> List[int]:
        """Re-stripe every still-recoverable snapshot onto the CURRENT
        ring, restoring the K-way redundancy that deaths eroded (ReStore's
        re-distribution step). Re-placement is raw (no delta re-encode:
        the delta reference tracks the *submit* stream, not placement).
        Snapshots that already lost a chunk entirely are left as-is.
        Returns the re-placed steps."""
        plane = self._ensure_plane()
        with self._meta_lock:
            steps = sorted(self._manifest)
            entries = {s: self._manifest[s] for s in steps}
        replaced = []
        for step in steps:
            blob = self._gather(step, entries[step])
            if blob is None:
                continue
            if entries[step].get("keys") is not None:
                # preserve the page cut: a byte-stream re-cut would break
                # the keyed identity the recorded crcs fingerprint
                blob = PagedBlob(blob)
            crcs = entries[step].get("crcs")
            if self.coarse_lock:
                with self._meta_lock:
                    live = list(self._live)
                    cb = plane.chunked(blob, min_chunks=len(live))
                    self._place_locked(step, cb, entries[step]["meta"], live,
                                       crcs if cb.n_chunks == len(crcs or []) else None)
            else:
                # same discipline as submit_blob: purge under the short
                # lock, chunk + place outside it, manifest installed last
                with self._meta_lock:
                    live = list(self._live)
                    self._drop_locked(step)
                cb = plane.chunked(blob, min_chunks=len(live))
                self._place_fine(step, cb, entries[step]["meta"], live,
                                 crcs if cb.n_chunks == len(crcs or []) else None)
            replaced.append(step)
        return replaced
