"""Level-0 live-clone snapshots: device-resident, O(memcpy) restore.

Wraps :func:`repro.core.state_transfer.clone_pytree` (the 3-phase
process-image transfer) behind the :class:`StateStore` protocol, so
dynamic replica rebirth and warm-standby serving state go through the
same submit/load API as the partner and durable levels. A clone lives in
the memory of the slice that took it - fastest to restore, first to die
with its host - which is exactly why it is level 0 in the ladder.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.state_transfer import TransferReport, clone_pytree
from repro.store.base import PyTree, Restored, StateStore


class LiveCloneStore(StateStore):
    level = 0
    name = "live-clone"

    def __init__(self, *, sharding=None, verify: bool = True,
                 bit_exact: bool = False, keep: int = 2, host: Optional[int] = None):
        self.sharding = sharding
        self.verify = verify
        self.bit_exact = bit_exact
        self.keep = keep
        self.host = host  # physical slice whose memory holds the clones
        self._clones: Dict[int, Tuple[PyTree, Dict, TransferReport]] = {}
        self._lock = threading.Lock()
        self.last_report: Optional[TransferReport] = None

    # ---- writes ------------------------------------------------------------
    def submit(self, step: int, state: PyTree, meta: Optional[Dict] = None) -> None:
        clone, report = clone_pytree(
            state, sharding=self.sharding, verify=self.verify,
            bit_exact=self.bit_exact,
        )
        if self.verify and not report.verified:
            raise RuntimeError(f"live clone of step {step} failed verification")
        with self._lock:
            self._clones[step] = (clone, dict(meta or {}), report)
            self.last_report = report
            for s in sorted(self._clones)[: -self.keep] if self.keep else []:
                del self._clones[s]

    # ---- reads -------------------------------------------------------------
    def load(self, template: PyTree, step: Optional[int] = None) -> Optional[Restored]:
        with self._lock:
            if step is None:
                step = max(self._clones, default=None)
            if step is None or step not in self._clones:
                return None
            clone, meta, _ = self._clones[step]
        return step, clone, dict(meta)

    def steps(self) -> List[int]:
        with self._lock:
            return sorted(self._clones)

    def report_for(self, step: int) -> Optional[TransferReport]:
        with self._lock:
            entry = self._clones.get(step)
        return entry[2] if entry else None

    # ---- space management --------------------------------------------------
    def drop(self, step: int) -> None:
        with self._lock:
            self._clones.pop(step, None)

    def trim(self, keep: int) -> None:
        with self._lock:
            for s in sorted(self._clones)[:-keep] if keep else []:
                del self._clones[s]

    # ---- failure plumbing --------------------------------------------------
    def on_failure(self, dead_physicals: Sequence[int]) -> None:
        """Clones live on one host; if that host died they are gone."""
        if self.host is not None and self.host in set(dead_physicals):
            with self._lock:
                self._clones.clear()
