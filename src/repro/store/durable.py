"""Level-2 durable snapshots: npz + json manifest, atomic publish.

Absorbs the old ``Checkpointer`` with its two copy-pasted write bodies
(``save`` / ``save_async``) collapsed into one, and the snapshot path made
truly non-blocking: submits stage the state to host memory synchronously
(mandatory - the caller mutates it next step) and hand the staged blob to
a background writer, with up to ``buffers`` writes in flight. The old
code joined the previous writer *before* staging, so a slow disk stalled
the train loop for the full write; double buffering bounds the stall to
the rare case of both buffers busy (thread-based-MPI checkpointing,
Adam et al., 2019).

Snapshots on disk are always full and self-contained: the transfer
plane's delta encoding applies to memory levels only (a delta chain on
disk would couple GC to reference liveness; deferred - see ROADMAP open
items), so any published ``step-*`` dir restores alone after process
death, whatever was trimmed around it.

Crash consistency: writers build ``.tmp-<step>`` and ``os.rename`` onto
the final name (atomic on POSIX). A writer that dies mid-write leaks its
tmp dir; construction garbage-collects any stale ``.tmp-*`` (they used to
accumulate forever), and the post-publish GC sweeps tmp dirs that no
in-flight writer owns.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.store.base import PyTree, Restored, StateStore, flatten_with_paths, unflatten_like


class DurableStore(StateStore):
    level = 2
    name = "durable"
    consumes_blob = True

    def __init__(self, directory: str, *, keep: int = 2, buffers: int = 2):
        assert buffers >= 1
        self.directory = directory
        self.keep = keep
        self.buffers = buffers
        self._inflight: List[Tuple[int, threading.Thread]] = []
        self._lock = threading.Lock()  # serializes publish + GC
        os.makedirs(directory, exist_ok=True)
        self._gc_stale_tmp()

    # ---- paths -------------------------------------------------------------
    def _final(self, step: int) -> str:
        return os.path.join(self.directory, f"step-{step:010d}")

    def _tmp(self, step: int) -> str:
        return os.path.join(self.directory, f".tmp-{step}")

    # ---- writes ------------------------------------------------------------
    def submit(self, step: int, state: PyTree, meta: Optional[Dict] = None) -> None:
        """Stage to host now, write to disk in the background. Blocks only
        when ``buffers`` writes are already in flight (double-buffered)."""
        self.submit_blob(step, flatten_with_paths(state), meta)

    def submit_blob(self, step: int, blob: Dict[str, np.ndarray],
                    meta: Optional[Dict] = None) -> None:
        # a still-running writer for the SAME step would share our
        # .tmp-<step> dir (replay can recross a checkpoint step): join it
        for s, t in list(self._inflight):
            if s == step:
                t.join()
        self._reap()
        while len(self._inflight) >= self.buffers:
            self._drain_one()
        t = threading.Thread(target=self._write, args=(step, blob, meta), daemon=True)
        self._inflight.append((step, t))
        t.start()

    def submit_sync(self, step: int, state: PyTree, meta: Optional[Dict] = None) -> str:
        """Synchronous submit (tests, final checkpoint at teardown)."""
        self._write(step, flatten_with_paths(state), meta)
        return self._final(step)

    def _write(self, step: int, blob: Dict[str, np.ndarray], meta: Optional[Dict]) -> None:
        tmp, final = self._tmp(step), self._final(step)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "state.npz"), **blob)
        manifest = {
            "step": step,
            "time": time.time(),
            "meta": meta or {},
            "leaves": len(blob),
            "bytes": int(sum(a.nbytes for a in blob.values())),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with self._lock:
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self._gc_locked()

    def wait(self) -> None:
        while self._inflight:
            self._drain_one()

    def _drain_one(self) -> None:
        # join BEFORE removing: a live writer must stay visible in
        # ``_inflight`` or a concurrent writer's GC mistakes its tmp dir
        # for dead-writer debris and deletes it mid-write
        self._inflight[0][1].join()
        self._inflight.pop(0)

    def _reap(self) -> None:
        self._inflight = [(s, t) for s, t in self._inflight if t.is_alive()]

    # ---- reads -------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step-"):
                out.append(int(name.split("-")[1]))
        return sorted(out)

    def load(self, template: PyTree, step: Optional[int] = None) -> Optional[Restored]:
        self.wait()
        steps = self.steps()
        if not steps:
            return None
        step = steps[-1] if step is None else step
        path = self._final(step)
        try:
            with np.load(os.path.join(path, "state.npz")) as z:
                blob = {k: z[k] for k in z.files}
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
        except (FileNotFoundError, ValueError, json.JSONDecodeError):
            return None  # torn snapshot (should not happen post-rename)
        return step, unflatten_like(template, blob), manifest.get("meta", {})

    # ---- space management --------------------------------------------------
    def drop(self, step: int) -> None:
        with self._lock:
            shutil.rmtree(self._final(step), ignore_errors=True)

    def trim(self, keep: int) -> None:
        with self._lock:
            for s in self.steps()[:-keep] if keep else []:
                shutil.rmtree(self._final(s), ignore_errors=True)

    def _gc_locked(self) -> None:
        for s in self.steps()[: -self.keep]:
            shutil.rmtree(self._final(s), ignore_errors=True)
        # tmp dirs no live writer owns are debris from a dead writer
        active = {s for s, t in list(self._inflight) if t.is_alive()}
        self._gc_stale_tmp(skip=active)

    def _gc_stale_tmp(self, skip=()) -> None:
        for name in os.listdir(self.directory):
            if not name.startswith(".tmp-"):
                continue
            try:
                step = int(name.split("-", 1)[1])
            except ValueError:
                step = None
            if step in skip:
                continue
            shutil.rmtree(os.path.join(self.directory, name), ignore_errors=True)
