"""Level-2 durable snapshots: npz + json manifest, atomic publish, and
(opt-in) on-disk delta chains.

Absorbs the old ``Checkpointer`` with its two copy-pasted write bodies
(``save`` / ``save_async``) collapsed into one, and the snapshot path made
truly non-blocking: submits stage the state to host memory synchronously
(mandatory - the caller mutates it next step) and hand the staged blob to
a background writer, with up to ``buffers`` writes in flight. The old
code joined the previous writer *before* staging, so a slow disk stalled
the train loop for the full write; double buffering bounds the stall to
the rare case of both buffers busy (thread-based-MPI checkpointing,
Adam et al., 2019).

**Delta chains** (``delta="bf16"|"int8"``) extend the ``repro.xfer``
verified-exact delta encoding to disk - ReStore's sub-blocking argument
applied to bytes a full-frequency durable cadence would otherwise burn:
a published ``step-*`` dir stores only the chunks that actually moved
(``chunks.npz``: raw or codec'd fp32-delta payloads) plus a manifest whose
per-chunk records reference base chunks by ``(step, chunk_index)``;
byte-identical chunks ship nothing at all. Two invariants keep the scheme
safe:

- **ref-counted GC**: ``trim``/``drop``/the keep-based sweep never delete
  a step dir that a live chain's ``zero``/delta chunks still reference -
  retention is the transitive closure of the kept steps' base references
  (``_bases``, persisted as an advisory ``refs.json`` sidecar and REBUILT
  from the published manifests at startup, so refs orphaned by a crash
  between payload publish and sidecar update heal themselves);
- **chain-depth cap** (``max_chain``, default 4): a full self-contained
  snapshot is forced whenever extending the chain would make a restore
  read more than ``max_chain`` step dirs, so restore cost stays bounded
  whatever the submit cadence. Resubmits (replay recrossing a checkpoint
  step), layout changes, and submits where no chunk compressed also ship
  full - a delta dir is written only when it actually saves bytes.

Restore resolves the chain through :func:`repro.xfer.delta.decode_delta`
and is byte-identical to the full-snapshot path by construction (every
delta chunk was verified exact at encode time; ``zero`` chunks resolve to
the bytes the encoder proved equal).

Crash consistency: writers build ``.tmp-<step>`` and ``os.rename`` onto
the final name (atomic on POSIX). A writer that dies mid-write leaks its
tmp dir; construction garbage-collects any stale ``.tmp-*`` (they used to
accumulate forever), and the post-publish GC sweeps tmp dirs that no
in-flight writer owns. A delta dir whose base dir died with a crash is
simply unrestorable - ``load`` walks to the next (older) intact step
instead of failing the whole durable rung.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.store.base import PyTree, Restored, StateStore, flatten_with_paths, unflatten_like
from repro.xfer.chunking import (
    Chunk,
    ChunkedBlob,
    chunk_blob,
    chunk_pages,
    layout_from_json,
    layout_to_json,
)
from repro.xfer.delta import DeltaEncoder, decode_delta, payload_from_parts, payload_parts
from repro.xfer.plane import TransferPlane


class DurableStore(StateStore):
    level = 2
    name = "durable"
    consumes_blob = True

    def __init__(self, directory: str, *, keep: int = 2, buffers: int = 2,
                 delta: str = "none", max_chain: int = 4,
                 xfer: Optional[TransferPlane] = None):
        assert buffers >= 1
        assert delta in ("none", "bf16", "int8"), delta
        assert max_chain >= 1, max_chain
        self.directory = directory
        self.keep = keep
        self.buffers = buffers
        self.delta = delta
        self.max_chain = max_chain
        self._inflight: List[Tuple[int, threading.Thread]] = []
        self._lock = threading.Lock()  # serializes publish + GC + refs
        # delta-chain submit state (caller thread only - submits are
        # ordered by the single stager worker / the caller):
        self._plane = xfer
        self._encoder = DeltaEncoder(delta)
        self._anchors: List[Tuple[int, int]] = []  # per chunk: (step, idx)
        # keyed anchors for the paged cut: page key -> (step, idx in that
        # step's own cut). A paged layout legitimately drifts every submit
        # (tail pages appear, freed slots drop) - chains anchor by key so
        # zero-runs survive the drift that would reset an indexed chain
        self._anchor_keys: Dict[str, Tuple[int, int]] = {}
        self._chain_len = 0   # dirs a restore of the latest submit reads
        self._last_step: Optional[int] = None
        # set when a drop/trim/GC touches a dir the NEXT submit would
        # delta against (incl. a mark-cancelled in-flight tip): the chain
        # must restart with a full snapshot or it references a ghost
        self._chain_broken = False
        # ref graph + drop set (under _lock): step -> base steps its
        # manifest references; dropped steps are hidden from steps()/load
        # and physically deleted once nothing references them
        self._bases: Dict[int, Set[int]] = {}
        self._dropped: Set[int] = set()
        #: accounting of the last published dir / cumulative (benchmarks)
        self.last_io_bytes = 0
        self.io_bytes_total = 0
        #: how the last successful load resolved ("" = plain full snapshot,
        #: "chain:N" = delta chain across N step dirs)
        self.last_restore_info = ""
        self.last_restore_dirs = 0
        os.makedirs(directory, exist_ok=True)
        with self._lock:
            self._gc_stale_tmp()
            self._rebuild_refs_locked()
            # dropped dirs whose last referrer died with the old process
            # are collectable right away (keep=0: delete nothing visible)
            self._retain_locked(keep=0)

    # ---- plane plumbing ----------------------------------------------------
    def adopt_plane(self, plane: TransferPlane) -> None:
        """Called by the RecoveryLadder so chunk-consuming levels share ITS
        plane (one memoized chunking pass per staged blob). The delta codec
        stays this store's own (``delta=``) - the plane's ``delta`` config
        drives the MEMORY levels' encoders, not the on-disk chain."""
        if self._plane is None:
            self._plane = plane

    def _ensure_plane(self) -> TransferPlane:
        if self._plane is None:
            self._plane = TransferPlane()
        return self._plane

    # ---- paths -------------------------------------------------------------
    def _final(self, step: int) -> str:
        return os.path.join(self.directory, f"step-{step:010d}")

    def _tmp(self, step: int) -> str:
        return os.path.join(self.directory, f".tmp-{step}")

    @staticmethod
    def _parse_step(name: str) -> Optional[int]:
        """The step of a ``step-*`` entry, or None for anything else -
        stray entries (``step-old.bak``, editor droppings) used to raise
        ValueError out of ``steps()`` and kill every restore walk."""
        if not name.startswith("step-"):
            return None
        try:
            return int(name.split("-", 1)[1])
        except ValueError:
            return None

    def _disk_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            s = self._parse_step(name)
            if s is not None:
                out.append(s)
        return sorted(out)

    # ---- writes ------------------------------------------------------------
    def submit(self, step: int, state: PyTree, meta: Optional[Dict] = None) -> None:
        """Stage to host now, write to disk in the background. Blocks only
        when ``buffers`` writes are already in flight (double-buffered)."""
        self.submit_blob(step, flatten_with_paths(state), meta)

    def _join_step(self, step: int) -> None:
        """Join a still-running writer for the SAME step - it would share
        our ``.tmp-<step>`` dir (replay can recross a checkpoint step)."""
        for s, t in list(self._inflight):
            if s == step:
                t.join()
        self._reap()

    def submit_blob(self, step: int, blob: Dict[str, np.ndarray],
                    meta: Optional[Dict] = None) -> None:
        self._join_step(step)
        while len(self._inflight) >= self.buffers:
            self._drain_one()
        # encode on the CALLER thread: the delta reference must observe
        # submits in order, which concurrent writer threads do not give
        job = self._prepare(step, blob, meta)
        t = threading.Thread(target=self._write_prepared, args=(job,), daemon=True)
        self._inflight.append((step, t))
        t.start()

    def submit_sync(self, step: int, state: PyTree, meta: Optional[Dict] = None) -> str:
        """Synchronous submit (tests, final checkpoint at teardown)."""
        self._join_step(step)
        self._write_prepared(self._prepare(step, flatten_with_paths(state), meta))
        return self._final(step)

    # ---- the write path ----------------------------------------------------
    def _prepare(self, step: int, blob: Dict[str, np.ndarray],
                 meta: Optional[Dict]) -> Dict:
        """Everything except file IO, on the caller thread: chunk + delta-
        encode against the previous submit, decide full vs delta, and
        register the new dir's base refs so GC protects the chain BEFORE
        the background writer publishes it."""
        meta = dict(meta or {})
        if self.delta == "none":
            return self._full_job(step, blob, meta)

        cb = self._ensure_plane().chunked_cached(blob)
        with self._lock:
            broken, self._chain_broken = self._chain_broken, False
        # a resubmit (step <= last: replay recrossed a checkpoint) must not
        # delta against the dir it is about to replace; a broken chain
        # (drop/trim forgot an anchor dir), the chain cap and the very
        # first submit also force a self-contained snapshot
        force_full = (
            broken
            or self._chain_len == 0
            or self._chain_len >= self.max_chain
            or (self._last_step is not None and step <= self._last_step)
        )
        keyed = cb.keys is not None
        encoded = None
        if force_full:
            self._encoder.observe(cb)
        else:
            encoded = self._encoder.encode(cb)
            bad = (
                all(c.encoding == "raw" for c in encoded.chunks)
                or (not keyed and len(self._anchors) != encoded.n_chunks)
                or (keyed and any(
                    c.encoding != "raw" and encoded.keys[i] not in self._anchor_keys
                    for i, c in enumerate(encoded.chunks)
                ))
            )
            if bad:
                encoded = None  # layout changed / nothing compressed: full

        if encoded is None:
            self._anchors = [(step, i) for i in range(cb.n_chunks)]
            self._anchor_keys = (
                {k: (step, i) for i, k in enumerate(cb.keys)} if keyed else {}
            )
            self._chain_len = 1
            self._last_step = step
            return self._full_job(step, blob, meta)

        records: List[Dict] = []
        payloads: Dict[str, np.ndarray] = {}
        anchors: List[Tuple[int, int]] = []
        anchor_keys: Dict[str, Tuple[int, int]] = {}
        bases: Set[int] = set()
        payload_bytes = 0

        def prev_anchor(i: int) -> Tuple[int, int]:
            return (self._anchor_keys[encoded.keys[i]] if keyed
                    else self._anchors[i])

        for i, c in enumerate(encoded.chunks):
            if c.encoding == "zero":
                # flattened ref: point at the dir where the bytes actually
                # materialize, so zero runs do not lengthen resolution
                base = prev_anchor(i)
                records.append({"e": "zero", "b": list(base)})
                anchors.append(base)
                bases.add(base[0])
            elif c.encoding == "raw":
                payloads[f"c{i}p0"] = np.asarray(c.payload)
                payload_bytes += int(np.asarray(c.payload).nbytes)
                records.append({"e": "raw"})
                anchors.append((step, i))
            else:  # codec'd fp32 delta against the previous submit's bytes
                base = prev_anchor(i)
                parts, dtypes = payload_parts(c)
                for j, p in enumerate(parts):
                    payloads[f"c{i}p{j}"] = p
                    payload_bytes += int(p.nbytes)
                records.append({"e": c.encoding, "b": list(base), "d": dtypes})
                anchors.append((step, i))
                bases.add(base[0])
            if keyed:
                anchor_keys[encoded.keys[i]] = anchors[-1]
        self._anchors = anchors
        self._anchor_keys = anchor_keys
        self._chain_len += 1
        self._last_step = step
        manifest = {
            "step": step,
            "format": "delta",
            "meta": meta,
            "chunk_bytes": encoded.chunk_bytes,
            "n_chunks": encoded.n_chunks,
            "layout": layout_to_json(encoded.layout),
            "paged": keyed,
            "chunks": records,
            "bases": sorted(bases),
            "payload_bytes": payload_bytes,
            "bytes": encoded.total_bytes,
        }
        with self._lock:
            self._bases[step] = bases
            self._dropped.discard(step)
        return {"step": step, "format": "delta", "payloads": payloads,
                "manifest": manifest, "meta": meta}

    def _full_job(self, step: int, blob: Dict[str, np.ndarray],
                  meta: Dict) -> Dict:
        """A self-contained snapshot job + its GC registration (shared by
        the none-mode path and every delta-mode full fallback; callers on
        the delta path reset the chain state first)."""
        with self._lock:
            self._bases[step] = set()
            self._dropped.discard(step)
        return {"step": step, "format": "full", "blob": blob, "meta": meta}

    def _write_prepared(self, job: Dict) -> None:
        step = job["step"]
        tmp, final = self._tmp(step), self._final(step)
        os.makedirs(tmp, exist_ok=True)
        if job["format"] == "full":
            blob = job["blob"]
            enc_blob: Dict[str, np.ndarray] = {}
            raw_dtypes: Dict[str, List] = {}
            for k, a in blob.items():
                a = np.asarray(a)
                if a.dtype.isbuiltin != 1:
                    # np.savez mangles non-native dtypes (bfloat16 -> void)
                    # into unrestorable arrays: ship uint8 views + tags
                    enc_blob[k] = np.ascontiguousarray(a).reshape(-1).view(np.uint8)
                    raw_dtypes[k] = [str(a.dtype), list(a.shape)]
                else:
                    enc_blob[k] = a
            np.savez(os.path.join(tmp, "state.npz"), **enc_blob)
            manifest = {
                "step": step,
                "format": "full",
                "time": time.time(),
                "meta": job["meta"],
                "leaves": len(blob),
                "bytes": int(sum(np.asarray(a).nbytes for a in blob.values())),
                "bases": [],
                "raw_dtypes": raw_dtypes,
            }
        else:
            if job["payloads"]:
                np.savez(os.path.join(tmp, "chunks.npz"), **job["payloads"])
            manifest = dict(job["manifest"])
            manifest["time"] = time.time()
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        io_bytes = sum(
            os.path.getsize(os.path.join(tmp, n)) for n in os.listdir(tmp)
        )
        with self._lock:
            if step in self._dropped:
                # drop/trim cancelled this step while the writer ran: the
                # old code let the writer republish a just-dropped dir
                shutil.rmtree(tmp, ignore_errors=True)
                self._bases.pop(step, None)
                return
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self.last_io_bytes = io_bytes
            self.io_bytes_total += io_bytes
            self._gc_locked()

    def wait(self) -> None:
        while self._inflight:
            self._drain_one()

    def _drain_one(self) -> None:
        # join BEFORE removing: a live writer must stay visible in
        # ``_inflight`` or a concurrent writer's GC mistakes its tmp dir
        # for dead-writer debris and deletes it mid-write
        self._inflight[0][1].join()
        self._inflight.pop(0)

    def _reap(self) -> None:
        self._inflight = [(s, t) for s, t in self._inflight if t.is_alive()]

    # ---- reads -------------------------------------------------------------
    def steps(self) -> List[int]:
        with self._lock:
            dropped = set(self._dropped)
        return [s for s in self._disk_steps() if s not in dropped]

    def load(self, template: PyTree, step: Optional[int] = None) -> Optional[Restored]:
        """Newest (or requested) restorable snapshot. Walks newest-first
        past torn/unreadable dirs: the old code gave up when the NEWEST
        snapshot was torn, skipping the whole durable rung even though an
        older intact ``step-*`` dir could have served the restore."""
        self.wait()
        avail = self.steps()
        if step is not None:
            candidates = [step] if step in avail else []
        else:
            candidates = list(reversed(avail))
        for s in candidates:
            got = self._load_step(s, template)
            if got is not None:
                return got
        return None

    def _load_step(self, step: int, template: PyTree) -> Optional[Restored]:
        path = self._final(step)
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            if manifest.get("format", "full") == "full":
                blob = self._load_full_blob(step)
                dirs = 1
                info = ""
            else:
                blob, dirs = self._load_chain_blob(step, manifest)
                info = f"chain:{dirs}"
            if blob is None:
                return None
            # inside the guard: a dir whose blob no longer matches the
            # template (schema drift, renamed leaves) is torn for THIS
            # restore and must fall back to older steps like any other
            state = unflatten_like(template, blob)
        except Exception:  # noqa: BLE001 - ANY torn dir falls to older steps
            return None
        self.last_restore_info = info
        self.last_restore_dirs = dirs
        return step, state, manifest.get("meta", {})

    def _load_full_blob(self, step: int) -> Dict[str, np.ndarray]:
        path = self._final(step)
        with open(os.path.join(path, "manifest.json")) as f:
            raw_dtypes = json.load(f).get("raw_dtypes", {})
        with np.load(os.path.join(path, "state.npz")) as z:
            out = {}
            for k in z.files:
                a = z[k]
                if k in raw_dtypes:
                    dt, shape = raw_dtypes[k]
                    a = a.view(np.dtype(dt)).reshape([int(d) for d in shape])
                out[k] = a
            return out

    def _load_chain_blob(self, step: int, manifest: Dict
                         ) -> Tuple[Optional[Dict[str, np.ndarray]], int]:
        """Resolve a delta dir's chunk stream through its base references.
        Reads <= ``max_chain`` dirs by construction (every base ref points
        strictly backwards and chains reset at each full snapshot); any
        inconsistency (missing base dir, layout drift, re-chunked base)
        degrades to None so ``load`` falls back to an older step."""
        layout = layout_from_json(manifest["layout"])
        chunk_bytes = int(manifest["chunk_bytes"])
        n_chunks = int(manifest["n_chunks"])
        # a paged chain's base dirs each carry their OWN page set: full
        # cuts are page cuts of that dir's blob (indices into its sorted
        # keys, what the submit anchored), never validated against the tip
        # layout - page tables legitimately drift along the chain
        paged = bool(manifest.get("paged"))
        dirs: Dict[int, Tuple[Dict, Dict[str, np.ndarray]]] = {}
        full_cuts: Dict[int, List[np.ndarray]] = {}

        def load_dir(s: int) -> Tuple[Dict, Dict[str, np.ndarray]]:
            if s in dirs:
                return dirs[s]
            path = self._final(s)
            with open(os.path.join(path, "manifest.json")) as f:
                man = json.load(f)
            payloads: Dict[str, np.ndarray] = {}
            if man.get("format", "full") == "delta":
                cpath = os.path.join(path, "chunks.npz")
                if os.path.exists(cpath):
                    with np.load(cpath) as z:
                        payloads = {k: z[k] for k in z.files}
            dirs[s] = (man, payloads)
            return dirs[s]

        def full_cut(s: int) -> List[np.ndarray]:
            if s not in full_cuts:
                if paged:
                    cb = chunk_pages(self._load_full_blob(s))
                else:
                    cb = chunk_blob(self._load_full_blob(s), chunk_bytes)
                    if cb.layout != layout:
                        raise ValueError(f"base step {s} layout drifted")
                full_cuts[s] = [c.payload for c in cb.chunks]
            return full_cuts[s]

        memo: Dict[Tuple[int, int], np.ndarray] = {}

        def resolve(s: int, i: int) -> np.ndarray:
            if (s, i) in memo:
                return memo[(s, i)]
            man, payloads = load_dir(s)
            if man.get("format", "full") == "full":
                raw = full_cut(s)[i]
            else:
                rec = man["chunks"][i]
                enc = rec["e"]
                if enc == "raw":
                    raw = payloads[f"c{i}p0"]
                else:
                    bs, bi = rec["b"]
                    if not bs < s:  # corrupt ref: refuse to loop forever
                        raise ValueError(f"non-monotone base ref {bs} in {s}")
                    ref = resolve(int(bs), int(bi))
                    if enc == "zero":
                        raw = ref
                    else:
                        parts = [
                            payloads[f"c{i}p{j}"] for j in range(len(rec["d"]))
                        ]
                        payload = payload_from_parts(enc, parts, rec["d"])
                        raw = decode_delta(
                            Chunk(index=i, encoding=enc, payload=payload, ref=ref)
                        )
            memo[(s, i)] = raw
            return raw

        raws = [resolve(step, i) for i in range(n_chunks)]
        total = sum(s.nbytes for s in layout)
        for i, raw in enumerate(raws):
            want = (layout[i].nbytes if paged
                    else min(chunk_bytes, total - i * chunk_bytes))
            if raw.nbytes != want:
                raise ValueError(f"chunk {i} size drifted")
        blob = ChunkedBlob(layout=layout, chunk_bytes=chunk_bytes).to_blob(raws)
        return blob, len(dirs)

    # ---- space management --------------------------------------------------
    def drop(self, step: int) -> None:
        """Forget ``step``: hidden from ``steps()``/``load`` immediately, an
        in-flight writer for it is mark-cancelled (it discards instead of
        republishing - the old race), and the dir is physically removed as
        soon as no live chain references it."""
        with self._lock:
            self._mark_dropped_locked(step)
            self._retain_locked(keep=0)

    def trim(self, keep: int) -> None:
        with self._lock:
            visible = [s for s in self._disk_steps() if s not in self._dropped]
            for s in visible[:-keep] if keep else []:
                self._mark_dropped_locked(s)
            self._retain_locked(keep=0)

    def _mark_dropped_locked(self, step: int) -> None:
        """Hide ``step`` and make the drop survive a restart: a dir kept
        alive only as a chain base carries a ``dropped`` marker (deleted
        with the dir; a resubmit's atomic rename replaces the dir, marker
        and all), so a crash-restart does not resurrect forgotten steps."""
        self._dropped.add(step)
        if step == self._last_step or step in self._anchor_steps():
            self._chain_broken = True  # forgotten steps never anchor chains
        final = self._final(step)
        if os.path.isdir(final):
            try:
                with open(os.path.join(final, "dropped"), "w"):
                    pass
            except OSError:
                pass

    def _anchor_steps(self) -> Set[int]:
        """Steps the NEXT delta submit would reference (indexed + keyed)."""
        steps = {s for s, _ in self._anchors}
        steps.update(s for s, _ in self._anchor_keys.values())
        return steps

    def _gc_locked(self) -> None:
        self._retain_locked(keep=self.keep)
        # tmp dirs no live writer owns are debris from a dead writer
        active = {s for s, t in list(self._inflight) if t.is_alive()}
        self._gc_stale_tmp(skip=active)

    def _retain_locked(self, keep: int) -> None:
        """Delete every step dir outside the retained set: the newest
        ``keep`` visible steps (all of them when ``keep=0``), any step with
        a live in-flight writer, and the transitive closure of their base
        references - the ref-counted GC that keeps a chain's bases alive
        however old or dropped they are."""
        disk = self._disk_steps()
        visible = [s for s in disk if s not in self._dropped]
        wanted = set(visible[-keep:]) if keep else set(visible)
        for s, t in list(self._inflight):
            if t.is_alive() and s not in self._dropped:
                wanted.add(s)
        live: Set[int] = set()
        frontier = list(wanted)
        while frontier:
            s = frontier.pop()
            if s in live:
                continue
            live.add(s)
            frontier.extend(self._bases.get(s, ()))
        anchor_steps = self._anchor_steps()
        if self._last_step is not None:
            anchor_steps.add(self._last_step)
        for s in disk:
            if s not in live:
                shutil.rmtree(self._final(s), ignore_errors=True)
                self._bases.pop(s, None)
                if s in anchor_steps:
                    self._chain_broken = True
        # prune bookkeeping for steps that no longer exist anywhere; a
        # dropped flag must outlive its (possibly stalled) writer so the
        # mark-cancel in _write_prepared still sees it
        present = set(disk) & live
        alive = {s for s, t in list(self._inflight) if t.is_alive()}
        self._dropped &= present | alive
        # the prune keeps _last_step even when its dir/writer is not yet
        # visible: a submit registers its bases (after setting _last_step)
        # BEFORE its writer lands in _inflight, and a concurrent publish's
        # GC must not forget the pending chain link's references
        self._bases = {
            s: b for s, b in self._bases.items()
            if s in present or s in alive or s == self._last_step
        }
        self._write_refs_locked()

    def _gc_stale_tmp(self, skip=()) -> None:
        for name in os.listdir(self.directory):
            if not name.startswith(".tmp-"):
                continue
            try:
                step = int(name.split("-", 1)[1])
            except ValueError:
                step = None
            if step in skip:
                continue
            shutil.rmtree(os.path.join(self.directory, name), ignore_errors=True)

    # ---- the refcount sidecar ----------------------------------------------
    def _rebuild_refs_locked(self) -> None:
        """Startup: rebuild the ref graph from the published manifests -
        the sidecar is advisory only, so refs orphaned by a crash between
        a dir's publish and the sidecar update always heal. Refs to dirs
        that no longer exist are discarded (the referring delta dir is
        unrestorable and ``load`` walks past it)."""
        self._bases = {}
        for s in self._disk_steps():
            if os.path.exists(os.path.join(self._final(s), "dropped")):
                self._dropped.add(s)
            try:
                with open(os.path.join(self._final(s), "manifest.json")) as f:
                    man = json.load(f)
                self._bases[s] = {int(b) for b in man.get("bases", [])}
            except Exception:  # noqa: BLE001 - torn dir: no refs derivable
                self._bases[s] = set()
        present = set(self._bases)
        for bs in self._bases.values():
            bs &= present
        self._write_refs_locked()

    def _write_refs_locked(self) -> None:
        counts: Dict[int, int] = {}
        for bs in self._bases.values():
            for b in bs:
                counts[b] = counts.get(b, 0) + 1
        payload = {
            "refs": {str(s): sorted(bs) for s, bs in sorted(self._bases.items())},
            "refcounts": {str(s): n for s, n in sorted(counts.items())},
        }
        tmp = os.path.join(self.directory, ".refs.json.tmp")
        try:
            with open(tmp, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, os.path.join(self.directory, "refs.json"))
        except OSError:
            pass  # advisory: the next startup rebuilds from manifests
