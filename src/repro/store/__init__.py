"""``repro.store`` - the recovery-state plane.

One :class:`StateStore` protocol (``submit`` / ``load`` / ``steps`` /
``drop`` / ``trim``), three backends ordered by restore cost, and a
:class:`RecoveryLadder` policy that owns the source-selection ordering
the session used to hand-roll:

====== ======================== ============================================
level  backend                  survives
====== ======================== ============================================
0      :class:`LiveCloneStore`  nothing beyond its host - O(memcpy) restore
1      :class:`PartnerMemoryStore` any failure leaving >= 1 holder per shard
                                (K-way ReStore-style redundancy)
2      :class:`DurableStore`    job teardown (npz + manifest, atomic;
                                optional ref-counted on-disk delta chains)
====== ======================== ============================================

Paper mapping: level 1 is Sec. III-A's partner replica memory generalized
per ReStore (Huebner et al., 2022); level 2 is the classic multi-level
durable tier; level 0 is the Sec. III-A process-image transfer
(``core/state_transfer``) behind the same API for dynamic replica rebirth.

State movement (staging, striping, pipelined async submit, delta
encoding, digest verification) is owned by the ``repro.xfer`` transfer
plane; every ladder carries one (``RecoveryLadder(stores, xfer=...)``)
and its chunk-consuming levels adopt it.
"""
from repro.store.base import (
    PyTree,
    Restored,
    StateStore,
    flatten_with_paths,
    unflatten_like,
)
from repro.store.durable import DurableStore
from repro.store.ladder import LadderRestore, RecoveryLadder, RestoreAttempt
from repro.store.liveclone import LiveCloneStore
from repro.store.partner import PartnerMemoryStore

__all__ = [
    "DurableStore",
    "LadderRestore",
    "LiveCloneStore",
    "PartnerMemoryStore",
    "PyTree",
    "RecoveryLadder",
    "Restored",
    "RestoreAttempt",
    "StateStore",
    "flatten_with_paths",
    "unflatten_like",
]
