"""Core neural layers, written as pure functions over param pytrees.

Attention comes in four implementations selected by ``impl``:

- ``naive``   : full (S,S) score matrix - small-shape oracle only.
- ``chunked`` : flash-style online-softmax lax.scan over KV blocks - the
                production jnp path used by the multi-pod dry-run (keeps
                activation memory O(S * block) instead of O(S^2)).
- ``banded``  : exact sliding-window attention computing only the diagonal
                band (used for SWA layers at long sequence lengths).
- ``pallas``  : the TPU kernel in ``repro.kernels`` (interpret=True on CPU).

All attention entry points are causal decoder-style unless ``causal=False``
(encoder / cross attention).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig

# ---------------------------------------------------------------------------
# dtype helpers / initialisation
# ---------------------------------------------------------------------------


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# normalisation
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps: float = 1e-5, impl: str = "jnp"):
    if impl == "pallas":
        from repro.kernels import rmsnorm_ops

        return rmsnorm_ops.rmsnorm(x, scale, eps=eps)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE + qwen2-vl M-RoPE)
# ---------------------------------------------------------------------------


def _rope_angles(positions, head_dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x (B,S,H,hd), positions (B,S) or (S,) -> rotated x (half-split layout)."""
    B, S, H, hd = x.shape
    if positions.ndim == 1:
        positions = positions[None, :]
    cos, sin = _rope_angles(positions, hd, theta)  # (B,S,hd/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_positions(batch: int, seq: int, n_prefix: int):
    """Qwen2-VL multimodal positions (3, B, S): temporal/height/width.

    The vision prefix (n_prefix patches, stubbed frontend) is laid out on an
    (g x g) grid at t=0; text tokens advance t sequentially afterwards.
    """
    g = max(1, int(np.sqrt(max(n_prefix, 1))))
    idx = np.arange(seq)
    is_txt = idx >= n_prefix
    t = np.where(is_txt, idx - n_prefix + 1, 0)
    h = np.where(is_txt, idx - n_prefix + 1, np.minimum(idx // g, g - 1))
    w = np.where(is_txt, idx - n_prefix + 1, idx % g)
    pos = jnp.asarray(np.stack([t, h, w]), dtype=jnp.int32)  # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq))


def apply_mrope(x, positions3, theta: float, sections=(0.25, 0.375, 0.375)):
    """M-RoPE: split the rotary dim into t/h/w sections with separate ids.

    x (B,S,H,hd); positions3 (3,B,S).
    """
    B, S, H, hd = x.shape
    half = hd // 2
    secs = [int(round(s * half)) for s in sections]
    secs[-1] = half - sum(secs[:-1])
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # pick the position id per frequency slot by section
    sec_id = jnp.concatenate(
        [jnp.full((n,), i, dtype=jnp.int32) for i, n in enumerate(secs)]
    )  # (half,)
    pos = positions3.astype(jnp.float32)  # (3,B,S)
    pos_per_slot = jnp.take(pos, sec_id, axis=0)  # (half?,B,S) -> gathers along axis0
    ang = jnp.moveaxis(pos_per_slot, 0, -1) * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------


def repeat_kv(k, n_rep: int):
    """(B,S,KV,hd) -> (B,S,KV*n_rep,hd) by head repetition (GQA)."""
    if n_rep == 1:
        return k
    B, S, KV, hd = k.shape
    return jnp.repeat(k, n_rep, axis=2)


def _softcap(scores, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(scores / cap) * cap
    return scores


def attn_naive(q, k, v, *, causal: bool = True, window: int = 0,
               softcap: float = 0.0, q_offset: int = 0):
    """Reference attention. q (B,Sq,H,hd) k/v (B,Sk,KV,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    k = repeat_kv(k, H // KV)
    v = repeat_kv(v, H // KV)
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    scores = _softcap(scores, softcap)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window and window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attn_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                 softcap: float = 0.0, block_q: int = 512, block_k: int = 1024):
    """Flash-style online-softmax attention in pure jnp.

    Memory is O(S * block_k) per head. Both S dims must be multiples of the
    block sizes (callers pad). Used by the dry-run so compile-time memory
    analysis reflects a production attention, not an (S,S) allocation.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    n_rep = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, k.shape[1])
    nq = S // block_q
    nk = k.shape[1] // block_k
    scale = 1.0 / np.sqrt(hd)

    qb = q.reshape(B, nq, block_q, H, hd).astype(jnp.float32) * scale
    kb = k.reshape(B, nk, block_k, KV, hd)
    vb = v.reshape(B, nk, block_k, KV, hd)

    def kv_step(carry, j):
        m, l, o = carry  # (B,nq,H,bq), (B,nq,H,bq), (B,nq,H,bq,hd)
        kj = jnp.repeat(kb[:, j].astype(jnp.float32), n_rep, axis=2)  # (B,bk,H,hd)
        vj = jnp.repeat(vb[:, j].astype(jnp.float32), n_rep, axis=2)
        s = jnp.einsum("bnqhd,bkhd->bnhqk", qb, kj)
        s = _softcap(s, softcap)
        qpos = (jnp.arange(nq * block_q)).reshape(nq, block_q)  # (nq,bq)
        kpos = j * block_k + jnp.arange(block_k)
        mask = jnp.ones((nq, block_q, block_k), dtype=bool)
        if causal:
            mask &= qpos[:, :, None] >= kpos[None, None, :]
        if window and window > 0:
            mask &= qpos[:, :, None] - kpos[None, None, :] < window
        s = jnp.where(mask[None, :, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum("bnhqk,bkhd->bnhqd", p, vj)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, nq, H, block_q), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((B, nq, H, block_q), dtype=jnp.float32)
    o0 = jnp.zeros((B, nq, H, block_q, hd), dtype=jnp.float32)
    (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0), jnp.arange(nk))
    o = o / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(o, 2, 3).reshape(B, S, H, hd)  # (B,nq,H,bq,hd)->(B,S,H,hd)
    return out.astype(q.dtype)


def attn_banded(q, k, v, *, window: int, softcap: float = 0.0, block_q: int = 512):
    """Exact sliding-window attention computing only the diagonal band.

    Work is O(S * (window + block_q)) - the long_500k-friendly SWA path.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    n_rep = H // KV
    block_q = min(block_q, S)
    nq = S // block_q
    band = window + block_q  # keys that can be visible to a q block
    scale = 1.0 / np.sqrt(hd)
    # pad keys on the left so every block can slice a fixed-size band
    pad = band
    kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))

    def q_block(i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * block_q, block_q, axis=1)
        qi = qi.astype(jnp.float32) * scale
        # band start in padded coords: (i*block_q + block_q - band) + pad
        start = i * block_q + block_q - band + pad
        ki = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=1)
        ki = jnp.repeat(ki.astype(jnp.float32), n_rep, axis=2)
        vi = jnp.repeat(vi.astype(jnp.float32), n_rep, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, ki)
        s = _softcap(s, softcap)
        qpos = i * block_q + jnp.arange(block_q)
        kpos = start - pad + jnp.arange(band)
        mask = (qpos[:, None] >= kpos[None, :]) & (qpos[:, None] - kpos[None, :] < window)
        mask &= kpos[None, :] >= 0
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, vi).astype(q.dtype)

    blocks = jax.lax.map(q_block, jnp.arange(nq))  # (nq,B,bq,H,hd)
    return jnp.moveaxis(blocks, 0, 1).reshape(B, S, H, hd)


def _maybe_constrain(x, *axes):
    """with_sharding_constraint when a mesh with the named axes is active
    (no-op in single-device smoke tests)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        names = set(mesh.axis_names)
        if any(a is not None and a not in names for a in axes):
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*axes)
        )
    except Exception:  # noqa: BLE001 - constraint is an optimisation only
        return x


def attn_decode_oneshot(q, k_cache, v_cache, pos, *, window: int = 0,
                        softcap: float = 0.0):
    """Single-einsum decode attention (no KV chunking).

    Preferred whenever the fp32 score tensor (B,H,Smax) is small (decode
    batches are): ONE hd-contraction means GSPMD inserts a single partial
    -sum all-reduce per layer for hd-sharded caches, where the chunked scan
    forced per-chunk resharding of the whole cache (the 'involuntary full
    rematerialization' path, ~200x more collective bytes - see
    EXPERIMENTS.md Perf-2).
    """
    B, Sq, H, hd = q.shape
    KV = k_cache.shape[2]
    Smax = k_cache.shape[1]
    n_rep = H // KV
    # grouped-query einsum: never materialise the GQA-expanded cache
    qf = q[:, 0].astype(jnp.float32).reshape(B, KV, n_rep, hd) * (
        1.0 / np.sqrt(hd)
    )
    # align q with the hd-sharded cache: the QK contraction then runs
    # shard-local with ONE psum of the (small) score tensor, instead of
    # GSPMD all-gathering the whole cache to match head-sharded q
    # (EXPERIMENTS.md Perf-2: 45 GB -> sub-GB of collectives per step).
    qf = _maybe_constrain(qf, None, None, None, "model")
    s = jnp.einsum("bknd,bskd->bkns", qf, k_cache.astype(jnp.float32))
    s = _softcap(s, softcap)
    kpos = jnp.arange(Smax)
    posb = pos[:, None, None, None] if jnp.ndim(pos) else pos
    mask = kpos[None, None, None, :] <= posb
    if window and window > 0:
        mask &= posb - kpos[None, None, None, :] < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkns,bskd->bknd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# one-shot decode is used when the fp32 score tensor stays under this bound
ONESHOT_SCORE_BYTES = 256 * 2**20


def attn_decode(q, k_cache, v_cache, pos, *, window: int = 0,
                softcap: float = 0.0, block_k: int = 2048):
    """Single-token decode attention against a (B,Smax,KV,hd) cache.

    ``pos`` is the index of the current token - a scalar int32 when every
    row decodes in lockstep, or a per-row ``(B,)`` vector when rows sit at
    independent sequence positions (the serving gateway's continuous
    batcher admits a request into a freed slot mid-decode, so each slot
    carries its own position). Cache entries at indices > pos are masked
    out per row. Dispatches to the one-shot path for moderate caches;
    falls back to online softmax over KV chunks so the working set stays
    bounded for 500k caches.
    """
    B, Sq, H, hd = q.shape
    assert Sq == 1
    KV = k_cache.shape[2]
    n_rep = H // KV
    Smax = k_cache.shape[1]
    if B * H * Smax * 4 <= ONESHOT_SCORE_BYTES:
        return attn_decode_oneshot(
            q, k_cache, v_cache, pos, window=window, softcap=softcap
        )
    block_k = min(block_k, Smax)
    nk = Smax // block_k
    scale = 1.0 / np.sqrt(hd)
    qf = q[:, 0].astype(jnp.float32) * scale  # (B,H,hd)

    kb = k_cache.reshape(B, nk, block_k, KV, hd)
    vb = v_cache.reshape(B, nk, block_k, KV, hd)

    def kv_step(carry, j):
        m, l, o = carry  # (B,H), (B,H), (B,H,hd)
        kj = jnp.repeat(kb[:, j].astype(jnp.float32), n_rep, axis=2)  # (B,bk,H,hd)
        vj = jnp.repeat(vb[:, j].astype(jnp.float32), n_rep, axis=2)
        s = jnp.einsum("bhd,bkhd->bhk", qf, kj)
        s = _softcap(s, softcap)
        kpos = j * block_k + jnp.arange(block_k)
        posb = pos[:, None, None] if jnp.ndim(pos) else pos
        mask = kpos[None, None, :] <= posb
        if window and window > 0:
            mask &= posb - kpos[None, None, :] < window
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum("bhk,bkhd->bhd", p, vj)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, H), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((B, H), dtype=jnp.float32)
    o0 = jnp.zeros((B, H, hd), dtype=jnp.float32)
    (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0), jnp.arange(nk))
    out = (o / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
    return out[:, None]  # (B,1,H,hd)


def attention(q, k, v, *, impl: str = "chunked", causal: bool = True,
              window: int = 0, softcap: float = 0.0):
    """Dispatch over attention implementations (self-attention, train/prefill)."""
    if impl == "naive":
        return attn_naive(q, k, v, causal=causal, window=window, softcap=softcap)
    if impl == "banded" or (impl == "chunked" and window and q.shape[1] > 4 * window):
        if window and causal:
            return attn_banded(q, k, v, window=window, softcap=softcap)
    if impl == "pallas":
        from repro.kernels import flash_attention_ops

        return flash_attention_ops.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap
        )
    return attn_chunked(q, k, v, causal=causal, window=window, softcap=softcap)


# ---------------------------------------------------------------------------
# attention layer (params + forward, with KV cache support)
# ---------------------------------------------------------------------------


def attn_params_init(key, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    q_dim = cfg.n_heads * hd
    kv_dim = cfg.n_kv_heads * hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, q_dim, dtype),
        "wk": dense_init(ks[1], d, kv_dim, dtype),
        "wv": dense_init(ks[2], d, kv_dim, dtype),
        "wo": dense_init(ks[3], q_dim, d, dtype, scale=1.0 / np.sqrt(q_dim)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((q_dim,), dtype)
        p["bk"] = jnp.zeros((kv_dim,), dtype)
        p["bv"] = jnp.zeros((kv_dim,), dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    # NOTE: no explicit sharding constraint here. Forcing head-sharding on
    # q/k/v was measured to REGRESS the prefill cells by 17-57% on the
    # collective term (EXPERIMENTS.md Perf-5): GSPMD's propagated layout
    # for the train/prefill attention already beats padded-head sharding
    # when KV*hd crosses shard boundaries. The decode path constrains at
    # the point of use instead (attn_decode_forward).
    return q, k, v


def attn_forward(p, x, cfg: ModelConfig, *, is_global: bool, impl: str,
                 positions=None, mrope_pos=None):
    """Self-attention over a full sequence (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if positions is None:
        positions = jnp.arange(S)
    if cfg.mrope and mrope_pos is not None:
        q = apply_mrope(q, mrope_pos, cfg.rope_theta)
        k = apply_mrope(k, mrope_pos, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    window = 0 if is_global else cfg.window
    o = attention(q, k, v, impl=impl, causal=True, window=window,
                  softcap=cfg.attn_logit_softcap)
    o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return o @ p["wo"], (k, v)


def attn_decode_forward(p, x, cache, pos, cfg: ModelConfig, *, is_global: bool,
                        impl: str = "chunked"):
    """One-token decode. cache = {'k','v'} of shape (B, Smax, KV, hd).

    Returns output (B,1,D) and the updated cache. For windowed layers the
    cache length is the window size and indexing is modular (ring buffer).
    ``pos`` may be a per-row ``(B,)`` vector (slot-granular decode: each
    request row advances its own position); the cache write then scatters
    one row at a time instead of updating a shared column.
    """
    del impl
    B = x.shape[0]
    per_row = jnp.ndim(pos) > 0
    q, k, v = _project_qkv(p, x, cfg)
    if cfg.mrope:
        pos2 = pos[:, None] if per_row else jnp.full((B, 1), pos)
        pos3 = jnp.broadcast_to(pos2, (3, B, 1)).astype(jnp.int32)
        q = apply_mrope(q, pos3, cfg.rope_theta)
        k = apply_mrope(k, pos3, cfg.rope_theta)
    else:
        posv = (pos[:, None] if per_row
                else jnp.full((B, 1), pos)).astype(jnp.int32)
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)
    Smax = cache["k"].shape[1]
    slot = jnp.where(Smax < jnp.asarray(10**9), pos % Smax, pos)
    # write path: match the cache's hd-sharding so the update is local
    k = _maybe_constrain(k, None, None, None, "model")
    v = _maybe_constrain(v, None, None, None, "model")
    if per_row:
        rows = jnp.arange(B)
        k_cache = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
        v_cache = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    window = 0 if is_global else cfg.window
    if window and Smax <= window:
        # ring buffer: every live entry is in-window; mask only unwritten slots
        o = attn_decode(q, k_cache, v_cache, jnp.minimum(pos, Smax - 1), window=0,
                        softcap=cfg.attn_logit_softcap)
    else:
        o = attn_decode(q, k_cache, v_cache, pos, window=window,
                        softcap=cfg.attn_logit_softcap)
    o = o.reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return o @ p["wo"], {"k": k_cache, "v": v_cache}


def gather_cache_page(arr, batch_axis: int, row: int, t0: Optional[int] = None,
                      t1: Optional[int] = None):
    """One request row's cache page: slice row ``row`` out of a stacked
    cache leaf (dropping the batch axis), optionally bounded to time rows
    ``[t0, t1)`` on the axis right after it. Works on device arrays (a
    lazy slice the snapshot's host transfer materialises) and on host
    ndarrays alike - the paged serving snapshot's read path."""
    idx = (slice(None),) * batch_axis + (row,)
    if t0 is not None:
        idx = idx + (slice(t0, t1),)
    return arr[idx]


def scatter_cache_page(arr, batch_axis: int, row: int, page,
                       t0: Optional[int] = None, t1: Optional[int] = None):
    """Inverse of :func:`gather_cache_page` for HOST ndarrays: write a
    gathered page back into row ``row`` of a dense cache leaf (the paged
    restore's scatter into a zero-initialised cache)."""
    idx = (slice(None),) * batch_axis + (row,)
    if t0 is not None:
        idx = idx + (slice(t0, t1),)
    arr[idx] = page


def cross_attn_forward(p, x, enc_kv, cfg: ModelConfig):
    """Cross-attention (decoder over encoder output). enc_kv = (k, v)."""
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k, v = enc_kv
    o = attention(q, k, v, impl="chunked", causal=False, window=0)
    o = o.reshape(B, S, cfg.n_heads * hd)
    return o @ p["wo"]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_params_init(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d, f, dtype),
            "w_up": dense_init(ks[1], d, f, dtype),
            "w_down": dense_init(ks[2], f, d, dtype, scale=1.0 / np.sqrt(f)),
        }
    return {
        "w_up": dense_init(ks[0], d, f, dtype),
        "w_down": dense_init(ks[1], f, d, dtype, scale=1.0 / np.sqrt(f)),
    }


def mlp_forward(p, x, cfg: ModelConfig):
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.mlp == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    else:  # gelu
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style capacity dispatch)
# ---------------------------------------------------------------------------


def moe_params_init(key, cfg: ModelConfig, dtype):
    assert cfg.moe is not None
    moe = cfg.moe
    d, f, E = cfg.d_model, cfg.d_ff, moe.n_experts
    ks = jax.random.split(key, 4)

    def expert_stack(k, in_dim, out_dim, scale=None):
        return jax.vmap(lambda kk: dense_init(kk, in_dim, out_dim, dtype, scale))(
            jax.random.split(k, E)
        )

    p = {"router": dense_init(ks[0], d, E, jnp.float32, scale=0.02)}
    if cfg.mlp == "swiglu":
        p["w_gate"] = expert_stack(ks[1], d, f)
        p["w_up"] = expert_stack(ks[2], d, f)
        p["w_down"] = expert_stack(ks[3], f, d, scale=1.0 / np.sqrt(f))
    else:
        p["w_up"] = expert_stack(ks[1], d, f)
        p["w_down"] = expert_stack(ks[2], f, d, scale=1.0 / np.sqrt(f))
    return p


def moe_forward(p, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k capacity-based MoE with GShard-style GROUPED dispatch.

    Tokens are split into groups of ``moe.group_size`` with per-group
    capacity, so the dispatch/combine one-hot einsums cost
    O(T * g * E * k) instead of O(T^2 * k) - ungrouped dispatch was the
    dominant compute term of the mixtral train_4k cell (useful-FLOP ratio
    0.02; see EXPERIMENTS.md Perf-1).

    Dispatch/combine use one-hot einsums (TPU-friendly: no scatter). Expert
    tensors are sharded per MoEConfig.sharding by the jit-level param specs;
    with 'expert' sharding GSPMD turns the grouped dispatch einsum into
    all_to_all on the model axis.
    """
    moe = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = moe.n_experts, moe.top_k
    g = min(moe.group_size, T)
    while T % g:  # group size must tile the token stream
        g //= 2
    G = T // g
    C = max(4, int(moe.capacity_factor * K * g / E))
    C = min(C, g)
    xt = x.reshape(G, g, D)

    logits = jnp.einsum(
        "gtd,de->gte", xt.astype(jnp.float32), p["router"]
    )  # (G,g,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (G,g,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style, over all tokens)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = jnp.sum(me * ce) * E * moe.router_aux_coef

    # per-group capacity assignment
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (G,g,K,E)
    flat = onehot.reshape(G, g * K, E)
    pos_in_e = (jnp.cumsum(flat, axis=1) - flat).reshape(G, g, K, E)
    pos = jnp.einsum("gtke,gtke->gtk", pos_in_e, onehot)  # (G,g,K)
    keep = pos < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)  # (G,g,K,C)
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot * keep[..., None], pos_oh)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", gate_vals, onehot, pos_oh)

    xin = jnp.einsum(
        "gtec,gtd->egcd", dispatch, xt.astype(jnp.float32)
    ).astype(x.dtype)  # (E,G,C,D)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xin, p["w_gate"])) * jnp.einsum(
            "egcd,edf->egcf", xin, p["w_up"]
        )
    else:
        h = jnp.square(jax.nn.relu(jnp.einsum("egcd,edf->egcf", xin, p["w_up"])))
    eout = jnp.einsum("egcf,efd->egcd", h, p["w_down"])  # (E,G,C,D)
    out = jnp.einsum("gtec,egcd->gtd", combine, eout.astype(jnp.float32))
    return out.reshape(B, S, D).astype(x.dtype), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# embedding / logits / loss
# ---------------------------------------------------------------------------


def embed_forward(embed, tokens, cfg: ModelConfig):
    x = jnp.take(embed, tokens, axis=0)
    return x.astype(dtype_of(cfg)) * np.sqrt(cfg.d_model)


def logits_forward(params, x, cfg: ModelConfig):
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if cfg.tie_embeddings:
        return x @ head.T.astype(x.dtype)
    return x @ head.astype(x.dtype)


def softmax_xent(logits, labels, mask=None):
    """Stable cross-entropy; logits may be vocab-sharded (GSPMD reduces)."""
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1))
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    w = mask.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
