"""Mamba-2 SSD (state-space duality) blocks.

Three implementations of the SSD scan:

- ``ref``     : sequential recurrence (kernels/ssd_scan_ref.py) - the oracle.
- ``chunked`` : block-parallel SSD (intra-chunk quadratic + inter-chunk
                state scan) in pure jnp - the production/dry-run path.
- ``pallas``  : TPU kernel (kernels/ssd_scan.py), interpret=True on CPU.

Shapes: x (B,S,nh,hd); dt (B,S,nh); A (nh,) negative reals; B,C (B,S,ds)
shared across heads (n_groups=1 as in Mamba-2); D (nh,).
Recurrence per head: S_t = exp(dt_t A) S_{t-1} + dt_t x_t B_t^T,
y_t = S_t C_t + D x_t.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rmsnorm


# ---------------------------------------------------------------------------
# SSD scan implementations
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, Bm, Cm, D, *, chunk: int):
    """Chunked SSD. Returns y (B,S,nh,hd) and the final state (B,nh,hd,ds)."""
    Bb, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    # (B,nc,Q,...) views
    xc = xf.reshape(Bb, nc, Q, nh, hd)
    dtc = dtf.reshape(Bb, nc, Q, nh)
    Bc = Bf.reshape(Bb, nc, Q, ds)
    Cc = Cf.reshape(Bb, nc, Q, ds)

    dA = dtc * A  # (B,nc,Q,nh) log-decay per step
    cs = jnp.cumsum(dA, axis=2)  # inclusive cumulative log decay
    total = cs[:, :, -1]  # (B,nc,nh)

    # intra-chunk: y[i] += sum_{j<=i} exp(cs_i - cs_j) (C_i . B_j) dt_j x_j
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (B,nc,i,j,nh)
    tri = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    # mask in log space BEFORE exp: exp of unmasked upper triangle overflows
    # and poisons gradients through the 0-multiplied branch.
    decay = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -jnp.inf))
    cb = jnp.einsum("bnis,bnjs->bnij", Cc, Bc)  # (B,nc,i,j)
    w = cb[..., None] * decay * dtc[:, :, None, :, :]  # (B,nc,i,j,nh)
    y_intra = jnp.einsum("bnijh,bnjhd->bnihd", w, xc)

    # chunk states: S_c = sum_j exp(total - cs_j) dt_j x_j B_j^T
    sdecay = jnp.exp(total[:, :, None, :] - cs) * dtc  # (B,nc,Q,nh)
    S_c = jnp.einsum("bnjh,bnjhd,bnjs->bnhds", sdecay, xc, Bc)

    # inter-chunk recurrence over nc
    def step(S_run, inputs):
        S_chunk, tot = inputs  # (B,nh,hd,ds), (B,nh)
        S_next = S_run * jnp.exp(tot)[:, :, None, None] + S_chunk
        return S_next, S_run  # emit the state *entering* this chunk

    S0 = jnp.zeros((Bb, nh, hd, ds), dtype=jnp.float32)
    S_last, S_in = jax.lax.scan(
        step,
        S0,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    S_in = jnp.moveaxis(S_in, 0, 1)  # (B,nc,nh,hd,ds) state entering chunk

    # inter-chunk contribution: y[i] += exp(cs_i) C_i . S_in
    y_inter = jnp.einsum("bnis,bnhds,bnih->bnihd", Cc, S_in, jnp.exp(cs))
    y = (y_intra + y_inter).reshape(Bb, S, nh, hd) + D[None, None, :, None] * xf
    return y.astype(x.dtype), S_last


def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk: int, impl: str = "chunked"):
    if impl == "ref":
        from repro.kernels import ssd_scan_ref

        return ssd_scan_ref.ssd_ref(x, dt, A, Bm, Cm, D)
    if impl == "pallas":
        from repro.kernels import ssd_scan_ops

        return ssd_scan_ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk)
    return ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)


def ssd_decode_step(state, x, dt, A, Bm, Cm, D):
    """One-token SSD update. state (B,nh,hd,ds); x (B,nh,hd); dt (B,nh);
    Bm/Cm (B,ds). Returns (y (B,nh,hd), new_state)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A)  # (B,nh)
    upd = jnp.einsum("bh,bhd,bs->bhds", dtf, xf, Bm.astype(jnp.float32))
    state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhds,bs->bhd", state, Cm.astype(jnp.float32)) + D[None, :, None] * xf
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# causal depthwise conv (the mamba short conv)
# ---------------------------------------------------------------------------


def causal_conv1d(x, w, b):
    """x (B,S,Ch), w (Ch,k), b (Ch,) -> causal depthwise conv."""
    B, S, Ch = x.shape
    k = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):  # k is tiny (4): unrolled taps beat conv_general on TPU
        out = out + xp[:, i : i + S, :].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def conv_decode_step(conv_state, xt, w, b):
    """conv_state (B,k-1,Ch) holds the previous inputs; xt (B,Ch)."""
    k = w.shape[1]
    full = jnp.concatenate([conv_state, xt[:, None, :]], axis=1)  # (B,k,Ch)
    out = jnp.einsum("bkc,ck->bc", full.astype(jnp.float32), w.astype(jnp.float32))
    out = (out + b.astype(jnp.float32)).astype(xt.dtype)
    return out, full[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------


def mamba_params_init(key, cfg: ModelConfig, dtype):
    """The input projection is stored as FOUR separate column blocks
    (z | x | BC | dt) rather than one fused matrix: a fused (d, 10576)
    output slices the z/x/B/C/dt segments across model-axis shard
    boundaries, and GSPMD re-lays each slice with per-layer all-gathers
    (~3.4 GB/layer on mamba2-2.7b train_4k - see EXPERIMENTS.md Perf-4).
    Separate blocks keep every segment exactly shard-aligned. Same math,
    same total parameter count; the short conv splits likewise (x and BC
    channel groups)."""
    ssm = cfg.ssm
    d = cfg.d_model
    di = ssm.d_inner(d)
    nh = ssm.n_heads(d)
    ds = ssm.d_state
    ks = jax.random.split(key, 8)
    return {
        "in_z": dense_init(ks[0], d, di, dtype),
        "in_x": dense_init(ks[1], d, di, dtype),
        "in_bc": dense_init(ks[2], d, 2 * ds, dtype),
        "in_dt": dense_init(ks[3], d, nh, dtype),
        "conv_x_w": (jax.random.normal(ks[4], (di, ssm.d_conv)) * 0.1).astype(dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_bc_w": (jax.random.normal(ks[5], (2 * ds, ssm.d_conv)) * 0.1).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * ds,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[6], di, d, dtype, scale=1.0 / np.sqrt(di)),
    }


def _mamba_split(p, x, cfg: ModelConfig):
    ssm = cfg.ssm
    d = cfg.d_model
    di = ssm.d_inner(d)
    ds = ssm.d_state
    nh = ssm.n_heads(d)
    z = x @ p["in_z"]
    xc = x @ p["in_x"]
    bc = x @ p["in_bc"]
    dt = x @ p["in_dt"]
    return z, xc, bc, dt, di, ds, nh


def mamba_forward(p, x, cfg: ModelConfig, *, impl: str = "chunked"):
    """Full-sequence Mamba-2 block. Returns (out, final_states)."""
    ssm = cfg.ssm
    B, S, _ = x.shape
    z, xc, bc, dt, di, ds, nh = _mamba_split(p, x, cfg)
    xc = jax.nn.silu(causal_conv1d(xc, p["conv_x_w"], p["conv_x_b"]))
    bc = jax.nn.silu(causal_conv1d(bc, p["conv_bc_w"], p["conv_bc_b"]))
    xin = xc.reshape(B, S, nh, ssm.head_dim)
    Bm = bc[..., :ds]
    Cm = bc[..., ds:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, S_last = ssd_scan(xin, dt, A, Bm, Cm, p["D"], chunk=ssm.chunk, impl=impl)
    y = y.reshape(B, S, di)
    y = rmsnorm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["out_proj"], S_last


def mamba_decode_forward(p, x, state, cfg: ModelConfig):
    """One-token decode. state = {'conv_x', 'conv_bc', 'ssm'}."""
    ssm = cfg.ssm
    B = x.shape[0]
    z, xc, bc, dt, di, ds, nh = _mamba_split(p, x[:, 0, :], cfg)
    xc, conv_x = conv_decode_step(state["conv_x"], xc, p["conv_x_w"], p["conv_x_b"])
    bc, conv_bc = conv_decode_step(state["conv_bc"], bc, p["conv_bc_w"], p["conv_bc_b"])
    xc = jax.nn.silu(xc)
    bc = jax.nn.silu(bc)
    xin = xc.reshape(B, nh, ssm.head_dim)
    Bm = bc[..., :ds]
    Cm = bc[..., ds:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, ssm_state = ssd_decode_step(state["ssm"], xin, dt, A, Bm, Cm, p["D"])
    y = y.reshape(B, di)
    y = rmsnorm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"conv_x": conv_x, "conv_bc": conv_bc, "ssm": ssm_state}


def mamba_init_state(cfg: ModelConfig, batch: int, dtype):
    ssm = cfg.ssm
    d = cfg.d_model
    di = ssm.d_inner(d)
    nh = ssm.n_heads(d)
    return {
        "conv_x": jnp.zeros((batch, ssm.d_conv - 1, di), dtype),
        "conv_bc": jnp.zeros((batch, ssm.d_conv - 1, 2 * ssm.d_state), dtype),
        "ssm": jnp.zeros((batch, nh, ssm.head_dim, ssm.d_state), jnp.float32),
    }
