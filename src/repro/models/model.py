"""Model composition: init / forward / decode for all assigned families.

Layers are stacked (leading layer dim) and executed with ``lax.scan`` so the
compiled HLO contains a single layer body per segment - essential for
compiling 40+ layer configs quickly in the multi-pod dry-run.

Heterogeneous layer patterns (gemma3's 5 local : 1 global, hymba's three
global layers) are expressed as a *segment plan*: a list of homogeneous
param stacks executed in order, each with its own scan.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssd as S

PyTree = Any

# ---------------------------------------------------------------------------
# segment plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Segment:
    name: str
    count: int  # number of layers in this segment's stack
    kind: str  # 'attn' | 'ssm' | 'hybrid'
    is_global: bool  # full attention (vs sliding window)
    grouped: int = 0  # >0: gemma3-style [grouped local + 1 global] x count


def model_plan(cfg: ModelConfig) -> List[Segment]:
    if cfg.family == "ssm":
        return [Segment("blocks", cfg.n_layers, "ssm", False)]
    if cfg.family == "hybrid":
        segs: List[Segment] = []
        gl = set(cfg.hybrid_global_layers)
        i, run = 0, 0
        for li in range(cfg.n_layers):
            if li in gl:
                if run:
                    segs.append(Segment(f"swa{i}", run, "hybrid", False))
                    i += 1
                    run = 0
                segs.append(Segment(f"glb{li}", 1, "hybrid", True))
            else:
                run += 1
        if run:
            segs.append(Segment(f"swa{i}", run, "hybrid", False))
        return segs
    if cfg.attn_pattern == "local_global":
        ratio = cfg.local_global_ratio
        n_groups = cfg.n_layers // (ratio + 1)
        return [Segment("groups", n_groups, "attn", True, grouped=ratio)]
    is_global = cfg.attn_pattern == "full"
    return [Segment("blocks", cfg.n_layers, "attn", is_global)]


# ---------------------------------------------------------------------------
# per-layer params
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ModelConfig, kind: str, dtype, cross: bool = False):
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if kind == "ssm":
        p["mamba"] = S.mamba_params_init(ks[0], cfg, dtype)
        return p
    if kind == "hybrid":
        p["attn"] = L.attn_params_init(ks[0], cfg, dtype)
        p["mamba"] = S.mamba_params_init(ks[1], cfg, dtype)
        p["norm_attn"] = jnp.zeros((cfg.d_model,), dtype)
        p["norm_ssm"] = jnp.zeros((cfg.d_model,), dtype)
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        p["mlp"] = L.mlp_params_init(ks[2], cfg, dtype)
        return p
    # attn kinds
    p["attn"] = L.attn_params_init(ks[0], cfg, dtype)
    if not cfg.parallel_block:
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
    if cross:
        p["lnx"] = jnp.zeros((cfg.d_model,), dtype)
        p["xattn"] = L.attn_params_init(ks[3], cfg, dtype)
    if cfg.family == "moe":
        p["moe"] = L.moe_params_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.mlp_params_init(ks[2], cfg, dtype)
    return p


def _stack_init(key, cfg: ModelConfig, n: int, kind: str, dtype, cross=False):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _layer_init(k, cfg, kind, dtype, cross))(keys)


def init(key, cfg: ModelConfig) -> PyTree:
    dtype = L.pdtype_of(cfg)
    ks = jax.random.split(key, 16)
    V = cfg.padded_vocab()
    params: Dict[str, Any] = {
        "embed": L.embed_init(ks[0], V, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[1], cfg.d_model, V, dtype)

    plan = model_plan(cfg)
    segs: Dict[str, Any] = {}
    for i, seg in enumerate(plan):
        k = ks[2 + (i % 12)]
        if seg.grouped:
            kl, kg = jax.random.split(k)
            local = jax.vmap(
                lambda kk: _stack_init(kk, cfg, seg.grouped, "attn", dtype)
            )(jax.random.split(kl, seg.count))
            glob = _stack_init(kg, cfg, seg.count, "attn", dtype)
            segs[seg.name] = {"local": local, "global": glob}
        else:
            segs[seg.name] = _stack_init(k, cfg, seg.count, seg.kind, dtype)
    params["segments"] = segs

    if cfg.enc_layers:
        params["enc"] = _stack_init(ks[14], cfg, cfg.enc_layers, "attn", dtype)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dtype)
        params["dec_cross"] = None  # cross-attn params live inside decoder stack
        # re-init the decoder stack with cross-attention
        params["segments"]["blocks"] = _stack_init(
            ks[15], cfg, cfg.n_layers, "attn", dtype, cross=True
        )
    return params


# ---------------------------------------------------------------------------
# layer forward bodies (full-sequence)
# ---------------------------------------------------------------------------


def _attn_layer(p, x, cfg: ModelConfig, *, is_global: bool, impl: str,
                mrope_pos=None, enc_out=None):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    attn_out, kv = L.attn_forward(
        p["attn"], h, cfg, is_global=is_global, impl=impl, mrope_pos=mrope_pos
    )
    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block:
        mlp_out = L.mlp_forward(p["mlp"], h, cfg)
        return x + attn_out + mlp_out, aux
    x = x + attn_out
    if enc_out is not None:
        hx = L.rmsnorm(x, p["lnx"], cfg.norm_eps)
        B, Se, _ = enc_out.shape
        k = (enc_out @ p["xattn"]["wk"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        v = (enc_out @ p["xattn"]["wv"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        x = x + L.cross_attn_forward(p["xattn"], hx, (k, v), cfg)
    h2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        out, aux = L.moe_forward(p["moe"], h2, cfg)
        return x + out, aux
    return x + L.mlp_forward(p["mlp"], h2, cfg), aux


def _ssm_layer(p, x, cfg: ModelConfig, *, impl: str):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    out, _ = S.mamba_forward(p["mamba"], h, cfg, impl=impl)
    return x + out, jnp.zeros((), jnp.float32)


def _hybrid_layer(p, x, cfg: ModelConfig, *, is_global: bool, impl: str):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    attn_out, _ = L.attn_forward(p["attn"], h, cfg, is_global=is_global, impl=impl)
    ssm_out, _ = S.mamba_forward(p["mamba"], h, cfg, impl="chunked")
    fused = 0.5 * (
        L.rmsnorm(attn_out, p["norm_attn"], cfg.norm_eps)
        + L.rmsnorm(ssm_out, p["norm_ssm"], cfg.norm_eps)
    )
    x = x + fused
    h2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + L.mlp_forward(p["mlp"], h2, cfg), jnp.zeros((), jnp.float32)


def _seg_body(cfg: ModelConfig, seg: Segment, impl: str, mrope_pos=None, enc_out=None):
    def body(carry, lp):
        x, aux = carry
        if seg.kind == "ssm":
            x, a = _ssm_layer(lp, x, cfg, impl=impl)
        elif seg.kind == "hybrid":
            x, a = _hybrid_layer(lp, x, cfg, is_global=seg.is_global, impl=impl)
        else:
            x, a = _attn_layer(
                lp, x, cfg, is_global=seg.is_global, impl=impl,
                mrope_pos=mrope_pos, enc_out=enc_out,
            )
        return (x, aux + a), None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    return body


def _index_tree(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _run_segment(params_seg, seg: Segment, x, aux, cfg: ModelConfig, impl: str,
                 mrope_pos=None, enc_out=None):
    if seg.grouped:
        # gemma3 pattern: scan over groups of [`grouped` local layers + 1 global]
        local_seg = Segment(seg.name, seg.grouped, "attn", False)
        glob_seg = Segment(seg.name, 1, "attn", True)
        local_body = _seg_body(cfg, local_seg, impl, mrope_pos)
        glob_body = _seg_body(cfg, glob_seg, impl, mrope_pos)

        def group_body(carry, gp):
            if cfg.scan_layers:
                carry, _ = jax.lax.scan(local_body, carry, gp["local"])
            else:
                for j in range(seg.grouped):
                    carry, _ = local_body(carry, _index_tree(gp["local"], j))
            carry, _ = glob_body(carry, gp["global"])
            return carry, None

        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(group_body, (x, aux), params_seg)
        else:
            for g in range(seg.count):
                (x, aux), _ = group_body((x, aux), _index_tree(params_seg, g))
        return x, aux
    body = _seg_body(cfg, seg, impl, mrope_pos, enc_out)
    if seg.count == 1:
        (x, aux), _ = body((x, aux), jax.tree.map(lambda a: a[0], params_seg))
    elif not cfg.scan_layers:
        for i in range(seg.count):
            (x, aux), _ = body((x, aux), _index_tree(params_seg, i))
    else:
        (x, aux), _ = jax.lax.scan(body, (x, aux), params_seg)
    return x, aux


# ---------------------------------------------------------------------------
# full forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            *, impl: str = "chunked") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits (B,S,V), aux_loss). ``batch`` keys:

    - tokens (B, S_text) int32 - always present
    - patches / frames (B, n_prefix, d_model) - vlm/audio stub embeddings
    """
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = L.embed_forward(params["embed"], tokens, cfg)
    mrope_pos = None

    if cfg.family == "vlm" and cfg.n_prefix_embeds:
        patches = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        S_total = x.shape[1]
        mrope_pos = L.mrope_positions(B, S_total, cfg.n_prefix_embeds)

    enc_out = None
    if cfg.enc_layers:
        enc_x = batch["frames"].astype(x.dtype)
        enc_seg = Segment("enc", cfg.enc_layers, "attn", True)
        enc_body_cfg = cfg
        # encoder is bidirectional: reuse attn layer with causal disabled via
        # a dedicated body (window=0, causal=False)
        def enc_layer(carry, lp):
            h_in, aux = carry
            h = L.rmsnorm(h_in, lp["ln1"], cfg.norm_eps)
            q, k, v = L._project_qkv(lp["attn"], h, cfg)
            pos = jnp.arange(h.shape[1])
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)
            o = L.attention(q, k, v, impl="chunked", causal=False, window=0)
            o = o.reshape(h.shape[0], h.shape[1], cfg.n_heads * cfg.head_dim)
            h_in = h_in + o @ lp["attn"]["wo"]
            h2 = L.rmsnorm(h_in, lp["ln2"], cfg.norm_eps)
            return (h_in + L.mlp_forward(lp["mlp"], h2, cfg), aux), None

        if cfg.remat == "block":
            enc_layer = jax.checkpoint(enc_layer)
        if cfg.scan_layers:
            (enc_out, _), _ = jax.lax.scan(
                enc_layer, (enc_x, jnp.zeros((), jnp.float32)), params["enc"]
            )
        else:
            carry = (enc_x, jnp.zeros((), jnp.float32))
            for i in range(cfg.enc_layers):
                carry, _ = enc_layer(carry, _index_tree(params["enc"], i))
            enc_out = carry[0]
        enc_out = L.rmsnorm(enc_out, params["enc_norm"], cfg.norm_eps)

    aux = jnp.zeros((), jnp.float32)
    for seg in model_plan(cfg):
        x, aux = _run_segment(
            params["segments"][seg.name], seg, x, aux, cfg, impl, mrope_pos, enc_out
        )

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.logits_forward(params, x, cfg)
    return logits, aux


def loss_fn(params, batch, cfg: ModelConfig, *, impl: str = "chunked"):
    """Next-token CE. Loss positions: text tokens (prefix positions skipped)."""
    logits, aux = forward(params, batch, cfg, impl=impl)
    tokens = batch["tokens"]
    if cfg.family == "vlm" and cfg.n_prefix_embeds:
        logits = logits[:, cfg.n_prefix_embeds :, :]
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32).at[:, -1].set(0.0)
    ce = L.softmax_xent(logits, labels, mask)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode: cache init + single-token step
# ---------------------------------------------------------------------------


def _attn_cache_len(cfg: ModelConfig, is_global: bool, max_len: int) -> int:
    if is_global or not cfg.window:
        return max_len
    return min(cfg.window, max_len)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0,
               dtype=jnp.bfloat16) -> PyTree:
    """Decode cache pytree, mirroring the segment plan."""
    cache: Dict[str, Any] = {}

    def attn_entry(n, is_global):
        Smax = _attn_cache_len(cfg, is_global, max_len)
        shp = (n, batch, Smax, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}

    def ssm_entry(n):
        st = S.mamba_init_state(cfg, batch, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), st)

    for seg in model_plan(cfg):
        if seg.grouped:
            loc = attn_entry(seg.count * seg.grouped, False)
            loc = jax.tree.map(
                lambda a: a.reshape((seg.count, seg.grouped) + a.shape[1:]), loc
            )
            cache[seg.name] = {"local": loc, "global": attn_entry(seg.count, True)}
        elif seg.kind == "ssm":
            cache[seg.name] = ssm_entry(seg.count)
        elif seg.kind == "hybrid":
            cache[seg.name] = {
                "attn": attn_entry(seg.count, seg.is_global),
                "ssm": ssm_entry(seg.count),
            }
        else:
            cache[seg.name] = attn_entry(seg.count, seg.is_global)

    if cfg.enc_layers:
        shp = (cfg.n_layers, batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
        cache["cross"] = {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
    return cache


def _attn_decode_layer(lp, x, lcache, pos, cfg, is_global, cross_kv=None):
    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    out, new_cache = L.attn_decode_forward(
        lp["attn"], h, lcache, pos, cfg, is_global=is_global
    )
    if cfg.parallel_block:
        return x + out + L.mlp_forward(lp["mlp"], h, cfg), new_cache
    x = x + out
    if cross_kv is not None:
        hx = L.rmsnorm(x, lp["lnx"], cfg.norm_eps)
        x = x + L.cross_attn_forward(lp["xattn"], hx, cross_kv, cfg)
    h2 = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        out, _ = L.moe_forward(lp["moe"], h2, cfg)
        return x + out, None if new_cache is None else new_cache
    return x + L.mlp_forward(lp["mlp"], h2, cfg), new_cache


def decode_step(params, cache: PyTree, tokens, pos, cfg: ModelConfig):
    """One decode step. tokens (B,1) int32; pos scalar int32 (current index).

    Returns (logits (B,1,V), new_cache). The cache layout mirrors
    ``init_cache``; each segment scans over its layer stack, threading the
    layer's cache slice through as scan ys (functional update).
    """
    B = tokens.shape[0]
    x = L.embed_forward(params["embed"], tokens, cfg)
    new_cache: Dict[str, Any] = {}

    has_cross = bool(cfg.enc_layers)

    def _scan_or_loop(body, x0, xs, n):
        """lax.scan when scanning layers; unrolled loop (stacking the per-
        layer cache outputs) for the roofline depth-variant pass."""
        if cfg.scan_layers:
            return jax.lax.scan(body, x0, xs)
        outs = []
        x_c = x0
        for i in range(n):
            x_c, y = body(x_c, _index_tree(xs, i))
            outs.append(y)
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *outs)
        return x_c, stacked

    for seg in model_plan(cfg):
        seg_params = params["segments"][seg.name]
        seg_cache = cache[seg.name]

        if seg.grouped:
            def group_body(carry, inp):
                xx = carry
                gp, gc, li = inp
                def local_body(c2, inp2):
                    lp, lc = inp2
                    y, nc = _attn_decode_layer(lp, c2, lc, pos, cfg, False)
                    return y, nc
                xx, loc_new = jax.lax.scan(local_body, xx, (gp["local"], gc["local"]))
                xx, glob_new = _attn_decode_layer(
                    jax.tree.map(lambda a: a, gp["global"]), xx, gc["global"], pos, cfg, True
                )
                return xx, {"local": loc_new, "global": glob_new}

            x, seg_new = _scan_or_loop(
                group_body, x, (seg_params, seg_cache, jnp.arange(seg.count)),
                seg.count,
            )
            new_cache[seg.name] = seg_new
        elif seg.kind == "ssm":
            def ssm_body(xx, inp):
                lp, lst = inp
                h = L.rmsnorm(xx, lp["ln1"], cfg.norm_eps)
                out, nst = S.mamba_decode_forward(lp["mamba"], h, lst, cfg)
                return xx + out, nst

            x, seg_new = _scan_or_loop(ssm_body, x, (seg_params, seg_cache), seg.count)
            new_cache[seg.name] = seg_new
        elif seg.kind == "hybrid":
            def hyb_body(xx, inp):
                lp, lc = inp
                h = L.rmsnorm(xx, lp["ln1"], cfg.norm_eps)
                a_out, nac = L.attn_decode_forward(
                    lp["attn"], h, lc["attn"], pos, cfg, is_global=seg.is_global
                )
                s_out, nsc = S.mamba_decode_forward(lp["mamba"], h, lc["ssm"], cfg)
                fused = 0.5 * (
                    L.rmsnorm(a_out, lp["norm_attn"], cfg.norm_eps)
                    + L.rmsnorm(s_out, lp["norm_ssm"], cfg.norm_eps)
                )
                xx = xx + fused
                h2 = L.rmsnorm(xx, lp["ln2"], cfg.norm_eps)
                return xx + L.mlp_forward(lp["mlp"], h2, cfg), {"attn": nac, "ssm": nsc}

            if seg.count == 1:
                lp1 = jax.tree.map(lambda a: a[0], seg_params)
                lc1 = jax.tree.map(lambda a: a[0], seg_cache)
                x, nc1 = hyb_body(x, (lp1, lc1))
                new_cache[seg.name] = jax.tree.map(lambda a: a[None], nc1)
            else:
                x, seg_new = _scan_or_loop(hyb_body, x, (seg_params, seg_cache), seg.count)
                new_cache[seg.name] = seg_new
        else:
            def attn_body(xx, inp):
                if has_cross:
                    lp, lc, xkv_k, xkv_v = inp
                    y, nc = _attn_decode_layer(
                        lp, xx, lc, pos, cfg, seg.is_global, cross_kv=(xkv_k, xkv_v)
                    )
                else:
                    lp, lc = inp
                    y, nc = _attn_decode_layer(lp, xx, lc, pos, cfg, seg.is_global)
                return y, nc

            if has_cross:
                xs = (seg_params, seg_cache, cache["cross"]["k"], cache["cross"]["v"])
            else:
                xs = (seg_params, seg_cache)
            x, seg_new = _scan_or_loop(attn_body, x, xs, seg.count)
            new_cache[seg.name] = seg_new

    if has_cross:
        new_cache["cross"] = cache["cross"]

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.logits_forward(params, x, cfg)
    return logits, new_cache
