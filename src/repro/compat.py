"""JAX version shim: one import site for APIs that moved between releases.

The repo targets the modern sharding surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.AxisType``) but must also run on the
jax 0.4.x line installed in the offline container, where:

- ``shard_map`` lives in ``jax.experimental.shard_map`` and spells its
  arguments differently (``auto``/``check_rep`` instead of
  ``axis_names``/``check_vma``);
- ``jax.set_mesh`` does not exist - ``Mesh`` itself is the context
  manager that activates the physical mesh;
- ``Mesh``/``jax.make_mesh`` take no ``axis_types`` argument (every axis
  behaves like the later ``AxisType.Auto``).

Everything below is semantics-preserving: on new jax it forwards 1:1, on
old jax it translates. All repo code goes through this module instead of
touching the moved names directly.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = [
    "AxisType",
    "HAS_AXIS_TYPES",
    "make_mesh",
    "mesh_from_devices",
    "set_mesh",
    "shard_map",
]

# ---------------------------------------------------------------------------
# AxisType + mesh construction
# ---------------------------------------------------------------------------

try:  # jax >= 0.5
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_AXIS_TYPES = True
except ImportError:  # jax 0.4.x: every axis is implicitly Auto
    import enum

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    HAS_AXIS_TYPES = False


def _auto_types(n: int) -> Tuple["AxisType", ...]:
    return (AxisType.Auto,) * n


def make_mesh(shape: Sequence[int], axis_names: Sequence[str],
              axis_types: Optional[Tuple] = None) -> Mesh:
    """``jax.make_mesh`` with every axis Auto (GSPMD-managed)."""
    if HAS_AXIS_TYPES:
        return jax.make_mesh(
            tuple(shape), tuple(axis_names),
            axis_types=axis_types or _auto_types(len(tuple(shape))),
        )
    return jax.make_mesh(tuple(shape), tuple(axis_names))


def mesh_from_devices(devices, axis_names: Sequence[str],
                      axis_types: Optional[Tuple] = None) -> Mesh:
    """``Mesh(device_array, names)`` with every axis Auto - used where the
    device placement matters (elastic shrink keeps survivor order)."""
    devices = np.asarray(devices)
    if HAS_AXIS_TYPES:
        return Mesh(
            devices, tuple(axis_names),
            axis_types=axis_types or _auto_types(devices.ndim),
        )
    return Mesh(devices, tuple(axis_names))


# ---------------------------------------------------------------------------
# set_mesh
# ---------------------------------------------------------------------------

if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:

    @contextlib.contextmanager
    def set_mesh(mesh: Mesh):  # type: ignore[no-redef]
        """On 0.4.x the Mesh object is itself the activation context."""
        with mesh:
            yield mesh


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma: bool = False):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, **kw,
        )

else:
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma: bool = False):  # type: ignore[no-redef]
        """Translate to the 0.4.x spelling; ``check_vma`` maps to
        ``check_rep``.

        EVERY mesh axis is made manual, including the axes the caller left
        to GSPMD (``axis_names``'s complement, normally the 'model' axis):
        the 0.4.x partial-``auto`` path is unusable here - ``axis_index``
        lowers to a PartitionId op the SPMD partitioner rejects, and the
        train step trips a CHECK in XLA's manual-subgroup sharding
        propagation. Bodies never reference the model axis by name, so with
        it manual each model shard redundantly computes the same replicated
        result - bit-identical semantics, at a redundant-compute cost that
        only affects the legacy-jax simulation path."""
        return _shard_map_legacy(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=bool(check_vma), auto=frozenset(),
        )
