"""Multi-level asynchronous checkpointing.

Replication raises MTTI so checkpoints can be *less* frequent (the paper's
whole point), but unreplicated failures still need them. Two levels (Moody
et al.'s multi-level scheme, adapted):

- level 1 ``partner``: in-memory copy held by a partner slice's host -
  O(memcpy), survives single-slice loss, lost on job teardown;
- level 2 ``durable``: serialized npz + json manifest, atomic rename,
  written by a background thread so the train loop never blocks on I/O.

Restore prefers the newest level containing the wanted step and handles
world-size changes (state is replicated over the data axis, so elastic
restores simply re-place it onto the shrunk mesh).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[path] = np.asarray(leaf)
    return out


def _unflatten_like(template: PyTree, arrays: Dict[str, np.ndarray]) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        arr = arrays[path]
        leaves.append(np.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclass
class PartnerStore:
    """Level-1 partner-memory checkpoints: slice -> (step, state)."""

    _store: Dict[int, Tuple[int, Dict[str, np.ndarray], Dict]] = field(
        default_factory=dict
    )
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def save(self, partner: int, step: int, state: PyTree, meta: Dict) -> None:
        blob = _flatten_with_paths(state)
        with self._lock:
            self._store[partner] = (step, blob, dict(meta))

    def restore(self, partner: int, template: PyTree) -> Optional[Tuple[int, PyTree, Dict]]:
        with self._lock:
            if partner not in self._store:
                return None
            step, blob, meta = self._store[partner]
        return step, _unflatten_like(template, blob), meta

    def latest_step(self) -> int:
        with self._lock:
            return max((s for s, _, _ in self._store.values()), default=-1)

    def drop(self, partner: int) -> None:
        with self._lock:
            self._store.pop(partner, None)


@dataclass
class Checkpointer:
    """Level-2 durable checkpoints (npz + manifest, async, atomic)."""

    directory: str
    keep: int = 2
    _thread: Optional[threading.Thread] = None

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # ---- save ----------------------------------------------------------------
    def save(self, step: int, state: PyTree, meta: Optional[Dict] = None) -> str:
        """Synchronous durable save. Returns the checkpoint path."""
        blob = _flatten_with_paths(state)
        tmp = os.path.join(self.directory, f".tmp-{step}")
        final = os.path.join(self.directory, f"step-{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "state.npz"), **blob)
        manifest = {
            "step": step,
            "time": time.time(),
            "meta": meta or {},
            "leaves": len(blob),
            "bytes": int(sum(a.nbytes for a in blob.values())),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def save_async(self, step: int, state: PyTree, meta: Optional[Dict] = None):
        """Background save; snapshots to host memory synchronously (cheap),
        writes to disk off-thread. Returns the thread."""
        self.wait()
        blob = _flatten_with_paths(state)  # snapshot before params mutate

        def _write():
            tmp = os.path.join(self.directory, f".tmp-{step}")
            final = os.path.join(self.directory, f"step-{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "state.npz"), **blob)
            manifest = {
                "step": step,
                "time": time.time(),
                "meta": meta or {},
                "leaves": len(blob),
                "bytes": int(sum(a.nbytes for a in blob.values())),
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        return self._thread

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    # ---- restore ---------------------------------------------------------------
    def list_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step-"):
                steps.append(int(name.split("-")[1]))
        return sorted(steps)

    def restore(self, template: PyTree, step: Optional[int] = None
                ) -> Optional[Tuple[int, PyTree, Dict]]:
        self.wait()
        steps = self.list_steps()
        if not steps:
            return None
        step = steps[-1] if step is None else step
        path = os.path.join(self.directory, f"step-{step:010d}")
        with np.load(os.path.join(path, "state.npz")) as z:
            blob = {k: z[k] for k in z.files}
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        return step, _unflatten_like(template, blob), manifest.get("meta", {})

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step-{s:010d}"), ignore_errors=True)
