"""Deterministic, seekable data pipeline with replica mirroring.

Determinism is the foundation of the paper's replication model: a replica
"performs the same operations in the same order on the same inputs". Every
sample is generated from a counter-based RNG keyed by
``(seed, step, cmp_role)`` - so any slice can (re)produce any shard at any
step, which gives us:

- replica mirroring: replica roles consume ``topo.mirror_source()`` shards;
- replay after repair: re-request (step, role) - no data loss possible;
- elastic restart: a shrunk world re-keys shards by the new role ids.

Offline container => synthetic token streams (Zipf-ish) + synthetic
patch/frame embeddings for the stubbed VLM/audio frontends. The interface
(``global_batch(step, world)``) is what a production loader (e.g. array
-record + index shuffle) would implement; determinism keyed the same way.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.replication import WorldState

Batch = Dict[str, np.ndarray]


def _rng_for(seed: int, step: int, role: int) -> np.random.Generator:
    return np.random.default_rng(np.random.Philox(key=(seed << 32) ^ (step * 1_000_003 + role)))


@dataclass
class TokenPipeline:
    model: ModelConfig
    seq_len: int
    per_slice_batch: int
    seed: int = 0

    # ---- shard generation ---------------------------------------------------
    def shard(self, step: int, cmp_role: int) -> Batch:
        """The microbatch computational role ``cmp_role`` consumes at
        ``step``. Pure function of (seed, step, role)."""
        from repro.configs.base import ShapeConfig
        from repro.launch.specs import seq_layout

        rng = _rng_for(self.seed, step, cmp_role)
        V = self.model.vocab_size
        layout = seq_layout(
            self.model, ShapeConfig("adhoc", self.seq_len, 1, "train")
        )
        # Zipf-ish marginal over the vocab: realistic token frequency skew
        z = rng.zipf(1.3, size=(self.per_slice_batch, layout["text"])).astype(np.int64)
        tokens = np.minimum(z - 1, V - 1).astype(np.int32)
        batch: Batch = {"tokens": tokens}
        if "patches" in layout:
            batch["patches"] = rng.standard_normal(
                (self.per_slice_batch, layout["patches"], self.model.d_model),
                dtype=np.float32,
            )
        if "frames" in layout:
            batch["frames"] = rng.standard_normal(
                (self.per_slice_batch, layout["frames"], self.model.d_model),
                dtype=np.float32,
            )
        return batch

    def sample_range(self, step: int, cmp_role: int) -> tuple:
        """Global sample-id range of this shard (for the step log)."""
        n_comp_guess = 1  # ranges are informational; ids are (step, role, i)
        base = step * 1_000_000 + cmp_role * self.per_slice_batch
        return (base, base + self.per_slice_batch)

    # ---- replica-aware global batch ------------------------------------------
    def global_batch(self, step: int, world: WorldState) -> Batch:
        """Global arrays laid out in mesh order; replica slices receive a
        copy of their partner's shard (paper: same inputs)."""
        topo = world.topo
        shards = {c: self.shard(step, c) for c in topo.cmp_roles()}
        src = topo.mirror_source()  # role -> cmp role whose shard it gets
        roles_in_order = world.roles_in_mesh_order()
        keys = shards[0].keys()
        out: Batch = {}
        for k in keys:
            out[k] = np.concatenate(
                [shards[src[r]][k] for r in roles_in_order], axis=0
            )
        return out
