"""Replica topology - PartRePer-MPI's six communicators on a TPU mesh.

An MPI *process* maps to a model-parallel *slice*: one index along the
flattened (pod, data) mesh axes, owning a full copy of the (model-sharded)
training state. Slices are partitioned into ``nComp`` computational and
``nRep`` replica slices; replica role ``nComp + j`` mirrors computational
role ``replica_map[j]`` (same microbatch, same ops -> bit-identical state).

The paper's communicators become ``axis_index_groups`` partitions of the
flattened slice space (paper Sec. V):

- ``COMM_CMP``              -> ``comm_cmp_groups()``
- ``COMM_REP``              -> ``comm_rep_groups()``
- ``CMP_REP_INTERCOMM``     -> ``intercomm_perm()`` (ppermute pairs)
- ``CMP_NO_REP``            -> ``cmp_no_rep()``
- ``CMP_NO_REP_INTERCOMM``  -> pairs from ``cmp_no_rep()`` (P2P mini-apps)
- world (eworldComm)        -> the full axis

``WorldState`` is the failure-management view (paper Sec. VI): physical
slices die, roles are re-assigned ("the newly shrunk communicator has its
processes shuffled such that the replica now becomes the computational
process"), and the groups are regenerated over the surviving slices.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.heal.plan import HealAction, HealPlan


def split_comp_rep(n_slices: int, rdegree: float) -> Tuple[int, int]:
    """Partition a fixed pool of slices into computational + replicas.

    ``nRep ~= rdegree * nComp`` with ``nComp + nRep == n_slices``. The paper
    adds replicas on top of a fixed computational count; on a fixed mesh the
    replicas are carved out of the pool (the classic <=50%-efficiency
    trade-off of dual redundancy, Stearley et al.).
    """
    if rdegree <= 0:
        return n_slices, 0
    n_comp = max(1, round(n_slices / (1.0 + rdegree)))
    n_rep = min(n_slices - n_comp, n_comp)  # at most one replica per cmp
    return n_slices - n_rep, n_rep


@dataclass(frozen=True)
class ReplicaTopology:
    """Replica layout over ``n_comp + len(replica_map)`` slice roles.

    Roles ``0..n_comp-1`` are computational; replica role ``n_comp + j``
    mirrors computational role ``replica_map[j]``.
    """

    n_comp: int
    replica_map: Tuple[int, ...] = ()

    @classmethod
    def create(cls, n_slices: int, rdegree: float) -> "ReplicaTopology":
        n_comp, n_rep = split_comp_rep(n_slices, rdegree)
        return cls(n_comp=n_comp, replica_map=tuple(range(n_rep)))

    @property
    def n_rep(self) -> int:
        return len(self.replica_map)

    @property
    def n_slices(self) -> int:
        return self.n_comp + self.n_rep

    @property
    def rdegree(self) -> float:
        return self.n_rep / self.n_comp if self.n_comp else 0.0

    def replica_of(self, rep_role: int) -> int:
        return self.replica_map[rep_role - self.n_comp]

    def partner_of(self, cmp_role: int) -> Optional[int]:
        try:
            return self.n_comp + self.replica_map.index(cmp_role)
        except ValueError:
            return None

    # ---- the six communicators -------------------------------------------
    def cmp_roles(self) -> List[int]:
        return list(range(self.n_comp))

    def rep_roles(self) -> List[int]:
        return list(range(self.n_comp, self.n_slices))

    def cmp_no_rep(self) -> List[int]:
        with_rep = set(self.replica_map)
        return [c for c in self.cmp_roles() if c not in with_rep]

    def comm_cmp_groups(self) -> List[List[int]]:
        """axis_index_groups for a COMM_CMP collective. XLA replica groups
        must partition the axis, so replicas form an inert group whose
        (concurrent, off-critical-path) reduction result is discarded."""
        groups = [self.cmp_roles()]
        if self.n_rep:
            groups.append(self.rep_roles())
        return groups

    def comm_rep_groups(self) -> List[List[int]]:
        if not self.n_rep:
            return [self.cmp_roles()]
        return [self.rep_roles(), self.cmp_roles()]

    def pair_groups(self) -> List[List[int]]:
        """Mirror-pair partition ([cmp, rep] pairs + singletons): used by the
        RedMPI-style SDC gradient cross-check."""
        groups = []
        for c in self.cmp_roles():
            r = self.partner_of(c)
            groups.append([c, r] if r is not None else [c])
        return groups

    def intercomm_perm(self) -> List[Tuple[int, int]]:
        """CMP_REP_INTERCOMM as ppermute (src, dst) pairs: cmp -> its rep."""
        return [(self.replica_map[j], self.n_comp + j) for j in range(self.n_rep)]

    def mirror_source(self) -> List[int]:
        """role -> role whose data shard it consumes (identity for cmp roles,
        the mirrored cmp role for replicas). Drives microbatch mirroring in
        the data pipeline."""
        return self.cmp_roles() + list(self.replica_map)

    def is_rep_mask(self) -> List[bool]:
        return [False] * self.n_comp + [True] * self.n_rep

    def validate(self) -> None:
        assert self.n_comp > 0
        assert len(set(self.replica_map)) == len(self.replica_map)
        assert all(0 <= c < self.n_comp for c in self.replica_map)
        flat = sorted(i for g in self.comm_cmp_groups() for i in g)
        assert flat == list(range(self.n_slices)), "groups must partition"


# ---------------------------------------------------------------------------
# failure-management view (paper Sec. VI-A "Repairing the World")
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WorldState:
    """``assignment[role] = physical slice id`` over the original mesh.

    ``generation`` is the ULFM-revocation analogue: every repair bumps it,
    and hosts abort dispatch loops whose generation is stale.

    Beyond the role-holding slices, the world tracks two re-replication
    bookkeeping sets (the ``repro.heal`` plane):

    - ``spares``: live physical slices holding NO cmp/rep role - the warm
      standby pool (reserved at job launch via ``n_spares``, FTHP-MPI's
      spare processes; repair may also orphan a replica into it). Spares
      sit outside the shrunk mesh until a heal converts them.
    - ``exposed``: ``(cmp_role, generation)`` pairs recording when a role
      LOST its mirror (promote consumed it, or the replica died) - the
      most-exposed-first ordering key for :meth:`heal`. Roles unmirrored
      by the initial rdegree split are not exposure-eroded and are healed
      last.

    ``target_rdegree`` is the configured replication degree the heal plane
    restores toward; healing never pushes ``n_rep`` above
    ``target_n_rep``.
    """

    n_physical: int
    topo: ReplicaTopology
    assignment: Tuple[int, ...]
    dead: FrozenSet[int] = frozenset()
    generation: int = 0
    spares: Tuple[int, ...] = ()
    exposed: Tuple[Tuple[int, int], ...] = ()
    target_rdegree: float = 0.0

    @classmethod
    def create(cls, n_slices: int, rdegree: float, *, n_spares: int = 0) -> "WorldState":
        assert 0 <= n_spares < n_slices, (n_slices, n_spares)
        topo = ReplicaTopology.create(n_slices - n_spares, rdegree)
        # store the ACHIEVED split ratio, so target_n_rep == n_rep exactly
        # at creation (the requested rdegree may not be integer-realizable)
        return cls(
            n_physical=n_slices,
            topo=topo,
            assignment=tuple(range(topo.n_slices)),
            spares=tuple(range(topo.n_slices, n_slices)),
            target_rdegree=topo.rdegree,
        )

    @property
    def n_live(self) -> int:
        return len(self.assignment)

    @property
    def target_n_rep(self) -> int:
        """Replica count the configured rdegree implies for the CURRENT
        computational width (shrinks with the world after lost roles)."""
        return min(self.topo.n_comp, int(round(self.target_rdegree * self.topo.n_comp)))

    def replica_deficit(self) -> int:
        """How many mirrors below target the world is running (the
        time-at-risk unit: deficit x steps = exposure)."""
        return max(0, self.target_n_rep - self.topo.n_rep)

    def physical_of(self, role: int) -> int:
        return self.assignment[role]

    def role_of_physical(self, phys: int) -> Optional[int]:
        try:
            return self.assignment.index(phys)
        except ValueError:
            return None

    def repair(self, failed_physical: Sequence[int], *,
               use_spares: bool = True) -> Tuple["WorldState", Dict]:
        """Shrink + promote (+ spare backfill). Returns (new_world, report).

        - failed replica                  -> dropped (its cmp role is now
          *exposed*: recorded for most-exposed-first healing)
        - failed cmp with live replica    -> replica promoted into the role
          (the promoted role is exposed too - its mirror was consumed)
        - failed cmp without replica      -> with ``use_spares`` and a spare
          available, the spare *backfills* the role (``backfilled``): role
          ids and the computational width are preserved, so a ladder
          restore + replay reproduces the failure-free trajectory; without
          a spare it is ``lost_cmp`` (checkpoint/restart + elastic shrink
          are the caller's job; the role is removed here)
        - failed spare                    -> removed from the pool
        - a live replica whose target role vanished is orphaned back into
          the spare pool rather than dropped from the world

        ``report["role_map"]`` maps new cmp role ids -> old cmp role ids
        (identity unless a lost role forced renumbering) - consumers that
        carry per-role state across the shrink (e.g. the serving cache
        repack) use it to find each surviving role's old rows.
        """
        topo = self.topo
        dead = set(self.dead) | set(failed_physical)
        report: Dict = {"promoted": [], "dropped_reps": [], "lost_cmp": [],
                        "backfilled": [], "dead_spares": [], "orphaned": [],
                        "generation": self.generation + 1}
        gen = self.generation + 1
        exposed: Dict[int, int] = dict(self.exposed)

        spares = [s for s in self.spares if s not in dead]
        report["dead_spares"] = sorted(set(self.spares) - set(spares))

        # cmp role -> physical ; cmp role -> its replica's physical
        cmp_phys: Dict[int, int] = {
            c: self.assignment[c] for c in topo.cmp_roles()
        }
        rep_phys: Dict[int, int] = {
            topo.replica_map[j]: self.assignment[topo.n_comp + j]
            for j in range(topo.n_rep)
        }

        # drop dead replicas first (paper: "simply dropped")
        for c in list(rep_phys):
            if rep_phys[c] in dead:
                report["dropped_reps"].append(c)
                exposed.setdefault(c, gen)
                del rep_phys[c]

        # handle dead computational roles
        for c in list(cmp_phys):
            if cmp_phys[c] in dead:
                if c in rep_phys:
                    cmp_phys[c] = rep_phys.pop(c)  # promote
                    report["promoted"].append((c, cmp_phys[c]))
                    exposed.setdefault(c, gen)
                elif use_spares and spares:
                    # spare backfill: the role survives on a standby slice;
                    # its state is the caller's restore walk (like lost_cmp)
                    # but the computational width never shrinks
                    cmp_phys[c] = spares.pop(0)
                    report["backfilled"].append((c, cmp_phys[c]))
                else:
                    report["lost_cmp"].append(c)
                    del cmp_phys[c]

        # renumber surviving cmp roles densely, preserving order
        survivors = sorted(cmp_phys)
        renumber = {old: new for new, old in enumerate(survivors)}
        report["role_map"] = {new: old for old, new in renumber.items()}
        report["backfilled"] = [
            (renumber[c], p) for c, p in report["backfilled"]
        ]
        new_cmp_assign = [cmp_phys[c] for c in survivors]
        new_pairs = []
        for c, p in rep_phys.items():
            if c in renumber:
                new_pairs.append((renumber[c], p))
            else:  # its cmp role was lost: the live replica becomes a spare
                report["orphaned"].append(p)
                spares.append(p)
        new_pairs.sort()
        new_topo = ReplicaTopology(
            n_comp=len(new_cmp_assign),
            replica_map=tuple(c for c, _ in new_pairs),
        )
        new_world = WorldState(
            n_physical=self.n_physical,
            topo=new_topo,
            assignment=tuple(new_cmp_assign + [p for _, p in new_pairs]),
            dead=frozenset(dead),
            generation=gen,
            spares=tuple(sorted(spares)),
            exposed=tuple(sorted(
                (renumber[c], g) for c, g in exposed.items() if c in renumber
            )),
            target_rdegree=self.target_rdegree,
        )
        return new_world, report

    # ---- re-replication (the repro.heal plane) -----------------------------
    def unmirrored_cmp_roles(self) -> List[int]:
        """Cmp roles without a replica, most-exposed-first: roles that LOST
        a mirror come first (earliest exposure generation wins, role id
        tie-breaks - stable under repeated failures), then roles unmirrored
        by the initial split, in role order."""
        mirrored = set(self.topo.replica_map)
        since = dict(self.exposed)
        bare = [c for c in self.topo.cmp_roles() if c not in mirrored]
        return sorted(bare, key=lambda c: (since.get(c, 1 << 30), c))

    def heal(self, max_new: Optional[int] = None) -> Tuple["WorldState", HealPlan]:
        """Convert spares into replicas of unmirrored computational roles,
        most-exposed-first, until the configured target rdegree is met (or
        spares run out). Pure topology transition - the state motion (the
        3-phase live clone) and store re-registration are the Healer's job.

        The generation is NOT bumped: heals execute inside a recovery
        window whose repair already revoked + bumped, and the single
        re-lower that follows compiles the healed topology.
        """
        deficit = self.replica_deficit()
        plan = HealPlan(generation=self.generation, deficit_before=deficit,
                        deficit_after=deficit)
        budget = min(len(self.spares), deficit)
        if max_new is not None:
            budget = min(budget, max_new)
        if budget <= 0:
            return self, plan

        since = dict(self.exposed)
        targets = self.unmirrored_cmp_roles()[:budget]
        spares = list(self.spares)  # sorted ascending: lowest spare first
        plan.actions = [
            HealAction(cmp_role=c, spare=spares.pop(0),
                       exposed_since=since.get(c, -1))
            for c in targets
        ]

        n_comp = self.topo.n_comp
        pairs = [
            (self.topo.replica_map[j], self.assignment[n_comp + j])
            for j in range(self.topo.n_rep)
        ] + [(a.cmp_role, a.spare) for a in plan.actions]
        pairs.sort()
        healed = WorldState(
            n_physical=self.n_physical,
            topo=ReplicaTopology(
                n_comp=n_comp, replica_map=tuple(c for c, _ in pairs)
            ),
            assignment=tuple(list(self.assignment[:n_comp])
                             + [p for _, p in pairs]),
            dead=self.dead,
            generation=self.generation,
            spares=tuple(sorted(spares)),
            exposed=tuple(sorted(
                (c, g) for c, g in self.exposed if c not in set(targets)
            )),
            target_rdegree=self.target_rdegree,
        )
        plan.deficit_after = healed.replica_deficit()
        return healed, plan

    def validate(self) -> None:
        """World-level invariants (topology invariants via topo.validate):
        role<->physical stays a bijection, spares/dead/assignment are
        pairwise disjoint, and healing never overshot the target."""
        self.topo.validate()
        assert len(set(self.assignment)) == len(self.assignment)
        live = set(self.assignment)
        assert not live & set(self.dead), "dead physical still holds a role"
        assert not live & set(self.spares), "spare physical holds a role"
        assert not set(self.spares) & set(self.dead), "dead spare retained"
        assert len(set(self.spares)) == len(self.spares)
        mirrored = set(self.topo.replica_map)
        for c, g in self.exposed:
            assert 0 <= c < self.topo.n_comp and c not in mirrored

    # ---- mesh-space group translation -------------------------------------
    def live_physicals(self) -> List[int]:
        return sorted(self.assignment)

    def mesh_position(self) -> Dict[int, int]:
        """physical id -> dense position in the rebuilt (shrunk) mesh."""
        return {p: i for i, p in enumerate(self.live_physicals())}

    def roles_in_mesh_order(self) -> List[int]:
        """mesh position -> role (inverse of assignment under renumbering)."""
        pos = self.mesh_position()
        out = [-1] * self.n_live
        for role, phys in enumerate(self.assignment):
            out[pos[phys]] = role
        return out

    def physical_groups(self, role_groups: List[List[int]]) -> List[List[int]]:
        pos = self.mesh_position()
        return [[pos[self.assignment[r]] for r in g] for g in role_groups]

    def physical_perm(self, role_pairs: Sequence[Tuple[int, int]]) -> List[Tuple[int, int]]:
        pos = self.mesh_position()
        return [
            (pos[self.assignment[a]], pos[self.assignment[b]]) for a, b in role_pairs
        ]
