"""Replica topology - PartRePer-MPI's six communicators on a TPU mesh.

An MPI *process* maps to a model-parallel *slice*: one index along the
flattened (pod, data) mesh axes, owning a full copy of the (model-sharded)
training state. Slices are partitioned into ``nComp`` computational and
``nRep`` replica slices; replica role ``nComp + j`` mirrors computational
role ``replica_map[j]`` (same microbatch, same ops -> bit-identical state).

The paper's communicators become ``axis_index_groups`` partitions of the
flattened slice space (paper Sec. V):

- ``COMM_CMP``              -> ``comm_cmp_groups()``
- ``COMM_REP``              -> ``comm_rep_groups()``
- ``CMP_REP_INTERCOMM``     -> ``intercomm_perm()`` (ppermute pairs)
- ``CMP_NO_REP``            -> ``cmp_no_rep()``
- ``CMP_NO_REP_INTERCOMM``  -> pairs from ``cmp_no_rep()`` (P2P mini-apps)
- world (eworldComm)        -> the full axis

``WorldState`` is the failure-management view (paper Sec. VI): physical
slices die, roles are re-assigned ("the newly shrunk communicator has its
processes shuffled such that the replica now becomes the computational
process"), and the groups are regenerated over the surviving slices.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple


def split_comp_rep(n_slices: int, rdegree: float) -> Tuple[int, int]:
    """Partition a fixed pool of slices into computational + replicas.

    ``nRep ~= rdegree * nComp`` with ``nComp + nRep == n_slices``. The paper
    adds replicas on top of a fixed computational count; on a fixed mesh the
    replicas are carved out of the pool (the classic <=50%-efficiency
    trade-off of dual redundancy, Stearley et al.).
    """
    if rdegree <= 0:
        return n_slices, 0
    n_comp = max(1, round(n_slices / (1.0 + rdegree)))
    n_rep = min(n_slices - n_comp, n_comp)  # at most one replica per cmp
    return n_slices - n_rep, n_rep


@dataclass(frozen=True)
class ReplicaTopology:
    """Replica layout over ``n_comp + len(replica_map)`` slice roles.

    Roles ``0..n_comp-1`` are computational; replica role ``n_comp + j``
    mirrors computational role ``replica_map[j]``.
    """

    n_comp: int
    replica_map: Tuple[int, ...] = ()

    @classmethod
    def create(cls, n_slices: int, rdegree: float) -> "ReplicaTopology":
        n_comp, n_rep = split_comp_rep(n_slices, rdegree)
        return cls(n_comp=n_comp, replica_map=tuple(range(n_rep)))

    @property
    def n_rep(self) -> int:
        return len(self.replica_map)

    @property
    def n_slices(self) -> int:
        return self.n_comp + self.n_rep

    @property
    def rdegree(self) -> float:
        return self.n_rep / self.n_comp if self.n_comp else 0.0

    def replica_of(self, rep_role: int) -> int:
        return self.replica_map[rep_role - self.n_comp]

    def partner_of(self, cmp_role: int) -> Optional[int]:
        try:
            return self.n_comp + self.replica_map.index(cmp_role)
        except ValueError:
            return None

    # ---- the six communicators -------------------------------------------
    def cmp_roles(self) -> List[int]:
        return list(range(self.n_comp))

    def rep_roles(self) -> List[int]:
        return list(range(self.n_comp, self.n_slices))

    def cmp_no_rep(self) -> List[int]:
        with_rep = set(self.replica_map)
        return [c for c in self.cmp_roles() if c not in with_rep]

    def comm_cmp_groups(self) -> List[List[int]]:
        """axis_index_groups for a COMM_CMP collective. XLA replica groups
        must partition the axis, so replicas form an inert group whose
        (concurrent, off-critical-path) reduction result is discarded."""
        groups = [self.cmp_roles()]
        if self.n_rep:
            groups.append(self.rep_roles())
        return groups

    def comm_rep_groups(self) -> List[List[int]]:
        if not self.n_rep:
            return [self.cmp_roles()]
        return [self.rep_roles(), self.cmp_roles()]

    def pair_groups(self) -> List[List[int]]:
        """Mirror-pair partition ([cmp, rep] pairs + singletons): used by the
        RedMPI-style SDC gradient cross-check."""
        groups = []
        for c in self.cmp_roles():
            r = self.partner_of(c)
            groups.append([c, r] if r is not None else [c])
        return groups

    def intercomm_perm(self) -> List[Tuple[int, int]]:
        """CMP_REP_INTERCOMM as ppermute (src, dst) pairs: cmp -> its rep."""
        return [(self.replica_map[j], self.n_comp + j) for j in range(self.n_rep)]

    def mirror_source(self) -> List[int]:
        """role -> role whose data shard it consumes (identity for cmp roles,
        the mirrored cmp role for replicas). Drives microbatch mirroring in
        the data pipeline."""
        return self.cmp_roles() + list(self.replica_map)

    def is_rep_mask(self) -> List[bool]:
        return [False] * self.n_comp + [True] * self.n_rep

    def validate(self) -> None:
        assert self.n_comp > 0
        assert len(set(self.replica_map)) == len(self.replica_map)
        assert all(0 <= c < self.n_comp for c in self.replica_map)
        flat = sorted(i for g in self.comm_cmp_groups() for i in g)
        assert flat == list(range(self.n_slices)), "groups must partition"


# ---------------------------------------------------------------------------
# failure-management view (paper Sec. VI-A "Repairing the World")
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WorldState:
    """``assignment[role] = physical slice id`` over the original mesh.

    ``generation`` is the ULFM-revocation analogue: every repair bumps it,
    and hosts abort dispatch loops whose generation is stale.
    """

    n_physical: int
    topo: ReplicaTopology
    assignment: Tuple[int, ...]
    dead: FrozenSet[int] = frozenset()
    generation: int = 0

    @classmethod
    def create(cls, n_slices: int, rdegree: float) -> "WorldState":
        topo = ReplicaTopology.create(n_slices, rdegree)
        return cls(
            n_physical=n_slices,
            topo=topo,
            assignment=tuple(range(topo.n_slices)),
        )

    @property
    def n_live(self) -> int:
        return len(self.assignment)

    def physical_of(self, role: int) -> int:
        return self.assignment[role]

    def role_of_physical(self, phys: int) -> Optional[int]:
        try:
            return self.assignment.index(phys)
        except ValueError:
            return None

    def repair(self, failed_physical: Sequence[int]) -> Tuple["WorldState", Dict]:
        """Shrink + promote. Returns (new_world, report).

        - failed replica                  -> dropped
        - failed cmp with live replica    -> replica promoted into the role
        - failed cmp without replica      -> ``lost_cmp`` (checkpoint/restart
          + elastic shrink are the caller's job; the role is removed here)
        """
        topo = self.topo
        dead = set(self.dead) | set(failed_physical)
        report: Dict = {"promoted": [], "dropped_reps": [], "lost_cmp": [],
                        "generation": self.generation + 1}

        # cmp role -> physical ; cmp role -> its replica's physical
        cmp_phys: Dict[int, int] = {
            c: self.assignment[c] for c in topo.cmp_roles()
        }
        rep_phys: Dict[int, int] = {
            topo.replica_map[j]: self.assignment[topo.n_comp + j]
            for j in range(topo.n_rep)
        }

        # drop dead replicas first (paper: "simply dropped")
        for c in list(rep_phys):
            if rep_phys[c] in dead:
                report["dropped_reps"].append(c)
                del rep_phys[c]

        # handle dead computational roles
        for c in list(cmp_phys):
            if cmp_phys[c] in dead:
                if c in rep_phys:
                    cmp_phys[c] = rep_phys.pop(c)  # promote
                    report["promoted"].append((c, cmp_phys[c]))
                else:
                    report["lost_cmp"].append(c)
                    del cmp_phys[c]

        # renumber surviving cmp roles densely, preserving order
        survivors = sorted(cmp_phys)
        renumber = {old: new for new, old in enumerate(survivors)}
        new_cmp_assign = [cmp_phys[c] for c in survivors]
        new_pairs = sorted(
            (renumber[c], p) for c, p in rep_phys.items() if c in renumber
        )
        new_topo = ReplicaTopology(
            n_comp=len(new_cmp_assign),
            replica_map=tuple(c for c, _ in new_pairs),
        )
        new_world = WorldState(
            n_physical=self.n_physical,
            topo=new_topo,
            assignment=tuple(new_cmp_assign + [p for _, p in new_pairs]),
            dead=frozenset(dead),
            generation=self.generation + 1,
        )
        return new_world, report

    # ---- mesh-space group translation -------------------------------------
    def live_physicals(self) -> List[int]:
        return sorted(self.assignment)

    def mesh_position(self) -> Dict[int, int]:
        """physical id -> dense position in the rebuilt (shrunk) mesh."""
        return {p: i for i, p in enumerate(self.live_physicals())}

    def roles_in_mesh_order(self) -> List[int]:
        """mesh position -> role (inverse of assignment under renumbering)."""
        pos = self.mesh_position()
        out = [-1] * self.n_live
        for role, phys in enumerate(self.assignment):
            out[pos[phys]] = role
        return out

    def physical_groups(self, role_groups: List[List[int]]) -> List[List[int]]:
        pos = self.mesh_position()
        return [[pos[self.assignment[r]] for r in g] for g in role_groups]

    def physical_perm(self, role_pairs: Sequence[Tuple[int, int]]) -> List[Tuple[int, int]]:
        pos = self.mesh_position()
        return [
            (pos[self.assignment[a]], pos[self.assignment[b]]) for a, b in role_pairs
        ]
