"""MTTI modelling for partial replication + checkpoint-interval optimisation.

The paper's Fig. 9(b) shows MTTI vs replication degree under Weibull
failures. This module provides:

- ``mtti_montecarlo``: MTTI of the *application* (interrupted when an
  unreplicated computational slice fails, or both members of a mirror pair
  have failed) under Weibull per-event system failures - matches the
  paper's injector semantics;
- ``mtti_exponential``: closed-form for shape=1 via expected number of
  system failures to interruption;
- ``daly_interval``: Young/Daly optimal checkpoint interval given the
  replication-stretched MTTI - the paper's motivation ("allow for longer
  checkpoint intervals");
- ``efficiency``: end-to-end useful-work fraction combining replica
  resource cost, rework, and checkpoint overhead - quantifies when partial
  replication pays off (Stearley et al.'s question).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

from repro.core.replication import ReplicaTopology


def _interrupted(topo: ReplicaTopology, dead_roles: set) -> bool:
    """Application is interrupted when a computational role is dead and its
    replica (if any) is dead too."""
    for c in range(topo.n_comp):
        r = topo.partner_of(c)
        if c in dead_roles and (r is None or r in dead_roles):
            return True
    return False


def expected_failures_to_interruption(topo: ReplicaTopology, trials: int = 2000,
                                      seed: int = 0) -> float:
    """E[# of uniform-random slice failures until the app is interrupted]."""
    rng = np.random.default_rng(seed)
    n = topo.n_slices
    counts = []
    for _ in range(trials):
        order = rng.permutation(n)
        dead: set = set()
        for k, v in enumerate(order, start=1):
            dead.add(int(v))
            if _interrupted(topo, dead):
                counts.append(k)
                break
    return float(np.mean(counts))


def mtti_montecarlo(topo: ReplicaTopology, system_scale: float,
                    shape: float = 0.7, trials: int = 2000, seed: int = 0) -> float:
    """MTTI under Weibull inter-failure times of the whole system.

    Inter-failure gaps are iid Weibull(shape, scale=system_scale); each
    failure kills a uniformly-random live slice (the paper's injector).
    """
    rng = np.random.default_rng(seed)
    times = []
    n = topo.n_slices
    for _ in range(trials):
        t = 0.0
        dead: set = set()
        alive = list(range(n))
        while True:
            t += system_scale * rng.weibull(shape)
            v = alive[rng.integers(len(alive))]
            alive.remove(v)
            dead.add(v)
            if _interrupted(topo, dead):
                times.append(t)
                break
    return float(np.mean(times))


def mtti_exponential(topo: ReplicaTopology, system_mtbf: float,
                     trials: int = 2000, seed: int = 0) -> float:
    """Closed-form-ish MTTI for exponential failures: E[failures] * MTBF."""
    return expected_failures_to_interruption(topo, trials, seed) * system_mtbf


def daly_interval(mtti: float, checkpoint_cost: float) -> float:
    """Young/Daly optimal checkpoint interval tau = sqrt(2 delta M) - delta."""
    if mtti <= 2 * checkpoint_cost:
        return checkpoint_cost
    return float(np.sqrt(2 * checkpoint_cost * mtti) - checkpoint_cost)


def efficiency(topo: ReplicaTopology, system_mtbf: float, checkpoint_cost: float,
               restart_cost: float, shape: float = 0.7,
               trials: int = 1000, seed: int = 0) -> Dict[str, float]:
    """Useful-work fraction of the whole allocation under failures.

    - resource factor: nComp / nSlices (replicas consume chips)
    - checkpoint factor: tau / (tau + delta) with Daly tau from the
      replication-stretched MTTI
    - rework factor: on each interruption ~tau/2 + restart lost
    """
    mtti = mtti_montecarlo(topo, system_mtbf, shape, trials, seed)
    tau = daly_interval(mtti, checkpoint_cost)
    resource = topo.n_comp / topo.n_slices
    ckpt = tau / (tau + checkpoint_cost)
    rework = mtti / (mtti + tau / 2.0 + restart_cost)
    eff = resource * ckpt * rework
    return {
        "mtti": mtti,
        "tau_opt": tau,
        "resource_factor": resource,
        "checkpoint_factor": ckpt,
        "rework_factor": rework,
        "efficiency": eff,
    }
