"""MTTI modelling for partial replication + checkpoint-interval optimisation.

The paper's Fig. 9(b) shows MTTI vs replication degree under Weibull
failures. This module provides:

- ``mtti_montecarlo``: MTTI of the *application* (interrupted when an
  unreplicated computational slice fails, or both members of a mirror pair
  have failed) under Weibull per-event system failures - matches the
  paper's injector semantics;
- ``mtti_exponential``: closed-form for shape=1 via expected number of
  system failures to interruption;
- ``daly_interval``: Young/Daly optimal checkpoint interval given the
  replication-stretched MTTI - the paper's motivation ("allow for longer
  checkpoint intervals");
- ``efficiency``: end-to-end useful-work fraction combining replica
  resource cost, rework, and checkpoint overhead - quantifies when partial
  replication pays off (Stearley et al.'s question);
- ``mtti_montecarlo_healed``: MTTI when a ``repro.heal`` spare pool
  re-establishes lost mirrors online - runs the REAL
  ``WorldState.repair`` + ``heal`` algebra per failure, so the model and
  the system cannot drift apart.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

from repro.core.replication import ReplicaTopology, WorldState
from repro.heal.policy import HealPolicy


def _interrupted(topo: ReplicaTopology, dead_roles: set) -> bool:
    """Application is interrupted when a computational role is dead and its
    replica (if any) is dead too."""
    for c in range(topo.n_comp):
        r = topo.partner_of(c)
        if c in dead_roles and (r is None or r in dead_roles):
            return True
    return False


def expected_failures_to_interruption(topo: ReplicaTopology, trials: int = 2000,
                                      seed: int = 0) -> float:
    """E[# of uniform-random slice failures until the app is interrupted]."""
    rng = np.random.default_rng(seed)
    n = topo.n_slices
    counts = []
    for _ in range(trials):
        order = rng.permutation(n)
        dead: set = set()
        for k, v in enumerate(order, start=1):
            dead.add(int(v))
            if _interrupted(topo, dead):
                counts.append(k)
                break
    return float(np.mean(counts))


def mtti_montecarlo(topo: ReplicaTopology, system_scale: float,
                    shape: float = 0.7, trials: int = 2000, seed: int = 0) -> float:
    """MTTI under Weibull inter-failure times of the whole system.

    Inter-failure gaps are iid Weibull(shape, scale=system_scale); each
    failure kills a uniformly-random live slice (the paper's injector).
    """
    rng = np.random.default_rng(seed)
    times = []
    n = topo.n_slices
    for _ in range(trials):
        t = 0.0
        dead: set = set()
        alive = list(range(n))
        while True:
            t += system_scale * rng.weibull(shape)
            v = alive[rng.integers(len(alive))]
            alive.remove(v)
            dead.add(v)
            if _interrupted(topo, dead):
                times.append(t)
                break
    return float(np.mean(times))


def mtti_montecarlo_healed(
    n_slices: int,
    rdegree: float,
    *,
    n_spares: int = 0,
    policy: str = "none",
    system_scale: float = 10.0,
    shape: float = 0.7,
    trials: int = 500,
    seed: int = 0,
) -> float:
    """MTTI with online re-replication from a spare pool.

    Each Weibull-spaced failure kills a uniformly-random live physical
    (role-holding or spare); the world runs the real
    ``WorldState.repair``/``heal`` transitions. The application is
    interrupted at the first failure replication cannot mask (a lost
    computational role - spare *backfill* still restores state, so it
    counts as the interruption it is; only re-established *mirrors*
    stretch MTTI).

    Fairness vs :func:`mtti_montecarlo`: ``system_scale`` there prices a
    system of ``n_slices - n_spares`` role-holding nodes. Adding spares
    adds hardware that also fails, so the whole-system inter-failure
    scale shrinks proportionally (per-node MTBF held constant) - else a
    failure landing harmlessly on a spare would be credited to healing.
    """
    pol = HealPolicy.parse(policy)
    rng = np.random.default_rng(seed)
    scale_eff = system_scale * (n_slices - n_spares) / n_slices
    times = []
    for _ in range(trials):
        world = WorldState.create(n_slices, rdegree, n_spares=n_spares)
        t = 0.0
        while True:
            t += scale_eff * rng.weibull(shape)
            alive = list(world.assignment) + list(world.spares)
            victim = int(alive[rng.integers(len(alive))])
            # use_spares=False: a backfill is an interruption, not a mask
            world, rep = world.repair([victim], use_spares=False)
            if rep["lost_cmp"] or world.topo.n_comp == 0:
                times.append(t)
                break
            if pol.wants_heal(world.replica_deficit()):
                world, _ = world.heal()
    return float(np.mean(times))


def mtti_exponential(topo: ReplicaTopology, system_mtbf: float,
                     trials: int = 2000, seed: int = 0) -> float:
    """Closed-form-ish MTTI for exponential failures: E[failures] * MTBF."""
    return expected_failures_to_interruption(topo, trials, seed) * system_mtbf


def daly_interval(mtti: float, checkpoint_cost: float) -> float:
    """Young/Daly optimal checkpoint interval tau = sqrt(2 delta M) - delta."""
    if mtti <= 2 * checkpoint_cost:
        return checkpoint_cost
    return float(np.sqrt(2 * checkpoint_cost * mtti) - checkpoint_cost)


def efficiency(topo: ReplicaTopology, system_mtbf: float, checkpoint_cost: float,
               restart_cost: float, shape: float = 0.7,
               trials: int = 1000, seed: int = 0) -> Dict[str, float]:
    """Useful-work fraction of the whole allocation under failures.

    - resource factor: nComp / nSlices (replicas consume chips)
    - checkpoint factor: tau / (tau + delta) with Daly tau from the
      replication-stretched MTTI
    - rework factor: on each interruption ~tau/2 + restart lost
    """
    mtti = mtti_montecarlo(topo, system_mtbf, shape, trials, seed)
    tau = daly_interval(mtti, checkpoint_cost)
    resource = topo.n_comp / topo.n_slices
    ckpt = tau / (tau + checkpoint_cost)
    rework = mtti / (mtti + tau / 2.0 + restart_cost)
    eff = resource * ckpt * rework
    return {
        "mtti": mtti,
        "tau_opt": tau,
        "resource_factor": resource,
        "checkpoint_factor": ckpt,
        "rework_factor": rework,
        "efficiency": eff,
    }
