"""Message/step logging and recovery (paper Secs. V-B, VI-B).

The paper logs every P2P send (message id piggybacked) and every collective
(``last_collective_id``); after the world is repaired, lost in-flight
messages are resent from the logs and incomplete collectives are replayed
in order.

In SPMD training the unit of in-flight work is the *step* (one step = one
fixed sequence of collectives), so the log records, per slice role:

    (step, sample range consumed, collective sequence number, state digest)

After repair:
- promoted replicas are already state-consistent (they mirrored every
  step), so only the in-flight step is replayed;
- checkpoint-restored worlds replay every step after the checkpoint;
- ``min_completed_step`` across live slices is the paper's "identify the
  collectives that every live process has completed";
- duplicate suppression: steps a slice already applied are skipped by id
  (the paper's "marked using their sendids to be skipped in the future").

The NAS mini-apps log at collective granularity with the same machinery
(each app step may contain several logged collectives).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class StepRecord:
    step: int
    sample_start: int
    sample_end: int
    collective_seq: int  # last completed collective id within the step
    digest: int = 0  # optional state checksum for cross-validation


@dataclass
class StepLog:
    """Per-slice-role append-only log with duplicate suppression."""

    role: int
    records: List[StepRecord] = field(default_factory=list)
    applied: set = field(default_factory=set)

    def record(self, rec: StepRecord) -> None:
        self.records.append(rec)
        self.applied.add(rec.step)

    def last_step(self) -> int:
        return self.records[-1].step if self.records else -1

    def has_applied(self, step: int) -> bool:
        return step in self.applied

    def trim(self, upto_step: int) -> None:
        """Garbage-collect records at or below a globally-complete step.
        ``applied`` is trimmed alongside ``records`` - duplicate
        suppression only ever consults steps at or after the replay start,
        so entries at or below a globally-complete step can never be
        queried again (they used to accumulate for the whole run, growing
        memory linearly in steps across long multi-failure runs)."""
        self.records = [r for r in self.records if r.step > upto_step]
        self.applied = {s for s in self.applied if s > upto_step}


def min_completed_step(logs: Sequence[StepLog]) -> int:
    """Latest step completed by EVERY live slice (paper Sec. VI-B)."""
    if not logs:
        return -1
    return min(log.last_step() for log in logs)


@dataclass(frozen=True)
class ReplayPlan:
    start_step: int  # first step to (re)execute
    skip: Dict[int, List[int]]  # role -> steps it must suppress (already applied)
    reason: str


def replay_plan(logs: Sequence[StepLog], target_step: int, *,
                restored_step: Optional[int] = None) -> ReplayPlan:
    """Plan the replay after repair.

    - promote path (restored_step None): replay from min_completed + 1;
      slices that already applied later steps suppress the duplicates
      (can happen when failure struck between a slice's optimizer update
      and its peers' - the paper's "already received" case);
    - restart path: replay everything after the checkpoint.
    """
    if restored_step is not None:
        start = restored_step + 1
        reason = f"checkpoint restart from step {restored_step}"
        skip: Dict[int, List[int]] = {}
    else:
        start = min_completed_step(logs) + 1
        reason = "promote: replay in-flight step(s)"
        skip = {
            log.role: sorted(s for s in log.applied if s >= start)
            for log in logs
            if any(s >= start for s in log.applied)
        }
    start = min(start, target_step)
    return ReplayPlan(start_step=start, skip=skip, reason=reason)
