"""Live state cloning - the process-image replication analogue (paper
Sec. III-A).

The paper replicates a process by transferring its data, heap and stack
segments (Condor-style). JAX state is explicit, so the transfer is a pytree
copy, but the 3-phase ordering and integrity discipline carry over:

  phase 1 "data segment"  -> model parameters (static layout, bulk bytes)
  phase 2 "heap segment"  -> optimizer state (allocator-ordered chunks; the
                             paper's chunk-matching step corresponds to
                             matching the moment pytree structure)
  phase 3 "stack segment" -> host control state: step counter, RNG key,
                             data-pipeline cursor, collective seq (the
                             jmp_buf analogue - restored last so the clone
                             resumes exactly at the pre-transfer point)

:func:`clone_pytree` is the generic engine (one phase per top-level key);
:func:`clone_state` keeps the paper's named 3-phase layout on top of it.
Verification is per phase: by default per-chunk [abs-sum, sum] digests
computed on-device in ONE fused pass through the Pallas checksum kernel
(``repro.xfer.digest`` - the old implementation looped a host-side
Python checksum over every leaf), optionally a per-leaf bit-exact
comparison (``bit_exact=True``). The digest catches chunk-local and
sign-compensating corruption, but remains blind to permutations that
preserve each chunk's value multiset (e.g. two identical-sum leaves
swapped within one chunk) - restore paths that must be provably faithful
opt into the exact check. Used for dynamic replica (re)birth via
:class:`repro.store.liveclone.LiveCloneStore` and by the recovery
benchmark to price promote vs restart.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclass(frozen=True)
class HostState:
    """The 'stack segment': everything needed to resume the host loop."""

    step: int
    rng_seed: int
    data_cursor: int
    collective_seq: int
    generation: int


@dataclass
class TransferReport:
    bytes_by_phase: Dict[str, int] = field(default_factory=dict)
    seconds_by_phase: Dict[str, float] = field(default_factory=dict)
    #: phase -> verification outcome; empty when verify was skipped
    verified_by_phase: Dict[str, bool] = field(default_factory=dict)
    bit_exact: bool = False  # which check produced verified_by_phase

    @property
    def verified(self) -> bool:
        return bool(self.verified_by_phase) and all(self.verified_by_phase.values())

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_phase.values())

    @property
    def total_seconds(self) -> float:
        return sum(self.seconds_by_phase.values())


def _tree_bytes(tree: PyTree) -> int:
    return sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(tree) if hasattr(x, "size")
    )


def _copy_tree(tree: PyTree, sharding=None) -> PyTree:
    """Device-to-device copy. With a sharding, places the clone onto the
    replica slice's devices (the intercomm transfer); without, a same-device
    copy (the simulator path)."""
    if sharding is not None:
        out = jax.device_put(tree, sharding)
    else:
        out = jax.tree.map(lambda x: jnp.array(x, copy=True), tree)
    jax.block_until_ready(out)
    return out


def verify_clone(src: PyTree, dst: PyTree, *, bit_exact: bool = False) -> bool:
    """Integrity check for one transferred phase.

    - default: per-chunk [abs-sum, sum] digests, one fused on-device pass
      per tree (the Pallas checksum kernel) compared chunk-wise - cheap,
      catches bulk, chunk-local and sign-compensating corruption;
    - ``bit_exact``: every leaf compared elementwise (catches value-
      multiset-preserving permutations the digest is blind to).
    """
    if bit_exact:
        a, b = jax.tree.leaves(src), jax.tree.leaves(dst)
        return len(a) == len(b) and all(
            np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(a, b)
        )
    from repro.xfer.digest import verify_tree  # deferred: keeps core light

    return verify_tree(src, dst)


def clone_pytree(
    state: PyTree,
    *,
    phases: Optional[Dict[str, PyTree]] = None,
    sharding=None,
    verify: bool = True,
    bit_exact: bool = False,
) -> Tuple[PyTree, TransferReport]:
    """Phase-ordered clone of an arbitrary state pytree.

    ``phases`` names sub-trees to transfer (and verify) independently; by
    default each top-level key of a dict state is its own phase, and a
    non-dict state is one ``state`` phase. Leaves that are not arrays
    (host control scalars, dataclasses) are copied by construction and
    verified by equality.
    """
    report = TransferReport(bit_exact=bit_exact)
    # (phase name, output key, subtree): output keys keep the state's own
    # (possibly non-string) keys; phase names label the report
    if phases is not None:
        items = [(name, name, sub) for name, sub in phases.items()]
    elif isinstance(state, dict):
        items = [(str(k), k, v) for k, v in state.items()]
    else:
        items = [("state", "state", state)]
    out: Dict[Any, PyTree] = {}
    for name, key, sub in items:
        t0 = time.perf_counter()
        arrays = all(hasattr(x, "dtype") for x in jax.tree.leaves(sub))
        clone = _copy_tree(sub, sharding) if arrays else _host_copy(sub)
        report.seconds_by_phase[name] = time.perf_counter() - t0
        report.bytes_by_phase[name] = _tree_bytes(sub) or 64  # O(1) control words
        if verify:
            report.verified_by_phase[name] = (
                verify_clone(sub, clone, bit_exact=bit_exact)
                if arrays
                else sub == clone
            )
        out[key] = clone
    rebuilt = out if (phases is not None or isinstance(state, dict)) else out["state"]
    return rebuilt, report


def _host_copy(sub: PyTree) -> PyTree:
    """Copy a host-control subtree: mutable ndarray leaves are copied (the
    snapshot must not alias the caller's buffers), immutable leaves
    (scalars, frozen dataclasses' fields) carry over by value."""
    if isinstance(sub, HostState):
        return HostState(**vars(sub))
    return jax.tree.map(
        lambda x: np.array(x) if isinstance(x, np.ndarray) else x, sub
    )


def clone_state(params: PyTree, opt_state: PyTree, host: HostState, *,
                sharding=None, verify: bool = True, bit_exact: bool = False
                ) -> Tuple[PyTree, PyTree, HostState, TransferReport]:
    """3-phase live clone of a slice's training state (paper phase names)."""
    cloned, report = clone_pytree(
        {"params": params, "opt": opt_state, "host": host},
        phases={
            "data_segment(params)": params,
            "heap_segment(optimizer)": opt_state,
            "stack_segment(host)": host,
        },
        sharding=sharding,
        verify=verify,
        bit_exact=bit_exact,
    )
    return (
        cloned["data_segment(params)"],
        cloned["heap_segment(optimizer)"],
        cloned["stack_segment(host)"],
        report,
    )
