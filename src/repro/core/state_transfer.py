"""Live state cloning - the process-image replication analogue (paper
Sec. III-A).

The paper replicates a process by transferring its data, heap and stack
segments (Condor-style). JAX state is explicit, so the transfer is a pytree
copy, but the 3-phase ordering and integrity discipline carry over:

  phase 1 "data segment"  -> model parameters (static layout, bulk bytes)
  phase 2 "heap segment"  -> optimizer state (allocator-ordered chunks; the
                             paper's chunk-matching step corresponds to
                             matching the moment pytree structure)
  phase 3 "stack segment" -> host control state: step counter, RNG key,
                             data-pipeline cursor, collective seq (the
                             jmp_buf analogue - restored last so the clone
                             resumes exactly at the pre-transfer point)

Used for dynamic replica (re)birth - the paper's future-work "dynamic
replication" - and by the recovery benchmark to price promote vs restart.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclass(frozen=True)
class HostState:
    """The 'stack segment': everything needed to resume the host loop."""

    step: int
    rng_seed: int
    data_cursor: int
    collective_seq: int
    generation: int


@dataclass
class TransferReport:
    bytes_by_phase: Dict[str, int] = field(default_factory=dict)
    seconds_by_phase: Dict[str, float] = field(default_factory=dict)
    verified: bool = False

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_phase.values())

    @property
    def total_seconds(self) -> float:
        return sum(self.seconds_by_phase.values())


def _tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def _copy_tree(tree: PyTree, sharding=None) -> PyTree:
    """Device-to-device copy. With a sharding, places the clone onto the
    replica slice's devices (the intercomm transfer); without, a same-device
    copy (the simulator path)."""
    if sharding is not None:
        out = jax.device_put(tree, sharding)
    else:
        out = jax.tree.map(lambda x: jnp.array(x, copy=True), tree)
    jax.block_until_ready(out)
    return out


def _checksum(tree: PyTree) -> float:
    return float(
        sum(jnp.sum(jnp.abs(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clone_state(params: PyTree, opt_state: PyTree, host: HostState, *,
                sharding=None, verify: bool = True
                ) -> Tuple[PyTree, PyTree, HostState, TransferReport]:
    """3-phase live clone of a slice's training state."""
    report = TransferReport()

    t0 = time.perf_counter()
    params_c = _copy_tree(params, sharding)
    report.seconds_by_phase["data_segment(params)"] = time.perf_counter() - t0
    report.bytes_by_phase["data_segment(params)"] = _tree_bytes(params)

    t0 = time.perf_counter()
    opt_c = _copy_tree(opt_state, sharding)
    report.seconds_by_phase["heap_segment(optimizer)"] = time.perf_counter() - t0
    report.bytes_by_phase["heap_segment(optimizer)"] = _tree_bytes(opt_state)

    t0 = time.perf_counter()
    host_c = HostState(**vars(host)) if not isinstance(host, HostState) else host
    report.seconds_by_phase["stack_segment(host)"] = time.perf_counter() - t0
    report.bytes_by_phase["stack_segment(host)"] = 64  # O(1) control words

    if verify:
        report.verified = (
            abs(_checksum(params_c) - _checksum(params)) < 1e-6 * max(1.0, _checksum(params))
            and abs(_checksum(opt_c) - _checksum(opt_state)) < 1e-6 * max(1.0, _checksum(opt_state))
        )
    return params_c, opt_c, host_c, report
