"""Elastic mesh shrink + state resharding.

When an UNREPLICATED computational slice fails, replication cannot mask it;
the world shrinks (paper: checkpoint/restart continues the job). At 1000+
node scale, restarting on the *surviving* nodes requires: rebuilding the
mesh without the dead slice, re-sharding the restored state onto it, and
re-balancing the batch over the remaining computational slices. All three
live here.
"""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import mesh_from_devices
from repro.configs.base import ModelConfig
from repro.core.replication import WorldState

PyTree = Any


def shrink_mesh(mesh: Mesh, live_slices: Sequence[int]) -> Mesh:
    """Rebuild the mesh keeping only ``live_slices`` along the flattened
    (pod, data) axes. The pod axis is folded into data in the shrunk mesh
    (a dead slice breaks the rectangular pod structure - survivors form a
    single flat data axis, which changes collective routing but not
    semantics)."""
    axis_names = mesh.axis_names
    model_dim = mesh.shape["model"] if "model" in axis_names else 1
    devs = mesh.devices.reshape(-1, model_dim)
    live = sorted(live_slices)
    new_devs = devs[np.asarray(live)]
    return mesh_from_devices(
        new_devs.reshape(len(live), model_dim), ("data", "model")
    )


def reshard_state(state: PyTree, shardings: PyTree) -> PyTree:
    """Re-place state onto a (new) mesh; blocks until resident."""
    out = jax.device_put(state, shardings)
    jax.block_until_ready(out)
    return out


def rebalance_batch(global_batch: int, n_comp: int) -> Tuple[int, int]:
    """per-slice batch (padded) + padding when n_comp doesn't divide."""
    per = -(-global_batch // n_comp)  # ceil
    return per, per * n_comp - global_batch
