"""SimCluster: the paper's failure-management flow, executed for real.

Runs actual replicated train steps (real shard_map collectives over fake
CPU devices), injects failures, and drives the REAL recovery machinery:

  detect (control plane) -> revoke -> agree -> shrink/promote
  (WorldState.repair) -> elastic mesh rebuild -> communicator regeneration
  (step re-lowered with new axis_index_groups) -> step replay (recovery
  logs + deterministic pipeline) -> resume

This is the vehicle for the paper's Sec. VII-B experiments (overheads under
failures, MTTI vs replication degree) and for the flagship integration
test: a promote-path recovery must reproduce the failure-free training
trajectory bit-for-bit.

Requires >= n_slices * model_shards fake devices; callers launch inside a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=N (tests
and benchmarks do this so the main process keeps 1 device).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType, Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ReplicationConfig, TrainConfig
from repro.checkpoint.checkpointer import Checkpointer, PartnerStore
from repro.core import data_plane as DP
from repro.core.control_plane import ControlPlane, CommunicatorRevoked, ProcessFailed
from repro.core.elastic import shrink_mesh
from repro.core.recovery import ReplayPlan, StepLog, StepRecord, min_completed_step, replay_plan
from repro.core.replication import WorldState
from repro.data.pipeline import TokenPipeline
from repro.dist.sharding import param_shardings
from repro.models import model as M
from repro.optim.adamw import adamw
from repro.optim.schedules import constant


@dataclass
class SimReport:
    steps_completed: int = 0
    app_seconds: float = 0.0
    handler_seconds: float = 0.0
    failures: int = 0
    promotes: int = 0
    restarts: int = 0
    interruptions: List[int] = field(default_factory=list)  # steps at interrupt
    replayed_steps: int = 0
    losses: List[float] = field(default_factory=list)
    events: List[str] = field(default_factory=list)


class SimCluster:
    def __init__(
        self,
        model_cfg: ModelConfig,
        *,
        n_slices: int,
        model_shards: int = 1,
        rdegree: float = 0.0,
        collective_mode: str = "paper",
        per_slice_batch: int = 2,
        seq_len: int = 32,
        lr: float = 1e-3,
        seed: int = 0,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        impl: str = "chunked",
        microbatches: int = 1,
    ):
        n_dev = len(jax.devices())
        assert n_dev >= n_slices * model_shards, (
            f"need {n_slices * model_shards} devices, have {n_dev} - launch in a "
            "subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=N"
        )
        self.model_cfg = model_cfg
        self.repl = ReplicationConfig(rdegree=rdegree, collective_mode=collective_mode)
        self.train_cfg = TrainConfig(microbatches=microbatches)
        self.model_shards = model_shards
        self.impl = impl
        self.base_mesh = Mesh(
            np.array(jax.devices()[: n_slices * model_shards]).reshape(
                n_slices, model_shards
            ),
            ("data", "model"),
            axis_types=(AxisType.Auto, AxisType.Auto),
        )
        self.world = WorldState.create(n_slices, rdegree)
        self.control = ControlPlane(heartbeat_timeout=1e9)  # report-driven in sim
        self.pipeline = TokenPipeline(
            model_cfg, seq_len=seq_len, per_slice_batch=per_slice_batch, seed=seed
        )
        self.optimizer = adamw(constant(lr))
        self.partner = PartnerStore()
        self.ckpt = Checkpointer(checkpoint_dir) if checkpoint_dir else None
        self.checkpoint_every = checkpoint_every
        self.logs: Dict[int, StepLog] = {
            r: StepLog(r) for r in range(self.world.topo.n_slices)
        }
        self.generation = 0
        self.report = SimReport()

        key = jax.random.PRNGKey(seed)
        self.params = M.init(key, model_cfg)
        self.opt_state = self.optimizer.init(self.params)
        self.mesh: Mesh = None  # set by _rebuild
        self.step_fn = None
        self._rebuild()

    # ------------------------------------------------------------------
    def _rebuild(self) -> None:
        """(Re)generate communicators: shrink the mesh to live slices,
        re-place state, re-lower the step with the new groups."""
        live = self.world.live_physicals()
        self.mesh = shrink_mesh(self.base_mesh, live)
        with jax.set_mesh(self.mesh):
            pshard = param_shardings(self.params, self.mesh, self.model_cfg)
            self.params = jax.device_put(self.params, pshard)
            self.opt_state = jax.device_put(
                self.opt_state,
                type(self.opt_state)(
                    step=NamedSharding(self.mesh, P()),
                    mu=pshard,
                    nu=pshard,
                ),
            )
            self.step_fn = DP.build_train_step(
                self.model_cfg,
                self.train_cfg,
                self.repl,
                self.mesh,
                self.world,
                self.optimizer,
                impl=self.impl,
                donate=False,
            )

    # ------------------------------------------------------------------
    def _run_one_step(self, step: int) -> float:
        batch_np = self.pipeline.global_batch(step, self.world)
        with jax.set_mesh(self.mesh):
            batch = jax.tree.map(jnp.asarray, batch_np)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch
            )
            loss = float(metrics["loss"])
        for role in range(self.world.topo.n_slices):
            src = self.world.topo.mirror_source()[role]
            s0, s1 = self.pipeline.sample_range(step, src)
            self.logs.setdefault(role, StepLog(role)).record(
                StepRecord(step=step, sample_start=s0, sample_end=s1, collective_seq=step)
            )
        return loss

    def _checkpoint(self, step: int) -> None:
        state = {"params": self.params, "opt": self.opt_state}
        meta = {"step": step, "n_comp": self.world.topo.n_comp}
        # level 1: partner memory for every slice (cheap in-sim)
        self.partner.save(0, step, state, meta)
        # level 2: durable
        if self.ckpt is not None:
            self.ckpt.save(step, state, meta)

    # ------------------------------------------------------------------
    def error_handler(self, step: int) -> Tuple[Dict, ReplayPlan]:
        """Paper Sec. VI: revoke -> agree -> repair -> regenerate ->
        message recovery. Returns (repair report, replay plan)."""
        t0 = time.perf_counter()
        self.control.revoke()
        failed = self.control.agree()
        old_topo = self.world.topo
        new_world, rep = self.world.repair(sorted(failed))
        restored_step: Optional[int] = None

        if rep["lost_cmp"]:
            # unrecoverable by replication: multi-level restore
            self.report.restarts += 1
            self.report.interruptions.append(step)
            template = {"params": self.params, "opt": self.opt_state}
            got = self.partner.restore(0, template)
            if got is None and self.ckpt is not None:
                got = self.ckpt.restore(template)
            if got is not None:
                restored_step, state, _ = got
                self.params, self.opt_state = state["params"], state["opt"]
            else:
                restored_step = -1  # restart from scratch
                key = jax.random.PRNGKey(self.pipeline.seed)
                self.params = M.init(key, self.model_cfg)
                self.opt_state = self.optimizer.init(self.params)
        else:
            self.report.promotes += len(rep["promoted"])

        # message recovery plan from the SURVIVORS' logs (paper Sec. VI-B:
        # "identify the collectives that every live process has completed")
        # - computed before the logs are re-keyed for the new world.
        survivor_roles = [
            r
            for r in range(old_topo.n_slices)
            if self.world.assignment[r] not in failed
        ]
        live_logs = [self.logs[r] for r in survivor_roles if r in self.logs]
        plan = replay_plan(live_logs, step, restored_step=restored_step)

        self.world = new_world
        self.logs = {r: StepLog(r) for r in range(new_world.topo.n_slices)}
        for r, log in self.logs.items():
            log.applied.update(range(0, plan.start_step))
        self._rebuild()
        self.control.shrink_complete(failed)
        self.generation = new_world.generation
        self.report.handler_seconds += time.perf_counter() - t0
        self.report.events.append(
            f"step {step}: failed={sorted(failed)} promoted={rep['promoted']} "
            f"lost={rep['lost_cmp']} plan={plan.reason}@{plan.start_step}"
        )
        return rep, plan

    # ------------------------------------------------------------------
    def run(
        self,
        steps: int,
        failures: Optional[Dict[int, List[int]]] = None,
        warmup_compile: bool = True,
    ) -> SimReport:
        """Run ``steps`` training steps. ``failures`` maps step index ->
        physical slices to kill *during* that step (detected at its
        dispatch boundary, like a communication-time detection)."""
        failures = failures or {}
        if warmup_compile:
            # compile outside timing WITHOUT consuming step 0: snapshot state,
            # run, restore (the update must not be applied twice)
            saved_p = jax.tree.map(np.asarray, self.params)
            saved_o = jax.tree.map(np.asarray, self.opt_state)
            self._run_one_step(0)
            with jax.set_mesh(self.mesh):
                pshard = param_shardings(saved_p, self.mesh, self.model_cfg)
                self.params = jax.device_put(saved_p, pshard)
                self.opt_state = jax.device_put(
                    saved_o,
                    type(self.opt_state)(
                        step=NamedSharding(self.mesh, P()), mu=pshard, nu=pshard
                    ),
                )
            self.logs = {r: StepLog(r) for r in range(self.world.topo.n_slices)}

        step = 0
        while step < steps:
            if step in failures and failures[step]:
                for victim in failures.pop(step):
                    if victim in self.world.assignment:
                        self.control.report_failure(victim)
                        self.report.failures += 1
            try:
                self.control.check(self.generation)
            except (CommunicatorRevoked, ProcessFailed):
                _, plan = self.error_handler(step)
                replay_from = max(plan.start_step, 0)
                self.report.replayed_steps += max(0, step - replay_from)
                step = replay_from
                continue

            t0 = time.perf_counter()
            loss = self._run_one_step(step)
            self.report.app_seconds += time.perf_counter() - t0
            self.report.losses.append(loss)
            self.report.steps_completed += 1
            if (
                self.checkpoint_every
                and step > 0
                and step % self.checkpoint_every == 0
            ):
                self._checkpoint(step)
            step += 1
        return self.report

    # ------------------------------------------------------------------
    def params_replica(self) -> Dict:
        """Host copy of params (replicated over data; gathered for tests)."""
        return jax.tree.map(np.asarray, self.params)
