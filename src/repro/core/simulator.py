"""SimCluster: the paper's failure-management flow, executed for real.

Runs actual replicated train steps (real shard_map collectives over fake
CPU devices) as a thin :class:`~repro.ft.program.ResilientProgram`: all of
the recovery machinery -

  detect (control plane) -> revoke -> agree -> shrink/promote
  (WorldState.repair) -> elastic mesh rebuild -> communicator regeneration
  (step re-lowered with new axis_index_groups) -> step replay (recovery
  logs + deterministic pipeline) -> resume

- lives in :class:`~repro.ft.session.FTSession`; this module only supplies
the train data plane (build/run a step) and the trainer-specific hooks
(seekable pipeline sample ranges, state snapshot/restore/fresh-init).

This is the vehicle for the paper's Sec. VII-B experiments (overheads under
failures, MTTI vs replication degree) and for the flagship integration
test: a promote-path recovery must reproduce the failure-free training
trajectory bit-for-bit.

Requires >= n_slices * model_shards fake devices; callers launch inside a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=N (tests
and benchmarks do this so the main process keeps 1 device).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs.base import ModelConfig, ReplicationConfig, TrainConfig
from repro.core import data_plane as DP
from repro.data.pipeline import TokenPipeline
from repro.dist.sharding import opt_shardings, param_shardings
from repro.ft import FailureSchedule, FTReport, FTSession, ResilientProgram
from repro.models import model as M
from repro.optim.adamw import adamw
from repro.optim.schedules import constant
from repro.store import DurableStore, PartnerMemoryStore, RecoveryLadder
from repro.xfer import TransferPlane


@dataclass
class SimReport(FTReport):
    """FTReport + the training-loss trajectory."""

    losses: List[float] = field(default_factory=list)


class SimCluster(ResilientProgram):
    def __init__(
        self,
        model_cfg: ModelConfig,
        *,
        n_slices: int,
        model_shards: int = 1,
        rdegree: float = 0.0,
        spares: int = 0,
        heal: str = "none",
        collective_mode: str = "paper",
        per_slice_batch: int = 2,
        seq_len: int = 32,
        lr: float = 1e-3,
        seed: int = 0,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        partner_redundancy: int = 2,
        stores: Optional[RecoveryLadder] = None,
        impl: str = "chunked",
        microbatches: int = 1,
        delta: str = "none",
        chunk_bytes: int = 0,
        pipeline: bool = True,
        durable_delta: str = "none",
        durable_max_chain: int = 4,
    ):
        self.model_cfg = model_cfg
        self.repl = ReplicationConfig(rdegree=rdegree, collective_mode=collective_mode)
        self.train_cfg = TrainConfig(microbatches=microbatches)
        self.impl = impl
        self.pipeline = TokenPipeline(
            model_cfg, seq_len=seq_len, per_slice_batch=per_slice_batch, seed=seed
        )
        self.optimizer = adamw(constant(lr))

        key = jax.random.PRNGKey(seed)
        self.params = M.init(key, model_cfg)
        self.opt_state = self.optimizer.init(self.params)
        self.step_fn = None

        # recovery-state plane: level-1 K-way partner memory over the slice
        # hosts, plus level-2 durable when a directory is given; all levels
        # share one repro.xfer transfer plane (striping / pipelined async
        # submit / optional verified-exact delta encoding). ``durable_delta``
        # turns on the ON-DISK delta chains (ref-counted GC, restore depth
        # capped at ``durable_max_chain`` step dirs) independently of the
        # memory levels' ``delta`` codec.
        if stores is not None:
            assert (
                delta == "none" and durable_delta == "none"
                and not chunk_bytes and pipeline
            ), (
                "delta/durable_delta/chunk_bytes/pipeline configure the "
                "default ladder; an explicit stores= ladder carries its own - "
                "pass RecoveryLadder(..., xfer=TransferPlane(...)) and "
                "DurableStore(..., delta=...) instead"
            )
        if stores is None:
            assert durable_delta == "none" or checkpoint_dir, (
                "durable_delta configures the on-disk DurableStore - it "
                "needs checkpoint_dir, or the flag silently stores nothing"
            )
            xfer = TransferPlane(
                **({"chunk_bytes": chunk_bytes} if chunk_bytes else {}),
                delta=delta,
                pipeline=pipeline,
            )
            levels = [
                PartnerMemoryStore(range(n_slices), redundancy=partner_redundancy)
            ]
            if checkpoint_dir:
                levels.append(DurableStore(
                    checkpoint_dir, delta=durable_delta,
                    max_chain=durable_max_chain,
                ))
            stores = RecoveryLadder(levels, xfer=xfer)

        # the session owns the entire ULFM lifecycle; FTSession.__init__
        # builds the base mesh and calls build_step for the initial lowering
        self.session = FTSession(
            self,
            n_slices=n_slices,
            model_shards=model_shards,
            rdegree=rdegree,
            n_spares=spares,
            heal=heal,
            heartbeat_timeout=1e9,  # report-driven in sim
            stores=stores,
            checkpoint_every=checkpoint_every,
            replay="log",
            report=SimReport(),
            unit="step",
        )

    # ---- convenience views over the session --------------------------------
    @property
    def world(self):
        return self.session.world

    @property
    def mesh(self):
        return self.session.mesh

    @property
    def report(self) -> SimReport:
        return self.session.report

    @property
    def generation(self) -> int:
        return self.session.generation

    @property
    def ladder(self) -> RecoveryLadder:
        return self.session.ladder

    # ------------------------------------------------------------------
    # ResilientProgram hooks
    # ------------------------------------------------------------------
    def build_step(self, mesh, world) -> None:
        """Re-place state onto the (shrunk) mesh and re-lower the step with
        the new world's axis_index_groups."""
        with set_mesh(mesh):
            self._place_state(mesh)
            self.step_fn = DP.build_train_step(
                self.model_cfg,
                self.train_cfg,
                self.repl,
                mesh,
                world,
                self.optimizer,
                impl=self.impl,
                donate=False,
            )

    def run_step(self, step: int) -> float:
        loss = self._run_one_step(step)
        self.report.losses.append(loss)
        return loss

    def sample_range(self, step: int, cmp_role: int):
        return self.pipeline.sample_range(step, cmp_role)

    def snapshot(self):
        return (
            {"params": self.params, "opt": self.opt_state},
            {"n_comp": self.world.topo.n_comp},
        )

    def restore(self, state, meta) -> None:
        self.params, self.opt_state = state["params"], state["opt"]

    def init_fresh(self) -> None:
        key = jax.random.PRNGKey(self.pipeline.seed)
        self.params = M.init(key, self.model_cfg)
        self.opt_state = self.optimizer.init(self.params)

    # ------------------------------------------------------------------
    def _place_state(self, mesh) -> None:
        pshard = param_shardings(self.params, mesh, self.model_cfg)
        self.params = jax.device_put(self.params, pshard)
        self.opt_state = jax.device_put(
            self.opt_state, opt_shardings(self.opt_state, pshard, mesh)
        )

    def _run_one_step(self, step: int) -> float:
        batch_np = self.pipeline.global_batch(step, self.world)
        with set_mesh(self.mesh):
            batch = jax.tree.map(jnp.asarray, batch_np)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch
            )
            return float(metrics["loss"])

    # ------------------------------------------------------------------
    def run(
        self,
        steps: int,
        failures: Optional[Dict[int, List[int]]] = None,
        warmup_compile: bool = True,
    ) -> SimReport:
        """Run ``steps`` training steps through the session's dispatch loop.
        ``failures`` maps step index -> physical slices to kill *during*
        that step (detected at its dispatch boundary, like a
        communication-time detection); the schedule is copied, never
        mutated."""
        if warmup_compile:
            # compile outside timing WITHOUT consuming step 0: snapshot
            # state, run, restore (the update must not be applied twice)
            saved_p = jax.tree.map(np.asarray, self.params)
            saved_o = jax.tree.map(np.asarray, self.opt_state)
            self._run_one_step(0)
            self.params, self.opt_state = saved_p, saved_o
            with set_mesh(self.mesh):
                self._place_state(self.mesh)
            self.session.reset_logs()
        return self.session.run(steps, FailureSchedule(failures))

    # ------------------------------------------------------------------
    def params_replica(self) -> Dict:
        """Host copy of params (replicated over data; gathered for tests)."""
        return jax.tree.map(np.asarray, self.params)
