"""SimCluster: the paper's failure-management flow, executed for real.

Runs actual replicated train steps (real shard_map collectives over fake
CPU devices) as a thin :class:`~repro.ft.program.ResilientProgram`: all of
the recovery machinery -

  detect (control plane) -> revoke -> agree -> shrink/promote
  (WorldState.repair) -> elastic mesh rebuild -> communicator regeneration
  (step re-lowered with new axis_index_groups) -> step replay (recovery
  logs + deterministic pipeline) -> resume

- lives in :class:`~repro.ft.session.FTSession`; this module only supplies
the train data plane (build/run a step) and the trainer-specific hooks
(seekable pipeline sample ranges, state snapshot/restore/fresh-init).

This is the vehicle for the paper's Sec. VII-B experiments (overheads under
failures, MTTI vs replication degree) and for the flagship integration
test: a promote-path recovery must reproduce the failure-free training
trajectory bit-for-bit.

Requires >= n_slices * model_shards fake devices; callers launch inside a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=N (tests
and benchmarks do this so the main process keeps 1 device).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs.base import ModelConfig, ReplicationConfig, TrainConfig
from repro.core import data_plane as DP
from repro.core.fault_injector import (
    ChaosSchedule,
    SDCEvent,
    SDCInjector,
    SDCSchedule,
)
from repro.data.pipeline import TokenPipeline
from repro.dist.sharding import opt_shardings, param_shardings
from repro.ft import FailureSchedule, FTReport, FTSession, ResilientProgram
from repro.models import model as M
from repro.scrub import NULL_SPEC, ScrubEvidence, ScrubPlane, encode_spec
from repro.optim.adamw import adamw
from repro.optim.schedules import constant
from repro.store import DurableStore, PartnerMemoryStore, RecoveryLadder
from repro.xfer import TransferPlane


@dataclass
class SimReport(FTReport):
    """FTReport + the training-loss trajectory."""

    losses: List[float] = field(default_factory=list)


class SimCluster(ResilientProgram):
    def __init__(
        self,
        model_cfg: ModelConfig,
        *,
        n_slices: int,
        model_shards: int = 1,
        rdegree: float = 0.0,
        spares: int = 0,
        heal: str = "none",
        collective_mode: str = "paper",
        per_slice_batch: int = 2,
        seq_len: int = 32,
        lr: float = 1e-3,
        seed: int = 0,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        partner_redundancy: int = 2,
        stores: Optional[RecoveryLadder] = None,
        impl: str = "chunked",
        microbatches: int = 1,
        delta: str = "none",
        chunk_bytes: int = 0,
        pipeline: bool = True,
        durable_delta: str = "none",
        durable_max_chain: int = 4,
        sdc_check: bool = False,
        sdc_inject: bool = False,
        sdc_tol: float = 0.0,
        sdc_chunk_elems: int = 1 << 12,
        sdc_seed: int = 0,
        suspicion_window: float = 0.0,
        progress_window: Optional[float] = None,
        rung_deadline_s: float = 0.0,
        chaos_base_latency_s: float = 0.05,
    ):
        self.model_cfg = model_cfg
        self.repl = ReplicationConfig(
            rdegree=rdegree, collective_mode=collective_mode,
            sdc_check=sdc_check, sdc_tol=sdc_tol,
            sdc_chunk_elems=sdc_chunk_elems,
        )
        self.train_cfg = TrainConfig(microbatches=microbatches)
        # online SDC scrubbing (repro.scrub): ``sdc_check`` turns on the
        # in-step per-chunk digest cross-check + update gate; ``sdc_inject``
        # additionally lowers the in-graph bit-flip port (the step takes a
        # traced corruption spec) for schedules passed to :meth:`run`
        self._sdc_inject = bool(sdc_inject)
        self._sdc_injector = SDCInjector(seed=sdc_seed)
        self._sdc_schedule: Optional[SDCSchedule] = None
        self._sdc_armed: Optional[SDCEvent] = None
        self._sdc_evidence = None
        self.impl = impl
        self.pipeline = TokenPipeline(
            model_cfg, seq_len=seq_len, per_slice_batch=per_slice_batch, seed=seed
        )
        self.optimizer = adamw(constant(lr))

        key = jax.random.PRNGKey(seed)
        self.params = M.init(key, model_cfg)
        self.opt_state = self.optimizer.init(self.params)
        self.step_fn = None

        # recovery-state plane: level-1 K-way partner memory over the slice
        # hosts, plus level-2 durable when a directory is given; all levels
        # share one repro.xfer transfer plane (striping / pipelined async
        # submit / optional verified-exact delta encoding). ``durable_delta``
        # turns on the ON-DISK delta chains (ref-counted GC, restore depth
        # capped at ``durable_max_chain`` step dirs) independently of the
        # memory levels' ``delta`` codec.
        if stores is not None:
            assert (
                delta == "none" and durable_delta == "none"
                and not chunk_bytes and pipeline
            ), (
                "delta/durable_delta/chunk_bytes/pipeline configure the "
                "default ladder; an explicit stores= ladder carries its own - "
                "pass RecoveryLadder(..., xfer=TransferPlane(...)) and "
                "DurableStore(..., delta=...) instead"
            )
        if stores is None:
            assert durable_delta == "none" or checkpoint_dir, (
                "durable_delta configures the on-disk DurableStore - it "
                "needs checkpoint_dir, or the flag silently stores nothing"
            )
            xfer = TransferPlane(
                **({"chunk_bytes": chunk_bytes} if chunk_bytes else {}),
                delta=delta,
                pipeline=pipeline,
            )
            levels = [
                PartnerMemoryStore(range(n_slices), redundancy=partner_redundancy)
            ]
            if checkpoint_dir:
                levels.append(DurableStore(
                    checkpoint_dir, delta=durable_delta,
                    max_chain=durable_max_chain,
                ))
            stores = RecoveryLadder(levels, xfer=xfer)

        scrub = (
            ScrubPlane(chunk_elems=sdc_chunk_elems, tol=sdc_tol)
            if sdc_check else None
        )

        # the session owns the entire ULFM lifecycle; FTSession.__init__
        # builds the base mesh and calls build_step for the initial lowering
        self.session = FTSession(
            self,
            n_slices=n_slices,
            model_shards=model_shards,
            rdegree=rdegree,
            n_spares=spares,
            heal=heal,
            heartbeat_timeout=1e9,  # report-driven unless liveness is on
            stores=stores,
            checkpoint_every=checkpoint_every,
            replay="log",
            report=SimReport(),
            unit="step",
            scrub=scrub,
            suspicion_window=suspicion_window,
            progress_window=progress_window,
            rung_deadline_s=rung_deadline_s,
            chaos_base_latency_s=chaos_base_latency_s,
        )

    # ---- convenience views over the session --------------------------------
    @property
    def world(self):
        return self.session.world

    @property
    def mesh(self):
        return self.session.mesh

    @property
    def report(self) -> SimReport:
        return self.session.report

    @property
    def generation(self) -> int:
        return self.session.generation

    @property
    def ladder(self) -> RecoveryLadder:
        return self.session.ladder

    # ------------------------------------------------------------------
    # ResilientProgram hooks
    # ------------------------------------------------------------------
    def build_step(self, mesh, world) -> None:
        """Re-place state onto the (shrunk) mesh and re-lower the step with
        the new world's axis_index_groups."""
        with set_mesh(mesh):
            self._place_state(mesh)
            self.step_fn = DP.build_train_step(
                self.model_cfg,
                self.train_cfg,
                self.repl,
                mesh,
                world,
                self.optimizer,
                impl=self.impl,
                donate=False,
                sdc_inject=self._sdc_inject,
            )

    def run_step(self, step: int) -> float:
        if self._sdc_schedule is not None:
            ev = self._sdc_schedule.take(step)
            if ev is not None:
                self._arm_sdc(ev)
        loss = self._run_one_step(step)
        if self._sdc_armed is not None and self._sdc_armed.target == "grad":
            # transient compute fault: it poisoned this step's gradients
            # only, so the session's retry must rerun clean
            self._sdc_armed = None
        if self._sdc_evidence is not None:
            # the update was gated in-graph - the step is NOT complete;
            # hand the evidence to the session's corruption handler and
            # keep the poisoned loss out of the trajectory
            ev, self._sdc_evidence = self._sdc_evidence, None
            self.session.report_corruption(step, ev)
            return loss
        self.report.losses.append(loss)
        return loss

    def sample_range(self, step: int, cmp_role: int):
        return self.pipeline.sample_range(step, cmp_role)

    def snapshot(self):
        return (
            {"params": self.params, "opt": self.opt_state},
            {"n_comp": self.world.topo.n_comp},
        )

    def restore(self, state, meta) -> None:
        self.params, self.opt_state = state["params"], state["opt"]

    def init_fresh(self) -> None:
        key = jax.random.PRNGKey(self.pipeline.seed)
        self.params = M.init(key, self.model_cfg)
        self.opt_state = self.optimizer.init(self.params)

    # ---- repro.scrub hooks -------------------------------------------
    def scrub_view(self, state):
        """Narrow a snapshot to what the in-step scrub tables digest
        (params - the persistent space the vote adjudicates)."""
        return {"params": state["params"]}

    def corrupted_view(self):
        """The victim's host-side view of its state: the snapshot tree
        with the armed param flip applied. The in-graph flip poisons a
        VIEW (the stored tree stays clean so the gate can freeze it), so
        the corruption is re-materialized here for the ladder's byte
        diff - this is the tree ``restore_partial`` compares against the
        last submit's chunk fingerprints."""
        state = {
            "params": jax.tree.map(np.array, self.params),
            "opt": jax.tree.map(np.array, self.opt_state),
        }
        e = self._sdc_armed
        if e is None or e.target != "param" or not e.resolved:
            return state
        leaves, treedef = jax.tree.flatten(state["params"])
        if 0 <= e.leaf < len(leaves) and leaves[e.leaf].dtype == np.float32:
            arr = np.array(leaves[e.leaf])
            flat = arr.reshape(-1)
            if flat.size:
                elem = min(max(e.elem, 0), flat.size - 1)  # clamp like in-graph
                flat.view(np.uint32)[elem] ^= np.uint32(1) << np.uint32(e.bit & 31)
                leaves[e.leaf] = arr
                state["params"] = jax.tree.unflatten(treedef, leaves)
        return state

    def clear_corruption(self, verdict=None) -> None:
        """The session repaired (or restarted past) the corruption:
        disarm the spec so replayed steps run clean."""
        self._sdc_armed = None

    def _arm_sdc(self, event: SDCEvent) -> None:
        leaf_sizes = [
            (i, int(np.prod(x.shape)))
            for i, x in enumerate(jax.tree.leaves(self.params))
            if hasattr(x, "dtype") and x.dtype == jnp.float32
            and int(np.prod(x.shape))
        ]
        self._sdc_armed = self._sdc_injector.resolve(event, leaf_sizes)

    def _sdc_spec(self) -> np.ndarray:
        e = self._sdc_armed
        if e is None:
            return NULL_SPEC
        pos = self.world.mesh_position().get(e.victim)
        if pos is None:  # victim slice is dead / off-mesh: nothing to poison
            return NULL_SPEC
        return encode_spec(pos, e.target, e.leaf, e.elem, e.bit)

    # ------------------------------------------------------------------
    def _place_state(self, mesh) -> None:
        pshard = param_shardings(self.params, mesh, self.model_cfg)
        self.params = jax.device_put(self.params, pshard)
        self.opt_state = jax.device_put(
            self.opt_state, opt_shardings(self.opt_state, pshard, mesh)
        )

    def _run_one_step(self, step: int) -> float:
        batch_np = self.pipeline.global_batch(step, self.world)
        with set_mesh(self.mesh):
            batch = jax.tree.map(jnp.asarray, batch_np)
            if self._sdc_inject:
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch,
                    jnp.asarray(self._sdc_spec()),
                )
            else:
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch
                )
            if (self.repl.sdc_check and "sdc" in metrics
                    and float(metrics["sdc"]) > self.repl.sdc_tol):
                self._sdc_evidence = ScrubEvidence(
                    step=step,
                    sdc=float(metrics["sdc"]),
                    grad_table=np.asarray(metrics["sdc_grad_table"]),
                    param_table=np.asarray(metrics["sdc_param_table"]),
                    pairs=tuple(
                        (int(g[0]), int(g[1]))
                        for g in self.world.physical_groups(
                            self.world.topo.pair_groups())
                        if len(g) == 2
                    ),
                )
            return float(metrics["loss"])

    # ------------------------------------------------------------------
    def run(
        self,
        steps: int,
        failures: Optional[Dict[int, List[int]]] = None,
        warmup_compile: bool = True,
        sdc=None,
        chaos=None,
    ) -> SimReport:
        """Run ``steps`` training steps through the session's dispatch loop.
        ``failures`` maps step index -> physical slices to kill *during*
        that step (detected at its dispatch boundary, like a
        communication-time detection); the schedule is copied, never
        mutated. ``sdc`` is an :class:`SDCSchedule` (or anything its
        constructor accepts) of bit flips to arm - requires the cluster
        to be built with ``sdc_inject=True``. ``chaos`` is a
        :class:`ChaosSchedule` (or spec string / event list) of gray
        failures - requires ``suspicion_window > 0`` at construction so
        the liveness layer can detect them."""
        if chaos is not None:
            self.session.chaos = (
                ChaosSchedule.parse(chaos) if isinstance(chaos, str)
                else chaos if isinstance(chaos, ChaosSchedule)
                else ChaosSchedule(chaos)
            )
            if self.session.chaos and not self.session._liveness:
                raise ValueError(
                    "a chaos schedule needs suspicion_window > 0 at "
                    "SimCluster construction (the liveness layer detects it)"
                )
        if sdc is not None:
            assert self._sdc_inject, (
                "an SDC schedule needs sdc_inject=True at construction "
                "(the step must be lowered with the corruption-spec port)"
            )
            self._sdc_schedule = (
                sdc if isinstance(sdc, SDCSchedule) else SDCSchedule(sdc)
            )
        if warmup_compile:
            # compile outside timing WITHOUT consuming step 0: snapshot
            # state, run, restore (the update must not be applied twice)
            saved_p = jax.tree.map(np.asarray, self.params)
            saved_o = jax.tree.map(np.asarray, self.opt_state)
            self._run_one_step(0)
            self.params, self.opt_state = saved_p, saved_o
            with set_mesh(self.mesh):
                self._place_state(self.mesh)
            self.session.reset_logs()
        return self.session.run(steps, FailureSchedule(failures))

    # ------------------------------------------------------------------
    def params_replica(self) -> Dict:
        """Host copy of params (replicated over data; gathered for tests)."""
        return jax.tree.map(np.asarray, self.params)
