"""Control plane - the ULFM analogue (paper Secs. III-B, IV, VI-A).

PartRePer-MPI keeps failure detection/propagation/recovery in Open MPI +
ULFM while the data plane runs on the native library. Here the control
plane is a host-side service that NEVER touches the compiled XLA program:

- ``heartbeat(slice)``      <- PRTE daemon liveness tracking
- ``report_failure(slice)`` <- SIGCHLD/ptrace detection path
- ``detect()``              <- MPI_Comm_failure_get_ack
- ``revoke()``              <- MPI_Comm_revoke: bumps the world generation;
  every host dispatch loop compares its generation before dispatching the
  next step and enters the error handler on mismatch (error propagation)
- ``agree()``               <- the shrink-time agreement on the failed set

In a multi-controller deployment this runs over an out-of-band transport
(etcd/TCP heartbeats); the in-process implementation below is used by the
simulator and tests, with identical semantics and thread-safety.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set


class CommunicatorRevoked(Exception):
    """Raised by dispatch guards when the world generation moved (the
    MPI_ERR_REVOKED analogue)."""

    def __init__(self, generation: int):
        super().__init__(f"world revoked at generation {generation}")
        self.generation = generation


class ProcessFailed(Exception):
    """MPI_ERR_PROC_FAILED analogue: a peer died mid-operation."""

    def __init__(self, failed: Set[int]):
        super().__init__(f"slices failed: {sorted(failed)}")
        self.failed = set(failed)


@dataclass
class ControlPlane:
    heartbeat_timeout: float = 5.0
    clock: Callable[[], float] = time.monotonic

    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    _last_beat: Dict[int, float] = field(default_factory=dict, repr=False)
    _reported: Set[int] = field(default_factory=set, repr=False)
    _acked: Set[int] = field(default_factory=set, repr=False)
    _generation: int = 0
    _revoked: bool = False

    # ---- liveness ----------------------------------------------------------
    def register(self, slice_id: int) -> None:
        with self._lock:
            self._last_beat[slice_id] = self.clock()

    def heartbeat(self, slice_id: int) -> None:
        with self._lock:
            self._last_beat[slice_id] = self.clock()

    def report_failure(self, slice_id: int) -> None:
        """Direct failure report (the SIGCHLD/ptrace path - e.g. a device
        error surfaced by the runtime, or the fault injector)."""
        with self._lock:
            self._reported.add(slice_id)

    def detect(self) -> Set[int]:
        """Failed = explicitly reported + heartbeat-expired."""
        now = self.clock()
        with self._lock:
            expired = {
                s
                for s, t in self._last_beat.items()
                if now - t > self.heartbeat_timeout
            }
            return set(self._reported) | expired

    # ---- ULFM protocol -----------------------------------------------------
    @property
    def generation(self) -> int:
        with self._lock:
            return self._generation

    def is_revoked(self) -> bool:
        with self._lock:
            return self._revoked

    def revoke(self) -> int:
        """MPI_Comm_revoke: propagate the failure to every dispatch loop."""
        with self._lock:
            if not self._revoked:
                self._revoked = True
                self._generation += 1
            return self._generation

    def failure_ack(self) -> Set[int]:
        """MPI_Comm_failure_ack + get_ack: snapshot the failed set."""
        with self._lock:
            self._acked = set(self._reported)
            return set(self._acked)

    def agree(self) -> Set[int]:
        """Agreement on the failed set at shrink time. Single-controller:
        the snapshot is the consensus; multi-controller implementations
        intersect per-host views here."""
        failed = self.detect()
        with self._lock:
            self._reported |= failed
            return set(self._reported)

    def shrink_complete(self, recovered: Set[int]) -> None:
        """Called by the error handler once the world is repaired: clears the
        revocation so dispatch resumes at the new generation."""
        with self._lock:
            self._reported -= recovered
            for s in recovered:
                self._last_beat.pop(s, None)
            self._revoked = False

    # ---- dispatch guard ------------------------------------------------------
    def check(self, my_generation: int) -> None:
        """Fast-path guard the host loop calls before dispatching a step
        (the analogue of interleaving EMPI_Test with failure checks in the
        paper's Fig. 7 loop - but host-side, off the XLA hot path)."""
        with self._lock:
            if self._revoked or self._generation != my_generation:
                raise CommunicatorRevoked(self._generation)
            if self._reported:
                raise ProcessFailed(set(self._reported))
