"""Control plane - the ULFM analogue (paper Secs. III-B, IV, VI-A).

PartRePer-MPI keeps failure detection/propagation/recovery in Open MPI +
ULFM while the data plane runs on the native library. Here the control
plane is a host-side service that NEVER touches the compiled XLA program:

- ``heartbeat(slice)``      <- PRTE daemon liveness tracking
- ``report_failure(slice)`` <- SIGCHLD/ptrace detection path
- ``detect()``              <- MPI_Comm_failure_get_ack
- ``revoke()``              <- MPI_Comm_revoke: bumps the world generation;
  every host dispatch loop compares its generation before dispatching the
  next step and enters the error handler on mismatch (error propagation)
- ``agree()``               <- the shrink-time agreement on the failed set

Gray failures (the FTHP-MPI / GASPI-FT timeout model): fail-stop is only
the clean half of the fault space. A slice can be alive-but-hung - its
liveness daemon keeps beating while its dispatch progress freezes - or
silently wedged. Heartbeats therefore carry a monotonically increasing
*progress* mark (the slice's dispatch step), and suspicion accrues from
two independent signals:

- **silence**: no heartbeat for longer than ``heartbeat_timeout`` - the
  crash-shaped suspicion (daemon/host gone);
- **stall**: beating, but progress pinned BEHIND the world's frontier
  (the max progress any slice reported) for longer than
  ``progress_timeout`` - the hang-shaped suspicion. Slices AT the
  frontier are never stall-suspected: when the whole world blocks on one
  hung member, only the laggard accrues suspicion, so attribution names
  the culprit, not its victims.

A suspicion score is the larger of the two ratios; a score in
[``suspect_fraction``, 1.0] is a *soft* suspect (observability + cheap
quarantine decisions - a flap that recovers here costs nothing), a score
past 1.0 is an agreed failure: :meth:`detect` includes it and the
:meth:`check` dispatch guard raises it into the error handler exactly
like a reported crash - a hung slice can no longer stall the world
forever.

Zombie fencing: once :meth:`shrink_complete` evicts a slice, the slice id
is fenced at that generation - a late heartbeat or re-register stamped
with the old generation is rejected, so a recovered-then-returning
process cannot resurrect itself into the liveness tables of a world that
already shrank past it.

In a multi-controller deployment this runs over an out-of-band transport
(etcd/TCP heartbeats); the in-process implementation below is used by the
simulator and tests, with identical semantics and thread-safety.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set


class CommunicatorRevoked(Exception):
    """Raised by dispatch guards when the world generation moved (the
    MPI_ERR_REVOKED analogue)."""

    def __init__(self, generation: int):
        super().__init__(f"world revoked at generation {generation}")
        self.generation = generation


class ProcessFailed(Exception):
    """MPI_ERR_PROC_FAILED analogue: a peer died mid-operation."""

    def __init__(self, failed: Set[int]):
        super().__init__(f"slices failed: {sorted(failed)}")
        self.failed = set(failed)


@dataclass(frozen=True)
class Suspicion:
    """One slice's gray-failure score at a point in time.

    ``score`` >= 1.0 means the suspicion window elapsed (the slice is in
    :meth:`ControlPlane.detect`'s failed set); scores in
    [suspect_fraction, 1.0) are soft suspects - watched, quarantinable,
    but NOT yet grounds for a shrink (the flap-tolerance band)."""

    slice_id: int
    score: float
    silent_for: float
    stalled_for: float
    reason: str  # "silence" | "stall"


@dataclass
class ControlPlane:
    heartbeat_timeout: float = 5.0
    clock: Callable[[], float] = time.monotonic
    #: stall (zero-progress-while-beating) window; None = heartbeat_timeout
    progress_timeout: Optional[float] = None
    #: scores at/above this fraction of the window are soft suspects
    suspect_fraction: float = 0.5

    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    _last_beat: Dict[int, float] = field(default_factory=dict, repr=False)
    _last_progress: Dict[int, float] = field(default_factory=dict, repr=False)
    _progress_time: Dict[int, float] = field(default_factory=dict, repr=False)
    #: slice -> generation at which it was shrunk out (zombie fence)
    _fenced: Dict[int, int] = field(default_factory=dict, repr=False)
    _reported: Set[int] = field(default_factory=set, repr=False)
    _acked: Set[int] = field(default_factory=set, repr=False)
    _generation: int = 0
    _revoked: bool = False

    # ---- liveness ----------------------------------------------------------
    def _fenced_locked(self, slice_id: int, generation: Optional[int]) -> bool:
        """True when a beat/register must be rejected: the slice was shrunk
        out and the sender's generation stamp does not post-date the fence
        (an unstamped message from a fenced slice is always a zombie)."""
        fence = self._fenced.get(slice_id)
        if fence is None:
            return False
        return generation is None or generation <= fence

    def register(self, slice_id: int, generation: Optional[int] = None,
                 progress: Optional[float] = None) -> bool:
        """Admit a slice into the liveness tables. Generation-aware: a
        re-register of a fenced (already shrunk-out) slice with a stale
        generation stamp is rejected, so re-registration racing the
        generation bump cannot re-enter ``detect()``'s expired set.
        Returns False when fenced off."""
        with self._lock:
            if self._fenced_locked(slice_id, generation):
                return False
            if generation is not None and generation > self._fenced.get(
                    slice_id, -1):
                self._fenced.pop(slice_id, None)
            now = self.clock()
            self._last_beat[slice_id] = now
            if progress is not None:
                self._last_progress[slice_id] = progress
                self._progress_time[slice_id] = now
            return True

    def heartbeat(self, slice_id: int, progress: Optional[float] = None,
                  generation: Optional[int] = None) -> bool:
        """One liveness beat, optionally carrying the slice's dispatch
        progress mark (monotonic; stale marks are kept, not regressed).
        Returns False for fenced zombies - the beat is dropped."""
        with self._lock:
            if self._fenced_locked(slice_id, generation):
                return False
            now = self.clock()
            self._last_beat[slice_id] = now
            if progress is not None and (
                slice_id not in self._last_progress
                or progress > self._last_progress[slice_id]
            ):
                self._last_progress[slice_id] = progress
                self._progress_time[slice_id] = now
            return True

    def report_failure(self, slice_id: int) -> None:
        """Direct failure report (the SIGCHLD/ptrace path - e.g. a device
        error surfaced by the runtime, or the fault injector)."""
        with self._lock:
            self._reported.add(slice_id)

    def reported(self) -> Set[int]:
        with self._lock:
            return set(self._reported)

    def _scores_locked(self, now: float) -> List[Suspicion]:
        hb = self.heartbeat_timeout
        pt = self.progress_timeout if self.progress_timeout is not None else hb
        frontier = max(self._last_progress.values(), default=None)
        out = []
        for s, beat in self._last_beat.items():
            silent = now - beat
            stalled = 0.0
            if (
                frontier is not None
                and s in self._last_progress
                and self._last_progress[s] < frontier
            ):
                stalled = now - self._progress_time[s]
            silence_score = silent / hb if hb > 0 else 0.0
            stall_score = stalled / pt if pt > 0 else 0.0
            score = max(silence_score, stall_score)
            if score <= 0:
                continue
            out.append(Suspicion(
                slice_id=s, score=score, silent_for=silent,
                stalled_for=stalled,
                reason="silence" if silence_score >= stall_score else "stall",
            ))
        out.sort(key=lambda x: (-x.score, x.slice_id))
        return out

    def suspects(self) -> List[Suspicion]:
        """Every slice scoring at/above ``suspect_fraction``, worst first.
        Soft suspects (score < 1.0) are the flap band: watch, maybe
        quarantine as a state source, but do NOT shrink - a slice that
        resumes beating with progress drops back out at no cost."""
        now = self.clock()
        with self._lock:
            return [
                s for s in self._scores_locked(now)
                if s.score >= self.suspect_fraction
            ]

    def detect(self) -> Set[int]:
        """Failed = explicitly reported + suspicion-expired (silence OR
        progress-stall strictly past its window - exactly at the window is
        still alive)."""
        now = self.clock()
        with self._lock:
            expired = {
                s.slice_id for s in self._scores_locked(now) if s.score > 1.0
            }
            return set(self._reported) | expired

    # ---- ULFM protocol -----------------------------------------------------
    @property
    def generation(self) -> int:
        with self._lock:
            return self._generation

    def is_revoked(self) -> bool:
        with self._lock:
            return self._revoked

    def revoke(self) -> int:
        """MPI_Comm_revoke: propagate the failure to every dispatch loop."""
        with self._lock:
            if not self._revoked:
                self._revoked = True
                self._generation += 1
            return self._generation

    def failure_ack(self) -> Set[int]:
        """MPI_Comm_failure_ack + get_ack: snapshot the failed set."""
        with self._lock:
            self._acked = set(self._reported)
            return set(self._acked)

    def agree(self) -> Set[int]:
        """Agreement on the failed set at shrink time. Single-controller:
        the snapshot is the consensus; multi-controller implementations
        intersect per-host views here."""
        failed = self.detect()
        with self._lock:
            self._reported |= failed
            return set(self._reported)

    def shrink_complete(self, recovered: Set[int]) -> None:
        """Called by the error handler once the world is repaired: clears
        the revocation so dispatch resumes at the new generation, and
        FENCES the evicted slices at that generation - their late
        heartbeats/registers are rejected from here on (zombie fencing)."""
        with self._lock:
            self._reported -= recovered
            for s in recovered:
                self._last_beat.pop(s, None)
                self._last_progress.pop(s, None)
                self._progress_time.pop(s, None)
                self._fenced[s] = self._generation
            self._revoked = False

    # ---- dispatch guard ------------------------------------------------------
    def check(self, my_generation: int) -> None:
        """Fast-path guard the host loop calls before dispatching a step
        (the analogue of interleaving EMPI_Test with failure checks in the
        paper's Fig. 7 loop - but host-side, off the XLA hot path).
        Folds liveness expiry into the guard: a hung or silent slice past
        its suspicion window raises here exactly like a reported crash,
        instead of stalling the world forever."""
        with self._lock:
            if self._revoked or self._generation != my_generation:
                raise CommunicatorRevoked(self._generation)
        failed = self.detect()
        if failed:
            raise ProcessFailed(failed)
