"""Weibull fault injector (paper Sec. VII-B).

"It uses a Weibull Distribution to generate fault injection timings and
randomly kills one of the MPI processes after the generated time has
passed." Deterministic under a seed so experiments are reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

import numpy as np


@dataclass
class FaultInjector:
    """Generates (time, victim) failure events.

    ``scale`` is the Weibull scale (characteristic life) of the *whole
    system* inter-failure time; ``shape`` < 1 models infant-mortality-heavy
    HPC failure traces (k ~ 0.7 is typical in the literature), 1.0 is
    exponential.
    """

    n_slices: int
    scale: float = 100.0
    shape: float = 0.7
    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False)

    def __post_init__(self):
        self._rng = np.random.default_rng(np.random.Philox(key=self.seed))

    def next_event(self, alive: List[int]) -> Tuple[float, int]:
        """Time until next failure (from now) and the victim slice, chosen
        uniformly among alive slices (paper: "randomly kills one")."""
        dt = float(self.scale * self._rng.weibull(self.shape))
        victim = int(self._rng.choice(alive))
        return dt, victim

    def schedule(self, horizon: float, alive: List[int]) -> List[Tuple[float, int]]:
        """All failure events in [0, horizon) assuming no repairs change the
        alive set (callers re-draw after repairs if they do)."""
        events = []
        t = 0.0
        while True:
            dt, victim = self.next_event(alive)
            t += dt
            if t >= horizon:
                return events
            events.append((t, victim))
