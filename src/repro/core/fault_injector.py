"""Weibull fault injector (paper Sec. VII-B) + deterministic SDC events.

"It uses a Weibull Distribution to generate fault injection timings and
randomly kills one of the MPI processes after the generated time has
passed." Deterministic under a seed so experiments are reproducible.

Fail-stop is only half the fault model: :class:`SDCEvent` /
:class:`SDCInjector` / :class:`SDCSchedule` add *silent data corruption* -
a single bit flip in one mirror's view of the gradients or params,
with seeded leaf/element/bit selection so scrubbing tests and benchmarks
reproduce a corruption scenario exactly (the ``repro.scrub`` plane turns
these into in-graph flips via ``scrub.digest.encode_spec``).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import (Dict, Iterator, List, Mapping, Optional, Sequence, Set,
                    Tuple, Union)

import numpy as np


@dataclass
class FaultInjector:
    """Generates (time, victim) failure events.

    ``scale`` is the Weibull scale (characteristic life) of the *whole
    system* inter-failure time; ``shape`` < 1 models infant-mortality-heavy
    HPC failure traces (k ~ 0.7 is typical in the literature), 1.0 is
    exponential.
    """

    n_slices: int
    scale: float = 100.0
    shape: float = 0.7
    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False)

    def __post_init__(self):
        if not (self.scale > 0):
            raise ValueError(f"scale must be > 0, got {self.scale}")
        if not (self.shape > 0):
            raise ValueError(f"shape must be > 0, got {self.shape}")
        self._rng = np.random.default_rng(np.random.Philox(key=self.seed))

    def next_event(self, alive: List[int]) -> Tuple[float, int]:
        """Time until next failure (from now) and the victim slice, chosen
        uniformly among alive slices (paper: "randomly kills one")."""
        dt = float(self.scale * self._rng.weibull(self.shape))
        victim = int(self._rng.choice(alive))
        return dt, victim

    def schedule(self, horizon: float, alive: List[int],
                 max_events: int = 1_000_000) -> List[Tuple[float, int]]:
        """All failure events in [0, horizon) assuming no repairs change the
        alive set (callers re-draw after repairs if they do).

        ``max_events`` bounds the draw loop: a degenerate Weibull draw of
        exactly 0.0 (possible at float32 resolution for tiny shapes) would
        otherwise never advance ``t`` and spin forever."""
        events = []
        t = 0.0
        while True:
            dt, victim = self.next_event(alive)
            t += dt
            if t >= horizon:
                return events
            events.append((t, victim))
            if len(events) >= max_events:
                raise RuntimeError(
                    f"degenerate fault schedule: {max_events} events before "
                    f"horizon {horizon} (scale={self.scale}, "
                    f"shape={self.shape}) - inter-failure draws are not "
                    "advancing time"
                )


# ---------------------------------------------------------------------------
# silent data corruption (the repro.scrub fault model)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SDCEvent:
    """One bit flip in one slice's view of its state at one step.

    ``victim`` is a PHYSICAL slice id (like FailureSchedule's victims);
    ``target`` picks the poisoned space: ``"grad"`` models a transient
    compute fault (gone next step), ``"param"`` a poisoned resident copy
    (persists until repaired). ``leaf``/``elem``/``bit`` may be None -
    :meth:`SDCInjector.resolve` fills them deterministically from the
    seed, so a schedule written as just ``step:victim`` is reproducible.
    """

    step: int
    victim: int
    target: str = "param"
    leaf: Optional[int] = None
    elem: Optional[int] = None
    bit: Optional[int] = None

    def __post_init__(self):
        if self.target not in ("grad", "param"):
            raise ValueError(
                f"SDC target must be 'grad' or 'param', got {self.target!r}")

    @property
    def resolved(self) -> bool:
        return None not in (self.leaf, self.elem, self.bit)


@dataclass
class SDCInjector:
    """Seeded leaf/element/bit selection (Philox, like FaultInjector):
    leaves weighted by element count (a flip lands uniformly over the
    state's elements), bit uniform over all 32 - the sign bit included,
    BECAUSE it is the case the old sum-of-squares checksum provably
    missed."""

    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False)

    def __post_init__(self):
        self._rng = np.random.default_rng(np.random.Philox(key=self.seed))

    def resolve(self, event: SDCEvent,
                leaf_sizes: Sequence[Tuple[int, int]]) -> SDCEvent:
        """Fill the event's unspecified leaf/elem/bit. ``leaf_sizes`` is
        ``[(full-tree leaf index, n_elements), ...]`` over the flippable
        (float32, non-empty) leaves - the same leaf space the in-graph
        ``scrub.digest.inject_bitflip`` indexes."""
        if event.resolved:
            return event
        assert leaf_sizes, "no flippable leaves in the state tree"
        idxs = np.asarray([i for i, _ in leaf_sizes])
        sizes = np.asarray([n for _, n in leaf_sizes], np.float64)
        leaf = event.leaf
        if leaf is None:
            leaf = int(self._rng.choice(idxs, p=sizes / sizes.sum()))
        n = dict(leaf_sizes).get(leaf)
        assert n, f"leaf {leaf} is not flippable (not float32 / empty)"
        elem = event.elem if event.elem is not None else int(self._rng.integers(n))
        bit = event.bit if event.bit is not None else int(self._rng.integers(32))
        return replace(event, leaf=leaf, elem=elem, bit=bit)


class SDCSchedule:
    """Deterministic corruption plan: dispatch step -> SDCEvent. Mirrors
    ``FailureSchedule``'s contract: input copied, events consumed by
    :meth:`take` (a replay never re-poisons a step it already survived)."""

    def __init__(self, events: Union[None, "SDCSchedule",
                                     Sequence[SDCEvent],
                                     Mapping[int, SDCEvent]] = None):
        if isinstance(events, SDCSchedule):
            self._by_step = dict(events._by_step)
        elif isinstance(events, Mapping):
            self._by_step = {int(s): e for s, e in events.items()}
        else:
            self._by_step = {}
            for e in events or []:
                if e.step in self._by_step:
                    # Not an assert: must survive `python -O`, and parse()
                    # reaches here outside its per-item try/except so the
                    # CLI sees this exact message.
                    raise ValueError(f"duplicate SDC event at step {e.step}")
                self._by_step[e.step] = e

    @classmethod
    def parse(cls, spec: str) -> "SDCSchedule":
        """CLI syntax: comma list of ``step:victim[:target[:leaf:elem:bit]]``
        (target ``grad``/``param``, default param; omitted leaf/elem/bit
        are drawn by the seeded SDCInjector)."""
        events = []
        for item in filter(None, (s.strip() for s in (spec or "").split(","))):
            parts = item.split(":")
            try:
                if len(parts) == 2:
                    step, victim = parts
                    events.append(SDCEvent(int(step), int(victim)))
                elif len(parts) == 3:
                    step, victim, target = parts
                    events.append(SDCEvent(int(step), int(victim), target))
                elif len(parts) == 6:
                    step, victim, target, leaf, elem, bit = parts
                    events.append(SDCEvent(int(step), int(victim), target,
                                           int(leaf), int(elem), int(bit)))
                else:
                    raise ValueError(len(parts))
            except (ValueError, AssertionError):
                raise ValueError(
                    f"bad SDC injection {item!r}: expected "
                    "step:victim[:target[:leaf:elem:bit]] "
                    "(e.g. --sdc-inject 5:2 or 5:2:param:0:17:31)"
                ) from None
        return cls(events)

    def take(self, step: int) -> Optional[SDCEvent]:
        return self._by_step.pop(step, None)

    def pending(self) -> int:
        return len(self._by_step)

    def __bool__(self) -> bool:
        return bool(self._by_step)


# ---------------------------------------------------------------------------
# gray failures (the chaos plane): hangs, slowdowns, drops, flaps
# ---------------------------------------------------------------------------

_CHAOS_KINDS = ("hang", "slow", "drop", "flap")


@dataclass(frozen=True)
class ChaosEvent:
    """One gray-failure injection, starting at a dispatch step.

    Kinds (the fault model beyond fail-stop + SDC):

    - ``hang``: the victim keeps heartbeating but its progress mark
      freezes - the alive-but-wedged process. ``duration=inf`` means it
      never comes back on its own (the detector must fire).
    - ``slow``: the victim's store operations are served ``factor``x
      slower - the fail-slow peer. Applied as a per-peer latency
      multiplier on gathers, it should trip rung deadlines, not shrinks.
    - ``drop``: the victim's heartbeats stop arriving while it otherwise
      runs - a partitioned liveness channel. Pure-silence suspicion.
    - ``flap``: a short drop (default ``duration=2.0``) that recovers
      before the suspicion window expires - the false-positive probe.
      A correct detector soft-suspects it and never shrinks.

    ``victim`` is a PHYSICAL slice id, like FailureSchedule's victims.
    ``duration``/``factor`` are on the injection clock (the FTSession's
    logical clock in simulation: 1.0 per dispatch-loop iteration).
    """

    step: int
    kind: str
    victim: int
    duration: float = float("inf")
    factor: float = 100.0

    def __post_init__(self):
        if self.kind not in _CHAOS_KINDS:
            raise ValueError(
                f"chaos kind must be one of {_CHAOS_KINDS}, got {self.kind!r}")
        if not (self.duration > 0):
            raise ValueError(f"chaos duration must be > 0, got {self.duration}")
        if self.kind == "slow" and not (self.factor > 0):
            raise ValueError(f"slow factor must be > 0, got {self.factor}")


class ChaosSchedule:
    """Deterministic gray-failure plan: dispatch step -> [ChaosEvent].
    Mirrors ``FailureSchedule``'s contract: input copied, events consumed
    by :meth:`take` (a replay never re-injects a step it already
    survived)."""

    _FLAP_DEFAULT_DURATION = 2.0

    def __init__(self, events: Union[None, "ChaosSchedule",
                                     Sequence[ChaosEvent],
                                     Mapping[int, Sequence[ChaosEvent]]] = None):
        self._by_step: Dict[int, List[ChaosEvent]] = {}
        if isinstance(events, ChaosSchedule):
            self._by_step = {s: list(evs) for s, evs in events._by_step.items()}
        elif isinstance(events, Mapping):
            self._by_step = {int(s): list(evs) for s, evs in events.items()}
        else:
            for e in events or []:
                self._by_step.setdefault(e.step, []).append(e)

    @classmethod
    def parse(cls, spec: str) -> "ChaosSchedule":
        """CLI syntax: comma list of ``step:kind:victim[:duration[:factor]]``
        with kind in hang/slow/drop/flap; duration accepts ``inf``.
        E.g. ``--chaos 5:hang:2,10:slow:1:20:50,30:flap:0``."""
        events = []
        for item in filter(None, (s.strip() for s in (spec or "").split(","))):
            parts = item.split(":")
            try:
                if not 3 <= len(parts) <= 5:
                    raise ValueError(len(parts))
                step, kind, victim = int(parts[0]), parts[1], int(parts[2])
                if len(parts) >= 4:
                    duration = float(parts[3])
                elif kind == "flap":
                    duration = cls._FLAP_DEFAULT_DURATION
                else:
                    duration = float("inf")
                factor = float(parts[4]) if len(parts) == 5 else 100.0
                events.append(ChaosEvent(step, kind, victim, duration, factor))
            except ValueError:
                raise ValueError(
                    f"bad chaos injection {item!r}: expected "
                    "step:kind:victim[:duration[:factor]] with kind in "
                    f"{'/'.join(_CHAOS_KINDS)} "
                    "(e.g. --chaos 5:hang:2 or 10:slow:1:inf:50)"
                ) from None
        return cls(events)

    def take(self, step: int) -> List[ChaosEvent]:
        return self._by_step.pop(step, [])

    def pending(self) -> int:
        return sum(len(v) for v in self._by_step.values())

    def __bool__(self) -> bool:
        return bool(self._by_step)


class ChaosState:
    """Active gray-failure tracker: which injections are live *now*.

    The injection side of the chaos plane: the FTSession activates events
    as its dispatch loop crosses their step, then consults this to shape
    heartbeats (hung -> beat without progress, dropped -> no beat) and
    store latency (slow -> factor). Events age out on the same clock the
    detector reads, so flaps recover deterministically."""

    def __init__(self):
        self._active: List[Tuple[ChaosEvent, float]] = []
        self._started: Dict[int, float] = {}

    def activate(self, event: ChaosEvent, now: float) -> None:
        self._active.append((event, now))
        # first injection time per victim: detection latency is measured
        # against the moment the gray failure began, not when it was seen
        self._started.setdefault(event.victim, now)

    def _live(self, now: float) -> Iterator[ChaosEvent]:
        self._active = [
            (e, t0) for e, t0 in self._active if now < t0 + e.duration
        ]
        return (e for e, _ in self._active)

    def hung(self, now: float) -> Set[int]:
        return {e.victim for e in self._live(now) if e.kind == "hang"}

    def dropped(self, now: float) -> Set[int]:
        """Victims whose heartbeats are being swallowed (drop + flap:
        a flap IS a short drop)."""
        return {e.victim for e in self._live(now) if e.kind in ("drop", "flap")}

    def slow_factor(self, peer: int, now: float) -> float:
        f = 1.0
        for e in self._live(now):
            if e.kind == "slow" and e.victim == peer:
                f = max(f, e.factor)
        return f

    def start_time(self, victim: int) -> Optional[float]:
        return self._started.get(victim)

    def any_active(self, now: float) -> bool:
        return any(True for _ in self._live(now))


class ChaosLatency:
    """Adapter handing per-peer injected latency to the store plane.

    Stores call :meth:`read_delay` per gather touch; the returned seconds
    are *virtual* - charged to the active Deadline's budget rather than
    slept, so chaos tests stay fast and deterministic while still
    exercising the deadline/quarantine machinery with realistic
    magnitudes (``base_s`` ~ one healthy shard fetch)."""

    def __init__(self, state: ChaosState, clock, base_s: float = 0.05):
        self.state = state
        self.clock = clock
        self.base_s = base_s

    def read_delay(self, peer: int) -> float:
        factor = self.state.slow_factor(peer, self.clock())
        if factor <= 1.0:
            return 0.0
        return self.base_s * factor
