"""Weibull fault injector (paper Sec. VII-B) + deterministic SDC events.

"It uses a Weibull Distribution to generate fault injection timings and
randomly kills one of the MPI processes after the generated time has
passed." Deterministic under a seed so experiments are reproducible.

Fail-stop is only half the fault model: :class:`SDCEvent` /
:class:`SDCInjector` / :class:`SDCSchedule` add *silent data corruption* -
a single bit flip in one mirror's view of the gradients or params,
with seeded leaf/element/bit selection so scrubbing tests and benchmarks
reproduce a corruption scenario exactly (the ``repro.scrub`` plane turns
these into in-graph flips via ``scrub.digest.encode_spec``).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Iterator, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np


@dataclass
class FaultInjector:
    """Generates (time, victim) failure events.

    ``scale`` is the Weibull scale (characteristic life) of the *whole
    system* inter-failure time; ``shape`` < 1 models infant-mortality-heavy
    HPC failure traces (k ~ 0.7 is typical in the literature), 1.0 is
    exponential.
    """

    n_slices: int
    scale: float = 100.0
    shape: float = 0.7
    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False)

    def __post_init__(self):
        self._rng = np.random.default_rng(np.random.Philox(key=self.seed))

    def next_event(self, alive: List[int]) -> Tuple[float, int]:
        """Time until next failure (from now) and the victim slice, chosen
        uniformly among alive slices (paper: "randomly kills one")."""
        dt = float(self.scale * self._rng.weibull(self.shape))
        victim = int(self._rng.choice(alive))
        return dt, victim

    def schedule(self, horizon: float, alive: List[int]) -> List[Tuple[float, int]]:
        """All failure events in [0, horizon) assuming no repairs change the
        alive set (callers re-draw after repairs if they do)."""
        events = []
        t = 0.0
        while True:
            dt, victim = self.next_event(alive)
            t += dt
            if t >= horizon:
                return events
            events.append((t, victim))


# ---------------------------------------------------------------------------
# silent data corruption (the repro.scrub fault model)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SDCEvent:
    """One bit flip in one slice's view of its state at one step.

    ``victim`` is a PHYSICAL slice id (like FailureSchedule's victims);
    ``target`` picks the poisoned space: ``"grad"`` models a transient
    compute fault (gone next step), ``"param"`` a poisoned resident copy
    (persists until repaired). ``leaf``/``elem``/``bit`` may be None -
    :meth:`SDCInjector.resolve` fills them deterministically from the
    seed, so a schedule written as just ``step:victim`` is reproducible.
    """

    step: int
    victim: int
    target: str = "param"
    leaf: Optional[int] = None
    elem: Optional[int] = None
    bit: Optional[int] = None

    def __post_init__(self):
        assert self.target in ("grad", "param"), self.target

    @property
    def resolved(self) -> bool:
        return None not in (self.leaf, self.elem, self.bit)


@dataclass
class SDCInjector:
    """Seeded leaf/element/bit selection (Philox, like FaultInjector):
    leaves weighted by element count (a flip lands uniformly over the
    state's elements), bit uniform over all 32 - the sign bit included,
    BECAUSE it is the case the old sum-of-squares checksum provably
    missed."""

    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False)

    def __post_init__(self):
        self._rng = np.random.default_rng(np.random.Philox(key=self.seed))

    def resolve(self, event: SDCEvent,
                leaf_sizes: Sequence[Tuple[int, int]]) -> SDCEvent:
        """Fill the event's unspecified leaf/elem/bit. ``leaf_sizes`` is
        ``[(full-tree leaf index, n_elements), ...]`` over the flippable
        (float32, non-empty) leaves - the same leaf space the in-graph
        ``scrub.digest.inject_bitflip`` indexes."""
        if event.resolved:
            return event
        assert leaf_sizes, "no flippable leaves in the state tree"
        idxs = np.asarray([i for i, _ in leaf_sizes])
        sizes = np.asarray([n for _, n in leaf_sizes], np.float64)
        leaf = event.leaf
        if leaf is None:
            leaf = int(self._rng.choice(idxs, p=sizes / sizes.sum()))
        n = dict(leaf_sizes).get(leaf)
        assert n, f"leaf {leaf} is not flippable (not float32 / empty)"
        elem = event.elem if event.elem is not None else int(self._rng.integers(n))
        bit = event.bit if event.bit is not None else int(self._rng.integers(32))
        return replace(event, leaf=leaf, elem=elem, bit=bit)


class SDCSchedule:
    """Deterministic corruption plan: dispatch step -> SDCEvent. Mirrors
    ``FailureSchedule``'s contract: input copied, events consumed by
    :meth:`take` (a replay never re-poisons a step it already survived)."""

    def __init__(self, events: Union[None, "SDCSchedule",
                                     Sequence[SDCEvent],
                                     Mapping[int, SDCEvent]] = None):
        if isinstance(events, SDCSchedule):
            self._by_step = dict(events._by_step)
        elif isinstance(events, Mapping):
            self._by_step = {int(s): e for s, e in events.items()}
        else:
            self._by_step = {}
            for e in events or []:
                assert e.step not in self._by_step, (
                    f"duplicate SDC event at step {e.step}")
                self._by_step[e.step] = e

    @classmethod
    def parse(cls, spec: str) -> "SDCSchedule":
        """CLI syntax: comma list of ``step:victim[:target[:leaf:elem:bit]]``
        (target ``grad``/``param``, default param; omitted leaf/elem/bit
        are drawn by the seeded SDCInjector)."""
        events = []
        for item in filter(None, (s.strip() for s in (spec or "").split(","))):
            parts = item.split(":")
            try:
                if len(parts) == 2:
                    step, victim = parts
                    events.append(SDCEvent(int(step), int(victim)))
                elif len(parts) == 3:
                    step, victim, target = parts
                    events.append(SDCEvent(int(step), int(victim), target))
                elif len(parts) == 6:
                    step, victim, target, leaf, elem, bit = parts
                    events.append(SDCEvent(int(step), int(victim), target,
                                           int(leaf), int(elem), int(bit)))
                else:
                    raise ValueError(len(parts))
            except (ValueError, AssertionError):
                raise ValueError(
                    f"bad SDC injection {item!r}: expected "
                    "step:victim[:target[:leaf:elem:bit]] "
                    "(e.g. --sdc-inject 5:2 or 5:2:param:0:17:31)"
                ) from None
        return cls(events)

    def take(self, step: int) -> Optional[SDCEvent]:
        return self._by_step.pop(step, None)

    def pending(self) -> int:
        return len(self._by_step)

    def __bool__(self) -> bool:
        return bool(self._by_step)
