"""Data plane: replica-aware train / serve steps (paper Secs. V-B, V-C).

The division of labour mirrors PartRePer-MPI exactly:

- the *data plane* (this module) is the native-MPI analogue - every hot-path
  byte moves through XLA collectives over ICI, compiled once, with NO
  failure-awareness inside the compiled program;
- the *control plane* (core/control_plane.py) is the ULFM analogue - it
  detects failures host-side and bumps the world generation, upon which the
  host dispatch loop stops calling this step and enters the error handler.

The step is a ``shard_map`` whose manual axes are the flattened
(pod, data) slice space; the 'model' axis remains a GSPMD auto axis so
tensor/expert parallelism inside the model uses XLA's tuned collectives.

Collective modes for the gradient reduction (ReplicationConfig):

- ``paper``  : faithful reproduction - ``psum`` over COMM_CMP groups
  (replicas form an inert concurrent group), then ``ppermute`` over
  CMP_REP_INTERCOMM forwards the reduced gradient to replicas
  ("collectives on computational processes, results sent to replicas").
- ``fused``  : beyond-paper - one all-reduce over the whole axis with
  replica contributions zeroed; replicas receive the result inside the
  same collective (no intercomm hop).
- ``branch`` : beyond-paper - mirrored pairs contribute grad/2 each, so
  replicas act as an extra branch of the reduction tree (valid because
  mirrored gradients are bit-identical).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig, ReplicationConfig, TrainConfig
from repro.core.replication import WorldState
from repro.models import model as M
from repro.optim import compression
from repro.optim.adamw import Optimizer

PyTree = Any


# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------


def manual_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def n_slices(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in manual_axes(mesh)]))


def _flat_slice_index(axes: Tuple[str, ...], mesh: Mesh):
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _tree_where(pred, a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _tree_scale(tree: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * s), tree)


def _tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: x + y, a, b)


# ---------------------------------------------------------------------------
# gradient reduction - the paper's communicator protocol
# ---------------------------------------------------------------------------


def reduce_gradients(grads: PyTree, *, idx, axes: Tuple[str, ...], mesh: Mesh,
                     world: WorldState, repl: ReplicationConfig) -> PyTree:
    """Replica-aware gradient reduction. Returns the summed gradient over
    computational slices, available on EVERY slice (cmp and rep).

    ``idx`` is this slice's flattened (pod, data) index, threaded in as a
    sharded iota input (not ``axis_index``: see ``_slice_iota``)."""
    topo = world.topo
    roles = world.roles_in_mesh_order()
    is_rep_by_pos = np.asarray(
        [topo.is_rep_mask()[r] for r in roles], dtype=np.float32
    )
    is_rep = jnp.asarray(is_rep_by_pos)[idx]

    if repl.grad_reduce_dtype == "bfloat16":
        # beyond-paper: reduce in bf16 (identical on every slice, so the
        # replica-mirror invariant is preserved bit-for-bit)
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)

    if topo.n_rep == 0 or repl.collective_mode == "fused":
        # single masked all-reduce over the whole (pod, data) space
        g = jax.tree.map(
            lambda x: jax.lax.psum(x * (1.0 - is_rep).astype(x.dtype), axes),
            grads,
        )
        return g

    if repl.collective_mode == "branch":
        has_partner = np.zeros(len(roles), dtype=np.float32)
        for c in topo.replica_map:
            has_partner[roles.index(c)] = 1.0
        hp = jnp.asarray(has_partner)[idx]
        w = jnp.where(is_rep > 0, 0.5, jnp.where(hp > 0, 0.5, 1.0))
        return jax.tree.map(
            lambda x: jax.lax.psum(x * w.astype(x.dtype), axes), grads
        )

    # --- paper-faithful: COMM_CMP group psum + CMP_REP_INTERCOMM ppermute ---
    cmp_groups = world.physical_groups(topo.comm_cmp_groups())
    intercomm = world.physical_perm(topo.intercomm_perm())
    g = jax.tree.map(
        lambda x: jax.lax.psum(x, axes, axis_index_groups=cmp_groups),
        grads,
    )
    # forward to replicas, optionally compressed (beyond-paper): both sides
    # consume decode(encode(g)) so mirrored state stays bit-identical.
    enc = compression.encode_tree(g, repl.intercomm_compression)
    g_local = compression.decode_tree(enc, repl.intercomm_compression, g)
    enc_rep = jax.tree.map(lambda x: jax.lax.ppermute(x, axes, intercomm), enc)
    g_rep = compression.decode_tree(enc_rep, repl.intercomm_compression, g)
    return _tree_where(is_rep > 0, g_rep, g_local)


def sdc_scrub(grads: PyTree, params: PyTree, *, idx, axes, mesh,
              world: WorldState, repl: ReplicationConfig) -> Dict[str, jnp.ndarray]:
    """RedMPI-style silent-data-corruption cross-check, per chunk.

    The old form reduced each slice to ONE sum-of-squares scalar - provably
    blind to sign flips (``x**2 == (-x)**2``) and unable to say which
    replica or which bytes are poisoned. Here every mirrored pair compares
    per-chunk ``[abs-sum, sum]`` digest rows (repro.scrub.digest) of both
    the gradients and the params, and the full per-slice digest tables are
    exported so the host can run a majority vote and a digest-guided
    partial restore.

    Returns metrics:

    - ``sdc``: global max |pair digest difference| (0.0 on healthy
      mirrors - bit-identical state digests to bit-identical rows);
    - ``sdc_chunks``: number of digest chunks disagreeing beyond
      ``repl.sdc_tol`` anywhere in the world;
    - ``sdc_grad_table`` / ``sdc_param_table``: (n_slices, n_chunks, 2)
      digest rows by mesh position (one-hot psum export).
    """
    from repro.scrub.digest import leaf_digest_matrix

    topo = world.topo
    roles = world.roles_in_mesh_order()
    sign_by_pos = np.asarray(
        [-1.0 if topo.is_rep_mask()[r] else 1.0 for r in roles], dtype=np.float32
    )
    paired = np.zeros(len(roles), dtype=np.float32)
    for j, c in enumerate(topo.replica_map):
        paired[roles.index(c)] = 1.0
        paired[roles.index(topo.n_comp + j)] = 1.0
    sign = jnp.asarray(sign_by_pos)[idx] * jnp.asarray(paired)[idx]
    pair_groups = world.physical_groups(topo.pair_groups())
    n_total = len(roles)
    onehot = (jnp.arange(n_total, dtype=jnp.int32) == idx).astype(jnp.float32)

    def scrub_one(tree):
        d = leaf_digest_matrix(tree, repl.sdc_chunk_elems)
        if d.shape[0] == 0:
            zero = jnp.zeros(())
            return zero, zero, jnp.zeros((n_total, 0, 2), jnp.float32)
        diff = jax.lax.psum(d * sign.astype(d.dtype), axes,
                            axis_index_groups=pair_groups)
        worst = jax.lax.pmax(jnp.max(jnp.abs(diff)), axes)
        bad = jnp.any(jnp.abs(diff) > repl.sdc_tol, axis=-1)
        n_bad = jax.lax.pmax(jnp.sum(bad.astype(jnp.float32)), axes)
        table = jax.lax.psum(onehot[:, None, None] * d[None, :, :], axes)
        return worst, n_bad, table

    g_worst, g_bad, g_table = scrub_one(grads)
    p_worst, p_bad, p_table = scrub_one(params)
    return {
        "sdc": jnp.maximum(g_worst, p_worst),
        "sdc_chunks": g_bad + p_bad,
        "sdc_grad_table": g_table,
        "sdc_param_table": p_table,
    }


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def build_train_step(
    model_cfg: ModelConfig,
    train_cfg: TrainConfig,
    repl: ReplicationConfig,
    mesh: Mesh,
    world: WorldState,
    optimizer: Optimizer,
    *,
    impl: str = "chunked",
    donate: bool = True,
    sdc_inject: bool = False,
) -> Callable:
    """Returns jitted ``step(params, opt_state, batch) -> (params, opt_state,
    metrics)``.

    ``batch`` arrays carry a leading global dim of n_live * per_slice; the
    host data pipeline lays shards out in mesh order with replica slices
    receiving a copy of their partner's shard (paper: replicas run the same
    ops on the same inputs).

    With ``sdc_inject=True`` the step takes a 4th argument: a traced (6,)
    int32 corruption spec (repro.scrub.digest) that arms an in-graph
    single-bit flip on one slice's view of the grads or params - armed and
    disarmed per call without recompiling. When ``repl.sdc_check`` is also
    on, a detected mismatch gates the optimizer update (``sdc`` metric
    above ``repl.sdc_tol``), so a poisoned step never lands in the state
    and mirrored trajectories stay bit-identical through detection.
    """
    from repro.scrub.digest import TARGET_GRAD, TARGET_PARAM, inject_bitflip

    axes = manual_axes(mesh)
    topo = world.topo
    inv_ncomp = 1.0 / topo.n_comp

    def per_slice(params, opt_state, batch, slice_iota, sdc_spec):
        # this slice's flat (pod, data) index: first element of the sharded
        # iota (each slice sees a length-1 shard). axis_index would be
        # equivalent but does not lower on jax 0.4.x when the model axis is
        # a GSPMD auto axis (PartitionId limitation - see repro.compat).
        idx = slice_iota[0]
        stored = params
        if sdc_inject:
            # the victim computes with a poisoned VIEW of its params; the
            # underlying stored tree is untouched (persistent corruption is
            # modelled by keeping the spec armed across steps)
            params = inject_bitflip(params, sdc_spec, idx, TARGET_PARAM)
        def loss_of(p, b):
            return M.loss_fn(p, b, model_cfg, impl=impl)

        if train_cfg.microbatches > 1:
            mb = train_cfg.microbatches

            def mb_body(acc, b):
                (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(params, b)
                return _tree_add(acc, g), (l, m["ce"])

            split = jax.tree.map(
                lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch
            )
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, ces) = jax.lax.scan(mb_body, zeros, split)
            grads = _tree_scale(grads, 1.0 / mb)
            loss, ce = jnp.mean(losses), jnp.mean(ces)
        else:
            (loss, m), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, batch
            )
            ce = m["ce"]

        if sdc_inject:
            grads = inject_bitflip(grads, sdc_spec, idx, TARGET_GRAD)

        metrics: Dict[str, jnp.ndarray] = {}
        clean = None
        if repl.sdc_check and topo.n_rep:
            metrics.update(sdc_scrub(
                grads, params, idx=idx, axes=axes, mesh=mesh, world=world,
                repl=repl,
            ))
            clean = metrics["sdc"] <= repl.sdc_tol

        g = reduce_gradients(
            grads, idx=idx, axes=axes, mesh=mesh, world=world, repl=repl
        )
        g = _tree_scale(g, inv_ncomp)

        params_new, opt_state_new, stats = optimizer.update(g, opt_state, params)
        if clean is not None:
            # corruption gate: a poisoned gradient entered the reduction, so
            # params_new is poisoned on EVERY slice - freeze the update (the
            # gate is a global reduction, so all slices agree) and let the
            # host recovery path decide (retry / vote / partial restore)
            params_new = _tree_where(clean, params_new, stored)
            opt_state_new = _tree_where(clean, opt_state_new, opt_state)

        # loss averaged over computational slices (scalar all-reduce)
        roles = world.roles_in_mesh_order()
        is_cmp = 1.0 - jnp.asarray(
            np.asarray([topo.is_rep_mask()[r] for r in roles], dtype=np.float32)
        )[idx]
        metrics["loss"] = jax.lax.psum(loss * is_cmp, axes) * inv_ncomp
        metrics["ce"] = jax.lax.psum(ce * is_cmp, axes) * inv_ncomp
        metrics.update(stats)
        return params_new, opt_state_new, metrics

    lead = axes if len(axes) > 1 else axes[0]
    batch_spec = P(lead)
    smapped = shard_map(
        per_slice,
        mesh=mesh,
        in_specs=(P(), P(), batch_spec, P(lead), P()),
        out_specs=(P(), P(), P()),
        axis_names=set(axes),
        check_vma=False,
    )
    n_total = n_slices(mesh)
    iota = jnp.arange(n_total, dtype=jnp.int32)

    if sdc_inject:
        def step(params, opt_state, batch, sdc_spec):
            return smapped(params, opt_state, batch, iota, sdc_spec)
    else:
        from repro.scrub.digest import NULL_SPEC

        null_spec = jnp.asarray(NULL_SPEC)

        def step(params, opt_state, batch):
            # constant disarmed spec: XLA folds the injection branch away
            return smapped(params, opt_state, batch, iota, null_spec)

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(step, donate_argnums=donate_argnums)


# ---------------------------------------------------------------------------
# serve step (batched decode with replica failover)
# ---------------------------------------------------------------------------


def build_serve_step(
    model_cfg: ModelConfig,
    repl: ReplicationConfig,
    mesh: Mesh,
    world: WorldState,
    *,
    shard_batch: bool = True,
    donate: bool = True,
    cache_example: Optional[PyTree] = None,
    per_slot_pos: bool = False,
) -> Callable:
    """Returns jitted ``serve(params, cache, tokens, pos) -> (next_tokens,
    cache)`` - one greedy decode step.

    Replica slices mirror their partner's requests (the request router feeds
    them the same tokens), so a promoted replica continues decoding from its
    own live KV cache with zero recovery cost - the serving analogue of the
    paper's process replication. Decode itself needs no cross-slice
    collectives; the model axis is GSPMD-managed.

    ``shard_batch=False`` replicates the request batch on every slice (used
    when global_batch < n_slices, e.g. the long_500k single-request cell).

    ``per_slot_pos=True`` lowers the slot-granular step: ``pos`` is a
    ``(B,)`` vector sharded with the batch, so every request slot advances
    its own sequence position - the serving gateway's continuous batcher
    admits a fresh request into a freed slot mid-decode while its
    neighbours keep decoding at their own depths.
    """
    axes = manual_axes(mesh)

    def per_slice(params, cache, tokens, pos):
        logits, cache = M.decode_step(params, cache, tokens, pos, model_cfg)
        # vocab is padded for sharding; never sample a pad id
        next_tok = jnp.argmax(
            logits[:, -1, : model_cfg.vocab_size], axis=-1
        ).astype(jnp.int32)
        return next_tok[:, None], cache

    lead = axes if len(axes) > 1 else axes[0]
    tok_spec = P(lead) if shard_batch else P()
    if cache_example is not None:
        from repro.dist.sharding import cache_manual_specs

        cache_spec = cache_manual_specs(
            cache_example, lead if shard_batch else None
        )
    else:
        # plain stacked caches (L, B, ...): batch dim is axis 1; grouped
        # stacks (gemma3) need cache_example for per-leaf placement
        cache_spec = P(None, lead) if shard_batch else P()

    pos_spec = tok_spec if per_slot_pos else P()
    smapped = shard_map(
        per_slice,
        mesh=mesh,
        in_specs=(P(), cache_spec, tok_spec, pos_spec),
        out_specs=(tok_spec, cache_spec),
        axis_names=set(axes),
        check_vma=False,
    )
    return jax.jit(smapped, donate_argnums=(1,) if donate else ())


# ---------------------------------------------------------------------------
# prefill step (inference-prefill shape cells)
# ---------------------------------------------------------------------------


def build_prefill_step(
    model_cfg: ModelConfig,
    repl: ReplicationConfig,
    mesh: Mesh,
    world: WorldState,
    *,
    impl: str = "chunked",
) -> Callable:
    """Returns jitted ``prefill(params, batch) -> logits`` (forward only,
    replica slices mirror their partner's requests)."""
    axes = manual_axes(mesh)

    def per_slice(params, batch):
        logits, _ = M.forward(params, batch, model_cfg, impl=impl)
        return logits

    batch_spec = P(axes if len(axes) > 1 else axes[0])
    smapped = shard_map(
        per_slice,
        mesh=mesh,
        in_specs=(P(), batch_spec),
        out_specs=batch_spec,
        axis_names=set(axes),
        check_vma=False,
    )
    return jax.jit(smapped)
