"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM or unsupported collectives fail HERE.

Roofline accounting: XLA cost_analysis counts a lax.scan body ONCE, so the
full scanned compile (the dry-run pass itself + memory analysis +
collective schedule) is complemented by small UNROLLED depth variants whose
compiled cost/collective stats give exact per-layer slopes; cell totals are
the affine extrapolation  M(depth) = intercept + depth . slope  solved per
segment kind. See EXPERIMENTS.md section "Dry-run".

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out runs/dryrun [--rdegree 0.0] [--mode paper] [--no-variants]
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax import: jax locks the device count at first init.

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import (
    ModelConfig,
    ReplicationConfig,
    ShapeConfig,
    TrainConfig,
    shape_applicable,
)
from repro.configs.registry import ARCHS, SHAPES, get_arch, get_shape
from repro.compat import set_mesh
from repro.core import data_plane as DP
from repro.core.replication import WorldState
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import parse_collectives
from repro.launch.specs import input_specs
from repro.optim.adamw import adamw
from repro.optim.schedules import constant

# TPU v5e hardware constants (roofline denominators)
HW = {
    "peak_flops_bf16": 197e12,  # per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link
}

# ---------------------------------------------------------------------------
# cell compilation
# ---------------------------------------------------------------------------


def build_and_lower(model: ModelConfig, shape: ShapeConfig, mesh, world,
                    repl: ReplicationConfig, *, impl: str = "chunked"):
    specs = input_specs(model, shape, world, mesh)
    opt = adamw(constant(1e-3))
    with set_mesh(mesh):
        if specs["kind"] == "train":
            step = DP.build_train_step(
                model, TrainConfig(), repl, mesh, world, opt, impl=impl
            )
            lowered = step.lower(specs["params"], specs["opt"], specs["batch"])
        elif specs["kind"] == "decode":
            step = DP.build_serve_step(
                model, repl, mesh, world, shard_batch=specs["shard_batch"],
                cache_example=specs["cache"],
            )
            lowered = step.lower(
                specs["params"], specs["cache"], specs["tokens"], specs["pos"]
            )
        else:
            step = DP.build_prefill_step(model, repl, mesh, world, impl=impl)
            lowered = step.lower(specs["params"], specs["batch"])
        compiled = lowered.compile()
    return lowered, compiled


def _metrics_of(compiled) -> Dict:
    ca = compiled.cost_analysis() or {}
    colls = parse_collectives(compiled.as_text())
    ma = compiled.memory_analysis()
    mem = {
        k: float(getattr(ma, k))
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
        )
        if hasattr(ma, k)
    }
    if hasattr(ma, "peak_memory_in_bytes"):
        mem["peak_memory_in_bytes"] = float(ma.peak_memory_in_bytes)
    if hasattr(ma, "alias_size_in_bytes"):
        mem["alias_size_in_bytes"] = float(ma.alias_size_in_bytes)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collectives": colls,
        "memory": mem,
    }


# ---------------------------------------------------------------------------
# depth variants for exact roofline terms
# ---------------------------------------------------------------------------


def depth_variants(model: ModelConfig) -> Tuple[List[Tuple[ModelConfig, Tuple[int, ...]]], Tuple[int, ...]]:
    """Small UNROLLED configs + their depth vectors, and the full config's
    depth vector. Metrics are affine in the depth vector."""

    def v(cfg, **kw):
        return dataclasses.replace(cfg, scan_layers=False, **kw)

    if model.attn_pattern == "local_global":
        r = model.local_global_ratio
        full_d = (model.n_layers // (r + 1),)
        return (
            [(v(model, n_layers=(r + 1)), (1,)), (v(model, n_layers=2 * (r + 1)), (2,))],
            full_d,
        )
    if model.family == "hybrid":
        n_glob = len(model.hybrid_global_layers)
        n_swa = model.n_layers - n_glob
        variants = [
            (v(model, n_layers=2, hybrid_global_layers=(0,)), (1, 1)),
            (v(model, n_layers=3, hybrid_global_layers=(0,)), (2, 1)),
            (v(model, n_layers=3, hybrid_global_layers=(0, 1)), (1, 2)),
        ]
        return variants, (n_swa, n_glob)
    if model.enc_layers:
        variants = [
            (v(model, n_layers=1, enc_layers=1), (1, 1)),
            (v(model, n_layers=2, enc_layers=1), (2, 1)),
            (v(model, n_layers=1, enc_layers=2), (1, 2)),
        ]
        return variants, (model.n_layers, model.enc_layers)
    variants = [(v(model, n_layers=1), (1,)), (v(model, n_layers=2), (2,))]
    return variants, (model.n_layers,)


def _affine_solve(depths: List[Tuple[int, ...]], values: List[float],
                  full: Tuple[int, ...]) -> float:
    """Solve values[i] = c + depths[i] . s exactly; eval at `full`."""
    A = np.array([[1.0] + list(d) for d in depths])
    y = np.array(values)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    return float(coef[0] + np.dot(coef[1:], np.array(full, dtype=float)))


def extrapolated_metrics(model: ModelConfig, shape: ShapeConfig, mesh, world,
                         repl: ReplicationConfig) -> Dict:
    """Compile the unrolled depth variants and extrapolate flops / bytes /
    per-kind collective bytes to the full depth."""
    variants, full_d = depth_variants(model)
    ms, ds = [], []
    for cfg_v, d in variants:
        _, compiled = build_and_lower(cfg_v, shape, mesh, world, repl)
        ms.append(_metrics_of(compiled))
        ds.append(d)
    out = {
        "flops": _affine_solve(ds, [m["flops"] for m in ms], full_d),
        "bytes_accessed": _affine_solve(
            ds, [m["bytes_accessed"] for m in ms], full_d
        ),
    }
    kinds = set()
    for m in ms:
        kinds |= set(m["collectives"])
    colls = {}
    for k in kinds:
        colls[k] = {
            "bytes": max(
                0.0,
                _affine_solve(
                    ds, [m["collectives"].get(k, {}).get("bytes", 0.0) for m in ms], full_d
                ),
            ),
            "count": max(
                0.0,
                _affine_solve(
                    ds,
                    [m["collectives"].get(k, {}).get("count", 0) for m in ms],
                    full_d,
                ),
            ),
        }
    out["collectives"] = colls
    return out


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------


def roofline_terms(metrics: Dict, model: ModelConfig, shape: ShapeConfig,
                   n_chips: int) -> Dict:
    """Three-term roofline. cost_analysis stats describe the PER-DEVICE SPMD
    program, so terms divide by per-chip peaks directly."""
    flops = metrics["flops"]
    bytes_hbm = metrics["bytes_accessed"]
    coll_bytes = sum(c["bytes"] for c in metrics.get("collectives", {}).values())
    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = bytes_hbm / HW["hbm_bw"]
    t_coll = coll_bytes / HW["ici_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    tokens = shape.seq_len * shape.global_batch
    n_active = model.active_param_count()
    mult = 6 if shape.kind == "train" else 2
    model_flops_total = mult * n_active * (
        tokens if shape.kind != "decode" else shape.global_batch
    )
    model_flops_per_chip = model_flops_total / n_chips
    return {
        **terms,
        "dominant": dominant,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flop_ratio": (model_flops_per_chip / flops) if flops else 0.0,
        "bound_time_s": max(terms.values()),
        "roofline_fraction": (
            model_flops_per_chip / HW["peak_flops_bf16"] / max(terms.values())
            if max(terms.values()) > 0
            else 0.0
        ),
    }


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, rdegree: float,
             mode: str, with_variants: bool, out_dir: str,
             remat: Optional[str] = None,
             grad_dtype: str = "float32") -> Dict:
    model = get_arch(arch)
    if remat:
        model = dataclasses.replace(model, remat=remat)
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(model, shape)
    mesh_tag = "multipod_2x16x16" if multi_pod else "pod_16x16"
    cell_id = f"{model.name}__{shape.name}__{mesh_tag}__r{rdegree}__{mode}"
    rec: Dict = {
        "arch": model.name,
        "shape": shape.name,
        "mesh": mesh_tag,
        "rdegree": rdegree,
        "mode": mode,
        "skipped": not ok,
        "skip_reason": reason,
    }
    if not ok:
        _save(out_dir, cell_id, rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_slices = int(np.prod([mesh.shape[a] for a in mesh.axis_names if a != "model"]))
    n_chips = int(np.prod(list(mesh.shape.values())))
    world = WorldState.create(n_slices, rdegree)
    repl = ReplicationConfig(
        rdegree=rdegree, collective_mode=mode, grad_reduce_dtype=grad_dtype
    )

    t0 = time.time()
    try:
        lowered, compiled = build_and_lower(model, shape, mesh, world, repl)
        rec["compile_s"] = time.time() - t0
        scanned = _metrics_of(compiled)
        rec["scanned"] = scanned
        rec["topology"] = {
            "n_chips": n_chips,
            "n_slices": n_slices,
            "n_comp": world.topo.n_comp,
            "n_rep": world.topo.n_rep,
        }
        if with_variants:
            t1 = time.time()
            extr = extrapolated_metrics(model, shape, mesh, world, repl)
            rec["variants_s"] = time.time() - t1
            merged = dict(extr)
            merged["memory"] = scanned["memory"]
            rec["extrapolated"] = extr
            rec["roofline"] = roofline_terms(merged, model, shape, n_chips)
        else:
            rec["roofline"] = roofline_terms(scanned, model, shape, n_chips)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 - a dry-run failure IS the signal
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    _save(out_dir, cell_id, rec)
    return rec


def _save(out_dir: str, cell_id: str, rec: Dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--rdegree", type=float, default=0.0)
    ap.add_argument("--mode", default="paper",
                    choices=["paper", "fused", "branch"])
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--no-variants", action="store_true",
                    help="skip the roofline depth variants (compile-only)")
    ap.add_argument("--remat", default=None, choices=[None, "none", "block"],
                    help="override the activation-checkpoint policy")
    ap.add_argument("--grad-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="gradient all-reduce dtype (beyond-paper lever)")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for multi in meshes:
        for a in archs:
            for s in shapes:
                mesh_tag = "multipod_2x16x16" if multi else "pod_16x16"
                cell_id = (
                    f"{get_arch(a).name}__{s}__{mesh_tag}__r{args.rdegree}"
                    f"__{args.mode}"
                )
                done = os.path.join(args.out, cell_id + ".json")
                if os.path.exists(done):
                    with open(done) as f:
                        old = json.load(f)
                    if old.get("ok") or old.get("skipped"):
                        print(f"[CACHED] {a} x {s} x {mesh_tag}", flush=True)
                        continue
                t0 = time.time()
                rec = run_cell(
                    a, s, multi_pod=multi, rdegree=args.rdegree, mode=args.mode,
                    with_variants=not args.no_variants and not multi,
                    out_dir=args.out, remat=args.remat,
                    grad_dtype=args.grad_dtype,
                )
                tag = "SKIP" if rec.get("skipped") else (
                    "OK" if rec.get("ok") else "FAIL"
                )
                n_fail += tag == "FAIL"
                dom = rec.get("roofline", {}).get("dominant", "-")
                frac = rec.get("roofline", {}).get("roofline_fraction", 0.0)
                print(
                    f"[{tag}] {a} x {s} x {'2x16x16' if multi else '16x16'} "
                    f"({time.time()-t0:.0f}s) dominant={dom} roofline={frac:.2f}"
                    + (f" :: {rec.get('error','')}" if tag == "FAIL" else ""),
                    flush=True,
                )
    print(f"dry-run complete, failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
