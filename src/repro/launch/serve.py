"""Fault-tolerant serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b \
        --tokens 64 --rdegree 1.0 --slices 4 --inject-failure 20:0
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--rdegree", type=float, default=1.0)
    ap.add_argument("--slices", type=int, default=4)
    ap.add_argument("--model-shards", type=int, default=2)
    ap.add_argument("--per-slice-batch", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--inject-failure", default="",
                    help="comma list of token:physical_slice injections")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="submit KV-cache snapshots to the K-way partner "
                         "store every N tokens (0 = off); an unmirrored "
                         "slice loss then re-decodes from the snapshot "
                         "instead of cold-starting")
    ap.add_argument("--redundancy", type=int, default=2,
                    help="K-way shard redundancy of the snapshot store")
    ap.add_argument("--delta", default="none", choices=["none", "bf16", "int8"],
                    help="delta-encode KV snapshot chunks against the previous "
                         "submit (repro.xfer; a mostly-append cache then ships "
                         "mostly zero chunks)")
    ap.add_argument("--checkpoint-dir", default="",
                    help="stack a durable rung under the KV-snapshot ladder "
                         "so decode state survives process death")
    ap.add_argument("--durable-delta", default="none",
                    choices=["none", "bf16", "int8"],
                    help="on-disk delta chains for the durable rung: the "
                         "append-only cache's unchanged chunks ship nothing "
                         "(ref-counted GC, bounded chain restore)")
    ap.add_argument("--durable-max-chain", type=int, default=4,
                    help="max step dirs a durable delta-chain restore reads "
                         "before a full self-contained snapshot is forced")
    ap.add_argument("--heal", default="none",
                    help="re-replication policy (repro.heal): none | eager | "
                         "deferred:K")
    ap.add_argument("--spares", type=int, default=0,
                    help="warm-standby slices the heal plane converts back "
                         "into replicas (their caches warm from the partner)")
    ap.add_argument("--gateway", action="store_true",
                    help="serve through repro.serving.gateway: bounded "
                         "admission, continuous batching (slots free at "
                         "EOS/max-new and refill mid-decode), invisible "
                         "mid-stream failover via front-priority requeue")
    ap.add_argument("--requests", type=int, default=16,
                    help="gateway mode: synthetic requests to serve")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="gateway admission-queue bound (backpressure "
                         "beyond it; must be >= 1)")
    ap.add_argument("--max-batch-slots", type=int, default=0,
                    help="gateway cap on concurrently decoding slots "
                         "(0 = every (cmp, lane) slot the world offers)")
    ap.add_argument("--page-tokens", type=int, default=128,
                    help="paged decode state: fixed page extent in tokens "
                         "per (slot, leaf) - pages ARE the transfer-plane "
                         "chunks, so snapshots/heals move only dirtied "
                         "tail pages (must be a positive power of two)")
    ap.add_argument("--prefix-share", dest="prefix_share",
                    action="store_true", default=True,
                    help="share prompt-prefix pages copy-on-write across "
                         "requests with a common prompt (paged mode only)")
    ap.add_argument("--no-prefix-share", dest="prefix_share",
                    action="store_false")
    ap.add_argument("--stall-window", type=int, default=0,
                    help="gateway fail-slow watchdog: a cmp role whose "
                         "bound slots stop advancing for more than this "
                         "many serve steps is evicted through the ordinary "
                         "recovery window and its requests requeued "
                         "(0 = crash detection only)")
    args = ap.parse_args()

    if os.environ.get("_REPRO_REEXEC") != "1":
        n = args.slices * args.model_shards
        os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        os.environ["_REPRO_REEXEC"] = "1"
        os.execv(sys.executable, [sys.executable] + sys.argv)

    from repro.configs.registry import get_arch, smoke_config
    from repro.ft import FailureSchedule
    from repro.serving.engine import ServeEngine

    model = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    failures = FailureSchedule.parse(args.inject_failure)

    from repro.serving.gateway import validate_bounds

    if args.gateway:
        max_slots = args.max_batch_slots or None
        validate_bounds(args.max_queue, max_slots,
                        page_tokens=args.page_tokens)
        serve_gateway(args, model, failures, max_slots)
        return

    # page_tokens is validated on BOTH paths: the lockstep engine pages
    # its snapshots too
    validate_bounds(args.max_queue, None, page_tokens=args.page_tokens)
    eng = ServeEngine(
        model,
        n_slices=args.slices,
        model_shards=args.model_shards,
        rdegree=args.rdegree,
        spares=args.spares,
        heal=args.heal,
        per_slice_batch=args.per_slice_batch,
        max_len=args.max_len,
        seed=args.seed,
        snapshot_every=args.snapshot_every,
        partner_redundancy=args.redundancy,
        delta=args.delta,
        checkpoint_dir=args.checkpoint_dir or None,
        durable_delta=args.durable_delta,
        durable_max_chain=args.durable_max_chain,
        page_tokens=args.page_tokens,
        prefix_share=args.prefix_share,
    )
    print(
        f"serving {model.name}: {eng.world.topo.n_comp} cmp + "
        f"{eng.world.topo.n_rep} rep slices + {len(eng.world.spares)} spares, "
        f"batch/slice={args.per_slice_batch}, heal={args.heal}"
    )
    t0 = time.time()
    toks = eng.decode(args.tokens, failures=failures)
    dt = time.time() - t0
    r = eng.report
    print(f"decoded {toks.shape} in {dt:.1f}s "
          f"({r.tokens_decoded / max(r.decode_seconds, 1e-9):.1f} tok/s raw)")
    for ev in r.events:
        print("EVENT:", ev)
    for src in r.restored_from:
        print("RESTORED:", src)
    for h in r.heals:
        print("HEALED:", h)
    print(f"promotes={r.promotes} requeued={r.requeued_requests} "
          f"healed={r.healed_replicas} failover={r.failover_seconds:.2f}s")
    print("sample output ids:", toks[0, 0, :16].tolist())


def serve_gateway(args, model, failures, max_slots) -> None:
    """Drive a synthetic open-loop workload through the gateway."""
    import numpy as np

    from repro.serving.engine import ServeEngine
    from repro.serving.gateway import ServeGateway

    assert not (args.snapshot_every or args.checkpoint_dir), (
        "--gateway recovers by requeue (pinned prefixes), not snapshots"
    )
    eng = ServeEngine(
        model,
        n_slices=args.slices,
        model_shards=args.model_shards,
        rdegree=args.rdegree,
        spares=args.spares,
        heal=args.heal,
        per_slice_batch=args.per_slice_batch,
        max_len=args.max_len,
        seed=args.seed,
        slot_granular=True,
        page_tokens=args.page_tokens,
        prefix_share=args.prefix_share,
    )
    gw = ServeGateway(eng, max_queue=args.max_queue, max_batch_slots=max_slots,
                      stall_window=args.stall_window or None)
    print(
        f"gateway serving {model.name}: {eng.world.topo.n_comp} cmp + "
        f"{eng.world.topo.n_rep} rep slices + {len(eng.world.spares)} spares, "
        f"{gw.registry.n_slots} slots (cap {max_slots or 'none'}), "
        f"queue<={args.max_queue}"
    )
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        prompt = rng.integers(1, model.vocab_size, size=int(rng.integers(2, 8)))
        gw.submit(prompt, max_new=args.tokens, at_step=i // 2)
    t0 = time.time()
    gw.serve(max_steps=100_000, failures=failures)
    dt = time.time() - t0
    for ev in eng.report.events:
        print("EVENT:", ev)
    for ev in gw.registry.events:
        print("CAPACITY:", ev)
    s = gw.summary()
    done = sum(1 for st in gw.streams.values() if st.done)
    print(f"served {done}/{args.requests} requests in {dt:.1f}s "
          f"({s['tokens_decoded'] / max(dt, 1e-9):.1f} tok/s wall)")
    print(f"steps={s['steps']} completed={s['completed']} "
          f"rejected={s['rejected']} requeues={s['requeues']} "
          f"ttft_p50={s['ttft_p50_steps']:.0f} "
          f"ttft_p99={s['ttft_p99_steps']:.0f} steps")
    print("request 0 ids:", gw.streams[0].tokens[:16])


if __name__ == "__main__":
    main()
