"""HLO-text collective accounting (no jax import, no env side effects).

Used by launch/dryrun.py; kept separate so tests and tools can import the
parser without triggering the dry-run's XLA_FLAGS device-count override.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2, "u16": 2,
}

_COLL_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_OPND_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _nbytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    """Sum moved bytes per collective kind from optimized HLO text.

    Convention: all-reduce / all-to-all / collective-permute count operand
    bytes; all-gather counts result bytes (each device materialises the
    gather); reduce-scatter counts operand bytes.
    """
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        res_dtype, res_dims, kind = m.group(1), m.group(2), m.group(3)
        res_bytes = _nbytes(res_dtype, res_dims)
        paren = line[m.end() - 1 :]
        opnds = _OPND_RE.findall(paren)
        op_bytes = sum(_nbytes(d, s) for d, s in opnds) or res_bytes
        moved = res_bytes if kind == "all-gather" else op_bytes
        rec = out.setdefault(kind, {"count": 0, "bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += float(moved)
    return out
