"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests and benches see 1 CPU device; only
launch/dryrun.py forces 512 host devices before any jax import).
"""
from __future__ import annotations

from repro.compat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod: a leading
    pod axis, 2 x 16 x 16 = 512 chips. The paper's replication slices live
    on the flattened (pod, data) axes; 'model' is the GSPMD auto axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(data: int, model: int, pods: int = 1):
    """Arbitrary mesh for tests / benches on fake or real devices."""
    if pods > 1:
        return _make_mesh((pods, data, model), ("pod", "data", "model"))
    return _make_mesh((data, model), ("data", "model"))
