"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

Weak-type-correct, shardable, zero device allocation. The same builders are
used by the real train/serve drivers (with np arrays instead of SDS).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.replication import WorldState
from repro.dist.sharding import (
    cache_shardings,
    opt_shardings,
    param_shardings,
)
from repro.models import model as M
from repro.optim.adamw import adamw
from repro.optim.schedules import constant

PyTree = Any

# encoder context for enc-dec architectures in decode shapes (DESIGN.md)
ENCDEC_DECODE_ENC_LEN = 4096


def slice_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def lead_axes(mesh: Mesh):
    axes = slice_axes(mesh)
    return axes if len(axes) > 1 else axes[0]


def per_slice_batch(shape: ShapeConfig, world: WorldState) -> Tuple[int, bool]:
    """(per-slice batch, shard_batch). global_batch < n_comp -> replicate."""
    n_comp = world.topo.n_comp
    if shape.global_batch < n_comp:
        return shape.global_batch, False
    return -(-shape.global_batch // n_comp), True


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------


def seq_layout(model: ModelConfig, shape: ShapeConfig) -> Dict[str, int]:
    """How seq_len splits across modality streams (see DESIGN.md):
    vlm: n_prefix patches + text; encdec: seq/2 frames + seq/2 tokens."""
    S = shape.seq_len
    if model.family == "vlm" and model.n_prefix_embeds:
        return {"text": S - model.n_prefix_embeds, "patches": model.n_prefix_embeds}
    if model.enc_layers:
        return {"text": S // 2, "frames": S // 2}
    return {"text": S}


def train_batch_specs(model: ModelConfig, shape: ShapeConfig, world: WorldState,
                      mesh: Mesh) -> Dict[str, jax.ShapeDtypeStruct]:
    per, shard = per_slice_batch(shape, world)
    rows = world.topo.n_slices * per if shard else shape.global_batch
    lead = lead_axes(mesh) if shard else None
    layout = seq_layout(model, shape)
    sh = lambda *rest: NamedSharding(mesh, P(lead, *rest))
    specs = {"tokens": _sds((rows, layout["text"]), jnp.int32, sh(None))}
    if "patches" in layout:
        specs["patches"] = _sds(
            (rows, layout["patches"], model.d_model), jnp.float32, sh(None, None)
        )
    if "frames" in layout:
        specs["frames"] = _sds(
            (rows, layout["frames"], model.d_model), jnp.float32, sh(None, None)
        )
    return specs


def decode_input_specs(model: ModelConfig, shape: ShapeConfig, world: WorldState,
                       mesh: Mesh, cache_dtype=jnp.bfloat16):
    """(cache_specs, token_specs, pos_spec, shard_batch) for serve_step."""
    per, shard = per_slice_batch(shape, world)
    rows = world.topo.n_slices * per if shard else shape.global_batch
    lead = lead_axes(mesh) if shard else None

    enc_len = ENCDEC_DECODE_ENC_LEN if model.enc_layers else 0
    cache_shape = jax.eval_shape(
        lambda: M.init_cache(model, rows, max_len=shape.seq_len,
                             enc_len=enc_len, dtype=cache_dtype)
    )
    cshard = cache_shardings(cache_shape, mesh, shard_batch=shard)
    cache_specs = jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), cache_shape, cshard
    )
    tok = _sds((rows, 1), jnp.int32, NamedSharding(mesh, P(lead, None)))
    pos = _sds((), jnp.int32, NamedSharding(mesh, P()))
    return cache_specs, tok, pos, shard


# ---------------------------------------------------------------------------
# state specs
# ---------------------------------------------------------------------------


def state_specs(model: ModelConfig, mesh: Mesh, *, with_opt: bool = True):
    """(params_specs, opt_specs) as sharded ShapeDtypeStructs."""
    pshape = jax.eval_shape(lambda: M.init(jax.random.PRNGKey(0), model))
    pshard = param_shardings(pshape, mesh, model)
    params_specs = jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), pshape, pshard
    )
    if not with_opt:
        return params_specs, None
    opt = adamw(constant(1e-3))
    oshape = jax.eval_shape(opt.init, pshape)
    oshard = opt_shardings(oshape, pshard, mesh)
    opt_specs = jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), oshape, oshard
    )
    return params_specs, opt_specs


def input_specs(model: ModelConfig, shape: ShapeConfig, world: WorldState,
                mesh: Mesh) -> Dict[str, Any]:
    """Every input of the lowered step for this cell, keyed by role."""
    if shape.kind == "decode":
        cache, tok, pos, shard = decode_input_specs(model, shape, world, mesh)
        params, _ = state_specs(model, mesh, with_opt=False)
        return {
            "kind": "decode",
            "params": params,
            "cache": cache,
            "tokens": tok,
            "pos": pos,
            "shard_batch": shard,
        }
    params, opt = state_specs(model, mesh, with_opt=(shape.kind == "train"))
    batch = train_batch_specs(model, shape, world, mesh)
    if shape.kind == "train":
        return {"kind": "train", "params": params, "opt": opt, "batch": batch}
    return {"kind": "prefill", "params": params, "batch": batch}
