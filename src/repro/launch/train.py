"""Fault-tolerant training driver (the end-to-end entry point).

Wires every layer together: config registry -> mesh -> replica topology ->
data pipeline -> replicated train step (data plane) -> control plane guard
-> checkpointing -> failure handling (promote / elastic restart) -> replay.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --steps 200 --rdegree 0.25 --slices 4 --model-shards 2 \
        --inject-failure 50:0

On this CPU container run it with a reduced config (--smoke, default); the
same driver lowers the full config on a real TPU mesh.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--rdegree", type=float, default=0.25)
    ap.add_argument("--mode", default="paper", choices=["paper", "fused", "branch"])
    ap.add_argument("--slices", type=int, default=4)
    ap.add_argument("--model-shards", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--per-slice-batch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--redundancy", type=int, default=2,
                    help="K-way shard redundancy of the level-1 partner-memory "
                         "store (repro.store.PartnerMemoryStore)")
    ap.add_argument("--delta", default="none", choices=["none", "bf16", "int8"],
                    help="delta-encode snapshot chunks against the previous "
                         "submit (repro.xfer; verified byte-exact per chunk, "
                         "restores stay bit-identical)")
    ap.add_argument("--durable-delta", default="none",
                    choices=["none", "bf16", "int8"],
                    help="extend delta encoding to the DurableStore: "
                         "step dirs ship only changed chunks + a manifest "
                         "referencing base chunks, with ref-counted GC and "
                         "a full snapshot forced every --durable-max-chain "
                         "submits (needs --checkpoint-dir)")
    ap.add_argument("--durable-max-chain", type=int, default=4,
                    help="max step dirs a durable delta-chain restore reads "
                         "before a full self-contained snapshot is forced")
    ap.add_argument("--chunk-kib", type=int, default=0,
                    help="transfer-plane stripe size in KiB (0 = default 1024)")
    ap.add_argument("--no-pipeline", dest="pipeline", action="store_false",
                    help="submit snapshots synchronously instead of on the "
                         "transfer plane's double-buffered stager")
    ap.add_argument("--heal", default="none",
                    help="re-replication policy (repro.heal): none | eager | "
                         "deferred:K - converts spares back into replicas of "
                         "the most-exposed roles after failures")
    ap.add_argument("--spares", type=int, default=0,
                    help="warm-standby slices reserved outside the cmp/rep "
                         "split; the heal plane consumes them to restore "
                         "rdegree (and to backfill lost roles)")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced same-family config (CPU container default)")
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="full assigned config (real accelerator mesh)")
    ap.add_argument("--inject-failure", default="",
                    help="comma list of step:physical_slice failure injections")
    ap.add_argument("--sdc-check", action="store_true",
                    help="online SDC scrubbing (repro.scrub): mirrored pairs "
                         "cross-check per-chunk [abs-sum, sum] digests of "
                         "grads + params inside every step; a mismatch gates "
                         "the update and enters the corruption handler "
                         "(vote -> digest-guided partial restore)")
    ap.add_argument("--sdc-inject", default="",
                    help="comma list of step:victim[:target[:leaf:elem:bit]] "
                         "bit-flip injections (target grad|param; omitted "
                         "leaf/elem/bit drawn by the seeded injector); "
                         "implies --sdc-check")
    ap.add_argument("--sdc-tol", type=float, default=0.0,
                    help="digest comparison tolerance (0.0: mirrored pairs "
                         "are bit-identical, any difference is corruption)")
    ap.add_argument("--chaos", default="",
                    help="comma list of step:kind:victim[:duration[:factor]] "
                         "gray-failure injections (kind hang|slow|drop|flap; "
                         "duration/factor on the liveness clock, 'inf' ok); "
                         "needs --suspicion-window")
    ap.add_argument("--suspicion-window", type=float, default=0.0,
                    help="turn the liveness detector ON: heartbeats carry "
                         "dispatch progress, and a slice silent or stalled "
                         "longer than this many loop iterations is treated "
                         "as failed (0 = report-driven detection only)")
    ap.add_argument("--rung-deadline", type=float, default=0.0,
                    help="per-rung restore budget in seconds: a stalled or "
                         "fail-slow store gather is quarantined/abandoned "
                         "within this budget and the recovery ladder falls "
                         "to the next level (0 = unbounded)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N fake host devices (subprocess re-exec)")
    args = ap.parse_args()

    need = args.slices * args.model_shards
    if args.devices or (os.environ.get("_REPRO_REEXEC") != "1"):
        n = args.devices or need
        os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        os.environ["_REPRO_REEXEC"] = "1"
        os.execv(sys.executable, [sys.executable] + sys.argv)

    import jax  # noqa: E402  (after XLA_FLAGS)

    from repro.configs.registry import get_arch, smoke_config
    from repro.core.fault_injector import ChaosSchedule, SDCSchedule
    from repro.core.simulator import SimCluster
    from repro.ft import FailureSchedule

    model = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    failures = FailureSchedule.parse(args.inject_failure)
    sdc = SDCSchedule.parse(args.sdc_inject)
    sdc_check = args.sdc_check or bool(sdc)
    chaos = ChaosSchedule.parse(args.chaos)
    if chaos and args.suspicion_window <= 0:
        ap.error("--chaos needs --suspicion-window > 0 (the liveness "
                 "detector is what catches gray failures)")

    sim = SimCluster(
        model,
        n_slices=args.slices,
        model_shards=args.model_shards,
        rdegree=args.rdegree,
        spares=args.spares,
        heal=args.heal,
        collective_mode=args.mode,
        per_slice_batch=args.per_slice_batch,
        seq_len=args.seq_len,
        lr=args.lr,
        seed=args.seed,
        checkpoint_dir=args.checkpoint_dir or None,
        checkpoint_every=args.checkpoint_every,
        partner_redundancy=args.redundancy,
        microbatches=args.microbatches,
        delta=args.delta,
        chunk_bytes=args.chunk_kib * 1024,
        pipeline=args.pipeline,
        durable_delta=args.durable_delta,
        durable_max_chain=args.durable_max_chain,
        sdc_check=sdc_check,
        sdc_inject=bool(sdc),
        sdc_tol=args.sdc_tol,
        sdc_seed=args.seed,
        suspicion_window=args.suspicion_window,
        rung_deadline_s=args.rung_deadline,
    )
    print(
        f"world: {sim.world.topo.n_comp} computational + {sim.world.topo.n_rep} "
        f"replica slices x {args.model_shards} model shards "
        f"+ {len(sim.world.spares)} spares "
        f"({model.name}, mode={args.mode}, heal={args.heal})"
    )
    print("recovery ladder:", " -> ".join(
        f"L{s.level}:{s.name}" for s in sim.ladder) or "(none)")
    if sdc_check:
        print(f"scrub: sdc_check on (tol={args.sdc_tol:g}), "
              f"{sdc.pending() if sdc else 0} injection(s) scheduled")
    if args.suspicion_window > 0:
        print(f"liveness: suspicion_window={args.suspicion_window:g} "
              f"rung_deadline={args.rung_deadline:g}s, "
              f"{chaos.pending() if chaos else 0} chaos injection(s) scheduled")
    t0 = time.time()
    report = sim.run(args.steps, failures=failures, sdc=sdc or None,
                     chaos=chaos or None)
    dt = time.time() - t0
    for i, loss in enumerate(report.losses):
        if i % 10 == 0 or i == len(report.losses) - 1:
            print(f"step {i:5d} loss {loss:.4f}")
    for ev in report.events:
        print("EVENT:", ev)
    for src in report.restored_from:
        print("RESTORED:", src)
    for h in report.heals:
        print("HEALED:", h)
    for i, det in enumerate(report.detections):
        lat = report.detect_latency[i] if i < len(report.detect_latency) else -1
        print(f"DETECTED: {det} latency={lat:g}")
    for q in report.quarantines:
        print("QUARANTINED:", q)
    print(
        f"done: {report.steps_completed} steps in {dt:.1f}s "
        f"(app {report.app_seconds:.1f}s, error-handler {report.handler_seconds:.1f}s) "
        f"failures={report.failures} promotes={report.promotes} "
        f"restarts={report.restarts} replayed={report.replayed_steps} "
        f"healed={report.healed_replicas} exposure={report.exposure_steps} "
        f"final_rdegree={sim.world.topo.rdegree:.2f}"
    )
    if args.suspicion_window > 0:
        print(
            f"liveness: detections={len(report.detections)} "
            f"stalled_units={report.stalled_units} flaps={report.flaps} "
            f"quarantines={len(report.quarantines)}"
        )
    if sdc_check:
        print(
            f"scrub: detected={report.sdc_detected} "
            f"transient={report.sdc_transient} repairs={report.sdc_repairs} "
            f"partial-restore {report.sdc_bytes_moved}/"
            f"{report.sdc_bytes_full}B moved"
        )


if __name__ == "__main__":
    main()
