"""Pure-jnp oracle for the flash attention kernel.

Materialises the full (Sq, Sk) score matrix - only for validation shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0):
    """q (B,Sq,H,hd); k/v (B,Sk,KV,hd) with H % KV == 0. fp32 math."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    n_rep = H // KV
    k = jnp.repeat(k, n_rep, axis=2)
    v = jnp.repeat(v, n_rep, axis=2)
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window and window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
