"""jit'd public wrapper for the flash attention kernel.

On TPU this lowers to the Pallas kernel (``interpret=False``); on CPU (this
container) the kernel body is interpreted, which validates the exact kernel
logic against the ref oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "block_q", "block_k")
)
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128, block_k: int = 128):
    """Public entry point. q (B,Sq,H,hd); k/v (B,Sk,KV,hd).

    Pads sequence dims up to block multiples, runs the kernel, slices back.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Sk))
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        if not causal:
            # non-causal must not attend to padded keys: use a window trick is
            # wrong here, so mask via a huge negative bias on padded keys by
            # zeroing v and renormalising is incorrect too; instead extend the
            # causal-style mask by treating pad as future via window=Sk when
            # callers pass unpadded Sk. Simplest correct route: fall back to
            # block sizes that divide Sk.
            raise ValueError(
                f"non-causal flash requires Sk % block_k == 0 (Sk={Sk}, bk={bk})"
            )
    out = flash_attention_kernel(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, interpret=not _on_tpu(),
    )
    if pad_q:
        out = out[:, :Sq]
    return out
