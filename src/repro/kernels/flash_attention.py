"""Flash attention Pallas TPU kernel.

TPU-native adaptation: blockwise online softmax with

- grid ``(B*H, num_q_blocks, num_kv_blocks)`` - the innermost (kv) axis is
  sequential on TPU, so running max / denominator / accumulator live in VMEM
  scratch that persists across kv iterations;
- q/k/v tiles staged HBM->VMEM by ``BlockSpec``; tile shapes are multiples
  of 128 on the lane dim and of 8 on the sublane dim so the MXU sees aligned
  matmuls;
- GQA handled in the index map: the kv block index is ``head // n_rep``, so
  kv tiles are fetched once per kv head, not per q head;
- causal + sliding-window masking in-kernel; fully-masked kv blocks write
  nothing (``pl.when`` guards), which matters for the banded SWA case.

Validated on CPU via ``interpret=True`` against ``flash_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int, softcap: float,
                 block_q: int, block_k: int, num_kv_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # static skip would need a custom grid; mask instead, but skip the matmul
    # entirely when the whole block is above the diagonal (causal) or outside
    # the window band.
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window

    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    if causal or window > 0:
        # whole-block visibility test (static per grid point would be ideal;
        # pl.when keeps it on-device and skips the MXU work)
        any_visible = jnp.any(mask)
        pl.when(any_visible)(_compute)
    else:
        _compute()

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, block_q: int = 128,
                           block_k: int = 128, interpret: bool = True):
    """q (B,Sq,H,hd); k/v (B,Sk,KV,hd). Returns (B,Sq,H,hd).

    Sq % block_q == 0 and Sk % block_k == 0 (callers pad).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    n_rep = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = Sq // block_q
    nk = Sk // block_k

    # (B*H, S, hd) layout: head-major batch so a grid step owns one head.
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)

    kernel = functools.partial(
        _attn_kernel,
        scale=1.0 / np.sqrt(hd),
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        num_kv_blocks=nk,
    )

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, iq, ik, n_rep=n_rep: (bh // n_rep, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, iq, ik, n_rep=n_rep: (bh // n_rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denominator
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
