"""Pure-jnp oracle for the fused RMSNorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, scale, *, eps: float = 1e-5):
    """x (..., D); scale (D,). Gemma-style (1 + scale) weighting, fp32 math."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
