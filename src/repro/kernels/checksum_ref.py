"""Pure-jnp oracle for the fused per-chunk checksum kernel."""
from __future__ import annotations

import jax.numpy as jnp


def checksum_ref(x2d):
    """x2d (n_chunks, chunk_elems) -> (n_chunks, 2): [abs-sum, sum], fp32."""
    xf = x2d.astype(jnp.float32)
    return jnp.stack([jnp.sum(jnp.abs(xf), axis=-1), jnp.sum(xf, axis=-1)], axis=-1)
