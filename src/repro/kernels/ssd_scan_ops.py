"""jit'd public wrapper for the SSD scan kernel (pads, dispatches, slices)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import ssd_scan_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "h_blk"))
def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk: int = 128, h_blk: int = 8):
    """Public SSD scan. Returns (y, final_state) to match the chunked path.

    The Pallas kernel emits y; the final state (needed only when chaining
    prefill->decode) is recomputed cheaply from the last chunk here.
    """
    B, S, nh, hd = x.shape
    pad_s = (-S) % chunk
    pad_h = (-nh) % h_blk
    if pad_s:
        x = jnp.pad(x, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_s), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad_s), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad_s), (0, 0)))
    if pad_h:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad_h), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad_h)))
        A = jnp.pad(A, (0, pad_h))
        D = jnp.pad(D, (0, pad_h))
    y = ssd_scan_kernel(
        x, dt, A, Bm, Cm, D, chunk=chunk, h_blk=h_blk, interpret=not _on_tpu()
    )
    y = y[:, :S, :nh, :]
    state = _final_state(x, dt, A, Bm, chunk=chunk)[:, :nh]
    return y, state


def _final_state(x, dt, A, Bm, *, chunk: int):
    """State after the (padded) sequence - one decayed outer-product pass.

    Padded steps contribute dt=0 -> exp(0)=1 decay and zero update, so
    padding is state-neutral.
    """
    B, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    nc = S // chunk
    xf = x.astype(jnp.float32).reshape(B, nc, chunk, nh, hd)
    dtf = dt.astype(jnp.float32).reshape(B, nc, chunk, nh)
    Bf = Bm.astype(jnp.float32).reshape(B, nc, chunk, ds)
    dA = dtf * A
    cs = jnp.cumsum(dA, axis=2)
    total = cs[:, :, -1]  # (B,nc,nh)
    sdecay = jnp.exp(total[:, :, None, :] - cs) * dtf
    S_c = jnp.einsum("bnjh,bnjhd,bnjs->bnhds", sdecay, xf, Bf)

    def step(s, inp):
        sc, tot = inp
        return s * jnp.exp(tot)[:, :, None, None] + sc, None

    s0 = jnp.zeros((B, nh, hd, ds), jnp.float32)
    s_last, _ = jax.lax.scan(
        step, s0, (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(total, 1, 0))
    )
    return s_last
