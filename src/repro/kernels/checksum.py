"""Fused per-chunk checksum/digest Pallas TPU kernel.

One pass over a ``(n_chunks, chunk_elems)`` fp32 view of a flattened state
computes TWO reduction columns per chunk - ``abs``-sum and plain sum - so
clone/heal verification prices one HBM read instead of the old per-leaf
host loop (``core/state_transfer._checksum`` round-tripped every leaf
through a Python ``sum``). The chunk axis is the sublane tile (grid-
blocked); ``chunk_elems`` is the lane dim and should be a 128-multiple for
full VPU lanes. The plain-sum column adds sign sensitivity (compensating
sign flips now change the digest); a permutation that preserves each
chunk's value multiset remains invisible - callers needing that guarantee
use the per-leaf ``bit_exact`` path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _checksum_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.stack(
        [jnp.sum(jnp.abs(x), axis=-1), jnp.sum(x, axis=-1)], axis=-1
    )


def checksum_kernel(x2d, *, block_chunks: int = 8, interpret: bool = True):
    """x2d (n_chunks, chunk_elems) -> (n_chunks, 2) fp32 digests."""
    n, c = x2d.shape
    block_chunks = min(block_chunks, n)
    pad = (-n) % block_chunks
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    grid = (x2d.shape[0] // block_chunks,)
    out = pl.pallas_call(
        _checksum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_chunks, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_chunks, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x2d.shape[0], 2), jnp.float32),
        interpret=interpret,
    )(x2d)
    return out[:n] if pad else out
