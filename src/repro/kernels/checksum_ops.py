"""jit'd public wrapper for the fused per-chunk checksum kernel.

``chunk_digests`` takes the flattened fp32 view of a state (1-D), pads it
to a whole number of ``chunk_elems``-wide chunks and returns the
``(n_chunks, 2)`` digest matrix in one fused pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.checksum import checksum_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk_elems",))
def chunk_digests(x, *, chunk_elems: int):
    """x: 1-D array (any real dtype) -> (ceil(n/chunk_elems), 2) fp32."""
    x = x.astype(jnp.float32)
    n = x.shape[0]
    if n == 0:  # all-empty-leaf stream: no chunks, no kernel launch
        return jnp.zeros((0, 2), jnp.float32)
    pad = (-n) % chunk_elems
    if pad:
        x = jnp.pad(x, (0, pad))
    x2d = x.reshape(-1, chunk_elems)
    return checksum_kernel(x2d, interpret=not _on_tpu())
