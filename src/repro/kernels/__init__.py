# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# checksum{,_ref,_ops}.py: fused per-chunk digest kernel backing the
# repro.xfer transfer plane's clone/heal verification (the paper's
# integrity check over the process-image transfer, Sec. III-A, done
# on-device in one pass instead of a per-leaf host loop).
