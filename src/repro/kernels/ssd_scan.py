"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

TPU-native adaptation of the SSD algorithm (Dao & Gu 2024): the GPU version
leans on warp-level matmul fragments; on TPU we express each chunk's work as
MXU matmuls over VMEM tiles and exploit the *sequential* TPU grid to carry
the inter-chunk SSM state in VMEM scratch (no HBM round-trip for state).

Grid: ``(B, n_head_blocks, n_chunks)`` - chunks innermost/sequential.
Per step the kernel:
  1. computes in-chunk cumulative log-decays (fp32),
  2. intra-chunk output via two MXU matmuls (C.B^T masked-decay, then @x),
  3. adds the inter-chunk contribution C @ state_carry,
  4. updates the carried state with this chunk's outer-product sum.

Block sizes: chunk length Q (lane-dim 128-multiple recommended) and a head
block H_BLK so the state scratch (H_BLK, hd, ds) fits VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_ref,
                *, n_chunks: int, h_blk: int, hd: int, ds: int, q: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32).reshape(q, h_blk, hd)
    dt = dt_ref[0].astype(jnp.float32)        # (q, h_blk)
    A = a_ref[0].astype(jnp.float32)          # (h_blk,)
    Bm = b_ref[0].astype(jnp.float32)         # (q, ds)
    Cm = c_ref[0].astype(jnp.float32)         # (q, ds)
    D = d_ref[0].astype(jnp.float32)          # (h_blk,)

    dA = dt * A                               # (q, h_blk) log decay
    cs = jnp.cumsum(dA, axis=0)               # inclusive
    total = cs[-1:, :]                        # (1, h_blk)

    # decay matrix per head: L[i,j] = exp(cs_i - cs_j) for i>=j else 0
    diff = cs[:, None, :] - cs[None, :, :]    # (i, j, h)
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (q, q), 1
    )
    L = jnp.exp(jnp.where(tri[:, :, None], diff, -jnp.inf))  # (i, j, h)

    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (i, j)
    w = cb[:, :, None] * L * dt[None, :, :]   # (i, j, h)

    # intra-chunk: y[i,h,:] = sum_j w[i,j,h] * x[j,h,:]
    y_intra = jnp.einsum("ijh,jhd->ihd", w, x)

    # inter-chunk: y[i,h,:] += exp(cs[i,h]) * C_i @ state[h]
    state = state_ref[...]                    # (h_blk, hd, ds)
    cstate = jnp.einsum("is,hds->ihd", Cm, state)
    y = y_intra + jnp.exp(cs)[:, :, None] * cstate + D[None, :, None] * x

    y_ref[0, :, :] = y.reshape(q, h_blk * hd).astype(y_ref.dtype)

    # state update: state' = exp(total) * state + sum_j exp(total-cs_j) dt_j x_j B_j^T
    sdecay = jnp.exp(total - cs) * dt         # (q, h_blk)
    upd = jnp.einsum("jh,jhd,js->hds", sdecay, x, Bm)
    state_ref[...] = state * jnp.exp(total[0])[:, None, None] + upd


def ssd_scan_kernel(x, dt, A, Bm, Cm, D, *, chunk: int = 128, h_blk: int = 8,
                    interpret: bool = True):
    """x (B,S,nh,hd); dt (B,S,nh); A (nh,); Bm/Cm (B,S,ds); D (nh,).

    Returns y (B,S,nh,hd). S % chunk == 0; nh % h_blk == 0 (callers pad).
    """
    Bb, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    q = min(chunk, S)
    nc = S // q
    h_blk = min(h_blk, nh)
    nhb = nh // h_blk

    xr = x.reshape(Bb, S, nh * hd)
    a2 = jnp.broadcast_to(A[None, :], (1, nh))
    d2 = jnp.broadcast_to(D[None, :], (1, nh))

    kernel = functools.partial(
        _ssd_kernel, n_chunks=nc, h_blk=h_blk, hd=hd, ds=ds, q=q
    )
    out = pl.pallas_call(
        kernel,
        grid=(Bb, nhb, nc),
        in_specs=[
            pl.BlockSpec((1, q, h_blk * hd), lambda b, ih, ic: (b, ic, ih)),
            pl.BlockSpec((1, q, h_blk), lambda b, ih, ic: (b, ic, ih)),
            pl.BlockSpec((1, h_blk), lambda b, ih, ic: (0, ih)),
            pl.BlockSpec((1, q, ds), lambda b, ih, ic: (b, ic, 0)),
            pl.BlockSpec((1, q, ds), lambda b, ih, ic: (b, ic, 0)),
            pl.BlockSpec((1, h_blk), lambda b, ih, ic: (0, ih)),
        ],
        out_specs=pl.BlockSpec((1, q, h_blk * hd), lambda b, ih, ic: (b, ic, ih)),
        out_shape=jax.ShapeDtypeStruct((Bb, S, nh * hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((h_blk, hd, ds), jnp.float32)],
        interpret=interpret,
    )(xr, dt, a2, Bm, Cm, d2)
    return out.reshape(Bb, S, nh, hd)
