"""Pure-jnp oracle for the SSD scan: the sequential recurrence.

S_t = exp(dt_t * A) S_{t-1} + dt_t * x_t B_t^T ;  y_t = S_t C_t + D x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm, D):
    """x (B,S,nh,hd); dt (B,S,nh); A (nh,); Bm/Cm (B,S,ds); D (nh,).

    Returns (y (B,S,nh,hd), final_state (B,nh,hd,ds)). fp32 math.
    """
    Bb, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,nh,hd) (B,nh) (B,ds) (B,ds)
        decay = jnp.exp(dtt * A)  # (B,nh)
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bh,bhd,bs->bhds", dtt, xt, bt
        )
        y = jnp.einsum("bhds,bs->bhd", state, ct) + D[None, :, None] * xt
        return state, y

    state0 = jnp.zeros((Bb, nh, hd, ds), jnp.float32)
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state
