"""Fused RMSNorm Pallas TPU kernel.

Row-blocked: each grid step normalises a (block_rows, D) tile held in VMEM,
computing the fp32 row variance and the scaled output in one pass (the
unfused jnp version round-trips x through HBM twice). D is the lane dim, so
it should be a 128-multiple for full VPU lanes; block_rows is the sublane
tile (8-multiple).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rmsnorm_kernel(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
                   interpret: bool = True):
    orig_shape = x.shape
    D = orig_shape[-1]
    xr = x.reshape(-1, D)
    n = xr.shape[0]
    block_rows = min(block_rows, n)
    pad = (-n) % block_rows
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    grid = (xr.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr, scale.reshape(1, D))
    if pad:
        out = out[:n]
    return out.reshape(orig_shape)
