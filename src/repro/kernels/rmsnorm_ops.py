"""jit'd public wrapper for the fused RMSNorm kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm import rmsnorm_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x, scale, *, eps: float = 1e-5):
    return rmsnorm_kernel(x, scale, eps=eps, interpret=not _on_tpu())
