"""NAS-parallel-benchmark analogues in JAX (paper Sec. VII evaluation suite).

The paper measures failure-free replication overhead on CG, BT, LU, EP, SP,
IS, MG + CloverLeaf + PIC. We implement six mini-apps whose communication
patterns span the same space, each as a per-slice ``shard_map`` program
wired through the SAME replica-aware communicators as the trainer:

- EP       : embarrassingly parallel RNG reduction  (no comm, final psum)
- CG       : conjugate gradient on a 1-D Laplacian  (halo ppermute + dots)
- MG-lite  : two-level multigrid V-cycle            (halo + coarse psum)
- STENCIL  : CloverLeaf-lite 2-D Euler-ish stencil  (halo exchange + CFL)
- IS       : integer bucket sort                    (all_to_all; r in {0,1})
- PIC-lite : particle-in-cell skeleton              (gather/scatter + field psum)

P2P mirroring follows the paper's Sec. V-B exactly: computational slices
exchange halos with computational neighbours, replicas with replica
neighbours (cmp<->cmp mirrored by rep<->rep); collectives run on COMM_CMP
groups with results forwarded over the intercomm (or fused - same modes as
the trainer).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ReplicationConfig
from repro.core.data_plane import manual_axes, _flat_slice_index
from repro.core.replication import WorldState


# ---------------------------------------------------------------------------
# replica-aware communication helpers (per-slice context)
# ---------------------------------------------------------------------------


class Comms:
    """The paper's communicators, bound to one (mesh, world, mode)."""

    def __init__(self, mesh: Mesh, world: WorldState, repl: ReplicationConfig):
        self.mesh = mesh
        self.world = world
        self.repl = repl
        self.axes = manual_axes(mesh)
        topo = world.topo
        self.n_comp = topo.n_comp
        self.cmp_groups = world.physical_groups(topo.comm_cmp_groups())
        self.intercomm = world.physical_perm(topo.intercomm_perm())
        roles = world.roles_in_mesh_order()
        self.is_rep_by_pos = np.asarray(
            [topo.is_rep_mask()[r] for r in roles], dtype=np.float32
        )
        # role rank within own class (cmp rank for cmp slices, mirrored cmp
        # rank for replicas) - the paper's "corresponding destination"
        rank = []
        for r in roles:
            rank.append(r if r < topo.n_comp else topo.replica_of(r))
        self.classrank_by_pos = np.asarray(rank, dtype=np.int32)
        # neighbour permutation for halo exchange: cmp ring mirrored by rep
        # ring (paper: replicas send to the replica of their destination)
        pos_of_role = {r: i for i, r in enumerate(roles)}
        fwd = []
        for c in range(topo.n_comp):
            dst = (c + 1) % topo.n_comp
            fwd.append((pos_of_role[c], pos_of_role[dst]))
            rc, rd = topo.partner_of(c), topo.partner_of(dst)
            if rc is not None and rd is not None:
                fwd.append((pos_of_role[rc], pos_of_role[rd]))
            elif rc is not None:
                # source has a replica, destination doesn't: the replica also
                # sends to the computational destination in the paper; in
                # SPMD the destination simply takes the cmp copy (no-op).
                pass
        self.ring_fwd = fwd
        self.ring_bwd = [(b, a) for a, b in fwd]

    # --- collectives on COMM_CMP with intercomm forward (mode-aware) -----
    def allreduce(self, x):
        if self.n_comp == self.world.topo.n_slices or self.repl.collective_mode != "paper":
            idx = _flat_slice_index(self.axes, self.mesh)
            is_rep = jnp.asarray(self.is_rep_by_pos)[idx]
            return jax.lax.psum(x * (1.0 - is_rep), self.axes)
        g = jax.lax.psum(x, self.axes, axis_index_groups=self.cmp_groups)
        g_rep = jax.lax.ppermute(g, self.axes, self.intercomm)
        idx = _flat_slice_index(self.axes, self.mesh)
        is_rep = jnp.asarray(self.is_rep_by_pos)[idx]
        return jnp.where(is_rep > 0, g_rep, g)

    def halo_shift(self, x, forward: bool = True):
        """Send ``x`` to the next (prev) slice in the computational ring,
        mirrored on the replica ring. Returns the received buffer."""
        perm = self.ring_fwd if forward else self.ring_bwd
        return jax.lax.ppermute(x, self.axes, perm)

    def class_index(self):
        idx = _flat_slice_index(self.axes, self.mesh)
        return jnp.asarray(self.classrank_by_pos)[idx]


def _wrap(mesh, world, fn, n_in, n_out, repl):
    """shard_map a per-slice mini-app step: inputs/outputs stay per-slice
    (leading dim = slice), scalars replicated."""
    axes = manual_axes(mesh)
    lead = axes if len(axes) > 1 else axes[0]
    in_specs = tuple([P(lead)] * n_in)
    out_specs = tuple([P(lead)] * n_out) if n_out > 1 else P(lead)
    return jax.jit(
        shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=set(axes), check_vma=False,
        )
    )


# ---------------------------------------------------------------------------
# the apps: each returns (step_fn, init_state, verify_fn)
# ---------------------------------------------------------------------------


def make_ep(mesh, world, repl, *, n=1 << 14):
    """EP: per-slice Gaussian-pair counting, one final allreduce."""
    comms = Comms(mesh, world, repl)

    def step(seed):  # seed (slices, 1) int32
        rank = comms.class_index()
        key = jax.random.fold_in(jax.random.PRNGKey(0), seed[0, 0] + 7919 * rank)
        xy = jax.random.uniform(key, (n, 2)) * 2.0 - 1.0
        r2 = jnp.sum(xy * xy, axis=1)
        inside = jnp.sum((r2 <= 1.0).astype(jnp.float32))
        total = comms.allreduce(inside)
        return (total / (comms.n_comp * n) * 4.0)[None]  # pi estimate

    fn = _wrap(mesh, world, step, 1, 1, repl)
    init = np.zeros((world.topo.n_slices, 1), np.int32)
    verify = lambda out: abs(float(np.asarray(out)[0]) - np.pi) < 0.05
    return fn, init, verify


def make_cg(mesh, world, repl, *, local_n=512, iters=8):
    """CG on the 1-D Laplacian [2,-1] with halo exchange + reduction dots."""
    comms = Comms(mesh, world, repl)

    def apply_A(x):
        left = comms.halo_shift(x[:, -1:], forward=True)   # my right edge -> next
        right = comms.halo_shift(x[:, :1], forward=False)  # my left edge -> prev
        rank = comms.class_index()
        left = jnp.where(rank == 0, 0.0, left)
        right = jnp.where(rank == comms.n_comp - 1, 0.0, right)
        xl = jnp.concatenate([left, x[:, :-1]], axis=1)
        xr = jnp.concatenate([x[:, 1:], right], axis=1)
        return 2.0 * x - xl - xr

    def dot(a, b):
        return comms.allreduce(jnp.sum(a * b))

    def step(b):  # b (slices, local_n)
        x = jnp.zeros_like(b)
        r = b - apply_A(x)
        p = r
        rs = dot(r, r)

        def body(carry, _):
            x, r, p, rs = carry
            Ap = apply_A(p)
            alpha = rs / jnp.maximum(dot(p, Ap), 1e-30)
            x = x + alpha * p
            r = r - alpha * Ap
            rs_new = dot(r, r)
            p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
            return (x, r, p, rs_new), rs_new

        (x, r, p, rs), _ = jax.lax.scan(body, (x, r, p, rs), jnp.arange(iters))
        return x, rs[None]

    def fn_wrapped(b):
        axes = manual_axes(mesh)
        lead = axes if len(axes) > 1 else axes[0]
        return jax.jit(
            shard_map(
                step, mesh=mesh, in_specs=(P(lead),),
                out_specs=(P(lead), P(lead)),
                axis_names=set(axes), check_vma=False,
            )
        )(b)

    # rhs mirrored for replicas, like the data pipeline
    rng = np.random.default_rng(0)
    base = rng.standard_normal((world.topo.n_comp, local_n)).astype(np.float32)
    src = world.topo.mirror_source()
    order = world.roles_in_mesh_order()
    b0 = np.stack([base[src[r]] for r in order])
    verify = lambda out: float(np.asarray(out[1])[0]) < float(np.sum(base * base))
    return fn_wrapped, b0, verify


def make_stencil(mesh, world, repl, *, local=(64, 256), iters=10):
    """CloverLeaf-lite: 2-D diffusion/advection stencil, row-partitioned;
    halo exchange each sweep + a CFL-style global max each iteration."""
    comms = Comms(mesh, world, repl)
    H, W = local

    def step(u):  # (slices, H, W)
        def sweep(u, _):
            up = comms.halo_shift(u[:, -1:, :], forward=True)
            dn = comms.halo_shift(u[:, :1, :], forward=False)
            rank = comms.class_index()
            up = jnp.where(rank == 0, u[:, :1, :], up)
            dn = jnp.where(rank == comms.n_comp - 1, u[:, -1:, :], dn)
            ue = jnp.concatenate([up, u[:, :-1, :]], axis=1)
            uw = jnp.concatenate([u[:, 1:, :], dn], axis=1)
            un = jnp.roll(u, 1, axis=2)
            us = jnp.roll(u, -1, axis=2)
            lap = ue + uw + un + us - 4.0 * u
            cfl = comms.allreduce(jnp.max(jnp.abs(lap)) / comms.n_comp)
            dt = 0.2 / jnp.maximum(cfl, 1e-6) * 0.1
            return u + jnp.minimum(dt, 0.24) * lap, None

        u, _ = jax.lax.scan(sweep, u, jnp.arange(iters))
        return u

    fn = _wrap(mesh, world, step, 1, 1, repl)
    rng = np.random.default_rng(1)
    base = rng.standard_normal((world.topo.n_comp, H, W)).astype(np.float32)
    src = world.topo.mirror_source()
    order = world.roles_in_mesh_order()
    u0 = np.stack([base[src[r]] for r in order])
    verify = lambda out: np.isfinite(np.asarray(out)).all()
    return fn, u0, verify


def make_mg(mesh, world, repl, *, local_n=1024, cycles=4):
    """MG-lite: Jacobi smoothing on the fine grid (halo) + coarse-grid
    correction via a global reduction (the heavy small-message pattern that
    made MG the paper's worst case)."""
    comms = Comms(mesh, world, repl)

    def step(b):
        x = jnp.zeros_like(b)

        def vcycle(x, _):
            # fine smooth (1-D Laplacian Jacobi, halo exchange)
            left = comms.halo_shift(x[:, -1:], forward=True)
            right = comms.halo_shift(x[:, :1], forward=False)
            rank = comms.class_index()
            left = jnp.where(rank == 0, 0.0, left)
            right = jnp.where(rank == comms.n_comp - 1, 0.0, right)
            xl = jnp.concatenate([left, x[:, :-1]], axis=1)
            xr = jnp.concatenate([x[:, 1:], right], axis=1)
            x = 0.5 * (xl + xr + b) * 0.98
            # coarse correction: mean residual -> global solve -> prolong
            res = b - (2 * x - xl - xr)
            coarse = comms.allreduce(jnp.mean(res)) / comms.n_comp
            return x + 0.5 * coarse, jnp.mean(res * res)

        x, hist = jax.lax.scan(vcycle, x, jnp.arange(cycles))
        return x, hist[-1][None]

    axes = manual_axes(mesh)
    lead = axes if len(axes) > 1 else axes[0]
    fn = jax.jit(
        shard_map(
            step, mesh=mesh, in_specs=(P(lead),),
            out_specs=(P(lead), P(lead)),
            axis_names=set(axes), check_vma=False,
        )
    )
    rng = np.random.default_rng(2)
    base = rng.standard_normal((world.topo.n_comp, local_n)).astype(np.float32)
    src = world.topo.mirror_source()
    order = world.roles_in_mesh_order()
    b0 = np.stack([base[src[r]] for r in order])
    verify = lambda out: np.isfinite(np.asarray(out[1])).all()
    return fn, b0, verify


def make_is(mesh, world, repl, *, local_n=1 << 12):
    """IS: bucket sort - keys histogrammed locally then exchanged with
    all_to_all over COMM_CMP (requires equal group sizes: r in {0, 1})."""
    comms = Comms(mesh, world, repl)
    topo = world.topo
    assert topo.n_rep in (0, topo.n_comp), (
        "IS all_to_all needs equal-size communicator groups (paper runs "
        "collectives on COMM_CMP; XLA groups must be uniform)"
    )
    n_buckets = topo.n_comp
    groups = comms.cmp_groups if topo.n_rep else None

    def step(keys):  # (slices, local_n) int32 in [0, n_buckets*256)
        rank = comms.class_index()
        bucket = keys // 256  # destination class rank
        order = jnp.argsort(bucket, axis=1)
        sorted_keys = jnp.take_along_axis(keys, order, axis=1)
        counts = jnp.zeros((1, n_buckets), jnp.int32).at[
            0, bucket[0]
        ].add(1)
        # equal-split exchange (capacity local_n // n_buckets per bucket)
        cap = local_n // n_buckets
        sel = jnp.argsort(bucket[0], stable=True)
        chunks = sorted_keys[:, : cap * n_buckets].reshape(1, n_buckets, cap)
        exchanged = jax.lax.all_to_all(
            chunks, comms.axes, split_axis=1, concat_axis=1,
            axis_index_groups=groups, tiled=False,
        )
        local_sorted = jnp.sort(exchanged.reshape(1, -1), axis=1)
        checksum = comms.allreduce(jnp.sum(local_sorted.astype(jnp.float32)))
        return local_sorted, checksum[None]

    axes = manual_axes(mesh)
    lead = axes if len(axes) > 1 else axes[0]
    fn = jax.jit(
        shard_map(
            step, mesh=mesh, in_specs=(P(lead),),
            out_specs=(P(lead), P(lead)),
            axis_names=set(axes), check_vma=False,
        )
    )
    rng = np.random.default_rng(3)
    base = rng.integers(0, n_buckets * 256, (topo.n_comp, local_n)).astype(np.int32)
    src = topo.mirror_source()
    order = world.roles_in_mesh_order()
    k0 = np.stack([base[src[r]] for r in order])
    verify = lambda out: np.all(np.diff(np.asarray(out[0])[0]) >= 0)
    return fn, k0, verify


def make_pic(mesh, world, repl, *, n_part=1 << 12, grid=256, steps=4):
    """PIC-lite skeleton (Decyk): deposit charge on a grid, solve the field
    with a global reduction, push particles. Deposition uses scatter-add;
    the field solve is the allreduce-heavy phase."""
    comms = Comms(mesh, world, repl)

    def step(state):  # (slices, n_part, 2): position, velocity
        def push(state, _):
            pos, vel = state[:, :, 0], state[:, :, 1]
            cell = jnp.clip((pos * grid).astype(jnp.int32), 0, grid - 1)
            rho = jnp.zeros((1, grid), jnp.float32).at[0, cell[0]].add(1.0)
            rho = comms.allreduce(rho) / comms.n_comp
            # crude Poisson solve via FFT
            rho_hat = jnp.fft.rfft(rho[0] - jnp.mean(rho))
            k = jnp.arange(rho_hat.shape[0], dtype=jnp.float32)
            phi_hat = jnp.where(k > 0, rho_hat / jnp.maximum(k * k, 1e-9), 0.0)
            E = -jnp.fft.irfft(1j * k * phi_hat, n=grid).real
            force = E[cell[0]][None]
            vel = vel + 0.01 * force
            pos = (pos + 0.01 * vel) % 1.0
            return jnp.stack([pos, vel], axis=-1), jnp.sum(vel * vel)

        state, energy = jax.lax.scan(push, state, jnp.arange(steps))
        return state, energy[-1][None]

    axes = manual_axes(mesh)
    lead = axes if len(axes) > 1 else axes[0]
    fn = jax.jit(
        shard_map(
            step, mesh=mesh, in_specs=(P(lead),),
            out_specs=(P(lead), P(lead)),
            axis_names=set(axes), check_vma=False,
        )
    )
    rng = np.random.default_rng(4)
    base = rng.random((world.topo.n_comp, n_part, 2)).astype(np.float32)
    base[:, :, 1] -= 0.5
    src = world.topo.mirror_source()
    order = world.roles_in_mesh_order()
    s0 = np.stack([base[src[r]] for r in order])
    verify = lambda out: np.isfinite(np.asarray(out[1])).all()
    return fn, s0, verify


MINIAPPS: Dict[str, Callable] = {
    "ep": make_ep,
    "cg": make_cg,
    "mg": make_mg,
    "stencil": make_stencil,
    "is": make_is,
    "pic": make_pic,
}
