"""Gradient compression for the cmp->rep intercomm (beyond-paper lever).

The reduced gradient forwarded from computational to replica slices
(CMP_REP_INTERCOMM) tolerates lossy encoding: replicas apply the SAME
compressed gradient as their partner decodes, so mirrored state stays
bit-identical as long as BOTH sides apply the decode(encode(g)) value.
The data plane therefore applies the codec on the cmp side *before* the
ppermute so computational and replica slices consume identical bytes.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def _bf16_codec():
    def enc(g):
        return g.astype(jnp.bfloat16)

    def dec(g):
        return g.astype(jnp.float32)

    return enc, dec


def _int8_codec():
    def enc(g):
        gf = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        return (q, scale.astype(jnp.float32))

    def dec(t):
        q, scale = t
        return q.astype(jnp.float32) * scale

    return enc, dec


def get_codec(name: str) -> Tuple[Callable, Callable]:
    if name == "none":
        ident = lambda g: g
        return ident, ident
    if name == "bf16":
        return _bf16_codec()
    if name == "int8":
        return _int8_codec()
    raise ValueError(f"unknown compression {name!r}")


def roundtrip(tree: PyTree, name: str) -> PyTree:
    """decode(encode(g)) leaf-wise - applied identically on both sides."""
    enc, dec = get_codec(name)
    return jax.tree.map(lambda g: dec(enc(g)), tree)


def encode_tree(tree: PyTree, name: str) -> PyTree:
    enc, _ = get_codec(name)
    return jax.tree.map(enc, tree)


def decode_tree(tree: PyTree, name: str, like: PyTree) -> PyTree:
    _, dec = get_codec(name)
    if name == "int8":
        return jax.tree.map(dec, tree, is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(dec, tree)
