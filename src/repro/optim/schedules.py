"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    warmup_steps = max(1, warmup_steps)

    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / warmup_steps
        t = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup_steps, warm, cos)

    return lr


def constant(peak_lr: float):
    def lr(step):
        return jnp.full((), peak_lr, jnp.float32)

    return lr
