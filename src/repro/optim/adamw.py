"""AdamW with decoupled weight decay + global-norm clipping (from scratch;
no optax in this environment). Moments are fp32 regardless of param dtype.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    mu: PyTree
    nu: PyTree


class Optimizer(NamedTuple):
    init: Callable[[PyTree], AdamWState]
    update: Callable[[PyTree, AdamWState, PyTree], Tuple[PyTree, AdamWState, Dict]]


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw(
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(params: PyTree) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(grads: PyTree, state: AdamWState, params: PyTree):
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9)) if grad_clip else 1.0
        step = state.step + 1
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1.0 - b1) * g
            v = b2 * v + (1.0 - b2) * g * g
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        stats = {"grad_norm": gnorm, "lr": lr_t}
        return new_p, AdamWState(step=step, mu=new_m, nu=new_v), stats

    return Optimizer(init=init, update=update)
