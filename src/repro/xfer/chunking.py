"""Striping primitives: a staged blob as a fixed-size chunk stream.

The paper's parallel communication strategy (Sec. V) splits every
state-transfer message so all partners receive their part concurrently
instead of one whole-blob send at a time. Host-side, the same idea: a
flattened ``{path: ndarray}`` blob becomes one virtual byte stream (leaf
bytes in sorted path order) cut into fixed-size :class:`Chunk`\\ s. Chunks
are the unit of

- **striping** - round-robin placement across the partner ring
  (:func:`stripe_holders`), replacing whole-shard placement;
- **delta encoding** - each chunk independently compares to / encodes
  against the previous submit's same-index chunk (``xfer.delta``);
- **fine-grained locking** - stores place one chunk at a time, so a
  concurrent ``load`` never waits on a whole-blob copy.

Chunks that fall inside a single leaf are zero-copy views into the staged
blob; only chunks spanning a leaf boundary materialize new bytes.

Two cuts exist:

- :func:`chunk_blob` - the byte-stream cut: leaf bytes concatenated and
  sliced into fixed-size chunks (training states, dense serving caches);
- :func:`chunk_pages` - the page cut for :class:`PagedBlob`\\ s, where the
  blob's entries ARE the transfer units (the serving page table): one
  chunk per page, chunk identity = the page key, so the layout signature
  IS the page table and delta encoding matches by key instead of index.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class LeafSpec:
    """Layout record for one leaf: enough to rebuild it from raw bytes."""

    path: str
    dtype: str
    shape: Tuple[int, ...]
    nbytes: int


@dataclass
class Chunk:
    """One stripe of the byte stream.

    ``encoding`` selects how ``payload`` maps to raw bytes:

    - ``raw``  - payload IS the bytes (uint8);
    - ``zero`` - identical to the reference chunk: no payload, ``ref``
      (shared by refcount with the previous submit) is the bytes;
    - ``bf16``/``int8`` - payload is the codec-encoded fp32 *delta*
      against ``ref`` (kept only when reconstruction is byte-exact).
    """

    index: int
    encoding: str = "raw"
    payload: Optional[object] = None
    ref: Optional[np.ndarray] = None

    @property
    def moved_bytes(self) -> int:
        """Bytes a submit actually moves: the payload (the shared ``ref``
        already resides with every holder from the reference submit)."""
        if self.encoding == "zero":
            return 0
        if self.encoding == "int8":
            q, _ = self.payload
            return int(q.nbytes) + 4
        return int(np.asarray(self.payload).nbytes)

    def raw(self) -> np.ndarray:
        """Decode to the chunk's raw uint8 bytes (exact by construction)."""
        if self.encoding == "raw":
            return self.payload
        if self.encoding == "zero":
            return self.ref
        from repro.xfer.delta import decode_delta

        return decode_delta(self)


class PagedBlob(dict):
    """A staged blob whose entries are the transfer units themselves.

    The serving engine's page table stages to one of these: each entry is
    an immutable host page (``{page_key: ndarray}``) that the engine never
    mutates after handing it over, so staging/capture pass it through by
    reference (no per-submit copy of sealed pages) and the chunk cut is
    :func:`chunk_pages` - one chunk per page, keyed - instead of the
    byte-stream cut."""


@dataclass
class ChunkedBlob:
    """The striped form of one staged blob."""

    layout: Tuple[LeafSpec, ...]
    chunk_bytes: int
    chunks: List[Chunk] = field(default_factory=list)
    #: page keys for a paged cut (one per chunk, == layout paths); None for
    #: the byte-stream cut. Keys are the stable chunk identities the delta
    #: encoder and the durable chain anchors match on.
    keys: Optional[Tuple[str, ...]] = None

    @property
    def total_bytes(self) -> int:
        return sum(s.nbytes for s in self.layout)

    @property
    def moved_bytes(self) -> int:
        return sum(c.moved_bytes for c in self.chunks)

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    def layout_signature(self) -> Tuple:
        """Delta encoding is only valid between identically-laid-out
        submits (same leaves, same chunk size). For a paged cut the layout
        paths ARE the page keys - the signature is the page table."""
        return (self.chunk_bytes, self.layout)

    def chunk_size(self, index: int) -> int:
        """Expected raw byte size of chunk ``index``: the page's own size
        for a paged cut (pages are whole leaves), else the byte-stream
        slice (last chunk may be short)."""
        if self.keys is not None:
            return self.layout[index].nbytes
        return min(self.chunk_bytes,
                   self.total_bytes - index * self.chunk_bytes)

    def raw_chunks(self) -> List[np.ndarray]:
        return [c.raw() for c in self.chunks]

    def to_blob(self, raw: Optional[List[np.ndarray]] = None
                ) -> Dict[str, np.ndarray]:
        """Reassemble ``{path: ndarray}``. Restores are byte-identical to
        the submitted blob whatever each chunk's encoding. ``raw`` reuses
        already-decoded chunk bytes (delta decodes are not free - a caller
        that validated them should not pay twice)."""
        out: Dict[str, np.ndarray] = {}
        raw = self.raw_chunks() if raw is None else raw
        ci, off = 0, 0
        for spec in self.layout:
            pieces, need = [], spec.nbytes
            while need:
                chunk = raw[ci]
                take = min(need, chunk.nbytes - off)
                pieces.append(chunk[off : off + take])
                need -= take
                off += take
                if off == chunk.nbytes:
                    ci, off = ci + 1, 0
            # concatenate/copy (never view): pieces may sit at unaligned
            # offsets inside a chunk, and the caller owns the result;
            # zero-size leaves contribute no pieces at all
            if not pieces:
                b = np.zeros(0, np.uint8)
            elif len(pieces) == 1:
                b = pieces[0].copy()
            else:
                b = np.concatenate(pieces)
            out[spec.path] = b.view(np.dtype(spec.dtype)).reshape(spec.shape)
        return out


def layout_to_json(layout: Sequence[LeafSpec]) -> List[Dict]:
    """A layout as JSON-able records (the durable delta manifests persist
    the chunking layout so a restore can validate the chain against it)."""
    return [
        {"path": s.path, "dtype": s.dtype, "shape": list(s.shape),
         "nbytes": s.nbytes}
        for s in layout
    ]


def layout_from_json(rows: Sequence[Dict]) -> Tuple[LeafSpec, ...]:
    return tuple(
        LeafSpec(r["path"], r["dtype"], tuple(int(d) for d in r["shape"]),
                 int(r["nbytes"]))
        for r in rows
    )


def leaf_bytes(arr: np.ndarray) -> np.ndarray:
    """A leaf's raw bytes as a flat uint8 view (copy only if non-contiguous
    or 0-d)."""
    return np.ascontiguousarray(arr).reshape(-1).view(np.uint8)


def chunk_blob(blob: Dict[str, np.ndarray], chunk_bytes: int) -> ChunkedBlob:
    """Cut a staged blob into raw chunks of ``chunk_bytes`` (last may be
    short). Handles the degenerate shapes the protocol must survive: an
    empty blob (0 chunks), scalar leaves, and a chunk size larger than the
    largest leaf (chunks then span leaves)."""
    assert chunk_bytes >= 4 and chunk_bytes % 4 == 0, chunk_bytes
    layout, parts = [], []
    for path in sorted(blob):
        arr = np.asarray(blob[path])
        b = leaf_bytes(arr)
        layout.append(LeafSpec(path, str(arr.dtype), tuple(arr.shape), b.nbytes))
        parts.append(b)
    cb = ChunkedBlob(layout=tuple(layout), chunk_bytes=chunk_bytes)
    cur: List[np.ndarray] = []
    cur_n = 0
    for b in parts:
        off = 0
        while off < b.nbytes:
            take = min(chunk_bytes - cur_n, b.nbytes - off)
            cur.append(b[off : off + take])
            cur_n += take
            off += take
            if cur_n == chunk_bytes:
                cb.chunks.append(_seal(cur, len(cb.chunks)))
                cur, cur_n = [], 0
    if cur_n:
        cb.chunks.append(_seal(cur, len(cb.chunks)))
    return cb


def chunk_pages(blob: Dict[str, np.ndarray]) -> ChunkedBlob:
    """The page cut: one chunk per blob entry, keyed by its path.

    No byte stream is formed - each page's bytes are the chunk payload
    (zero-copy view), the layout IS the page table in sorted-key order,
    and ``chunk_bytes`` is only the striping hint (the largest page,
    4-aligned) used by :func:`chunk_count` callers. Identity by key means
    a submit whose table gained or dropped pages still delta-encodes
    against the surviving pages of the previous submit."""
    layout: List[LeafSpec] = []
    chunks: List[Chunk] = []
    keys: List[str] = []
    for i, path in enumerate(sorted(blob)):
        arr = np.asarray(blob[path])
        b = leaf_bytes(arr)
        layout.append(LeafSpec(path, str(arr.dtype), tuple(arr.shape),
                               b.nbytes))
        chunks.append(Chunk(index=i, encoding="raw", payload=b))
        keys.append(path)
    max_b = max((s.nbytes for s in layout), default=4)
    cbytes = max(4, max_b + ((-max_b) % 4))
    return ChunkedBlob(layout=tuple(layout), chunk_bytes=cbytes,
                       chunks=chunks, keys=tuple(keys))


def _seal(pieces: List[np.ndarray], index: int) -> Chunk:
    data = pieces[0] if len(pieces) == 1 else np.concatenate(pieces)
    return Chunk(index=index, encoding="raw", payload=data)


def chunk_count(total_bytes: int, chunk_bytes: int, min_chunks: int = 1) -> int:
    """How many chunks a submit stripes into: enough that every ring
    member holds a part (the paper's message splitting - no partner idles
    while another receives the whole blob), and no chunk exceeds
    ``chunk_bytes``."""
    need = max(1, -(-total_bytes // chunk_bytes)) if total_bytes else 0
    return max(need, min_chunks if total_bytes else 0)


def size_for_chunks(total_bytes: int, n_chunks: int) -> int:
    """A 4-byte-aligned chunk size yielding ~``n_chunks`` chunks."""
    if not total_bytes or n_chunks <= 0:
        return 4
    size = -(-total_bytes // n_chunks)
    return size + ((-size) % 4)


def stripe_holders(index: int, ring: Sequence[int], redundancy: int) -> List[int]:
    """The ``redundancy`` ring members holding chunk ``index``: consecutive
    peers starting at ``index mod n`` (ReStore's consecutive-ring default,
    applied per chunk instead of per whole-shard). Correct for odd ring
    sizes and rings smaller than the redundancy."""
    n = len(ring)
    k = min(redundancy, n)
    return [ring[(index + j) % n] for j in range(k)]
