"""On-device state digests via the fused checksum kernel.

Replaces the per-leaf host ``_checksum`` loop: the whole tree is viewed as
one fp32 stream (leaf path order) and digested per chunk in a single
fused pass (:func:`repro.kernels.checksum_ops.chunk_digests`). Two
digests are compared chunk-wise, so corruption localized to any chunk is
caught even when the old global abs-sum would have averaged it away.

The stream is fed to the kernel in bounded *segments* (a few chunks at a
time) instead of one ``jnp.concatenate`` over the whole tree: the old
path materialized a full fp32 copy of the state - a memory spike that
was tightest exactly during heals, when the clone target's buffers are
already resident. Segment boundaries are chunk-aligned, so the segmented
stream produces digests bit-identical to the single-concat form.
"""
from __future__ import annotations

from typing import Any, Iterator, List

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

#: default digest granularity: 64Ki fp32 = 256 KiB per chunk
DIGEST_CHUNK_ELEMS = 1 << 16

#: chunks digested per kernel feed: bounds the transient fp32 copy at
#: ``SEGMENT_CHUNKS * chunk_elems`` elements (16 MiB at the defaults)
SEGMENT_CHUNKS = 64


def _chunk_elems(n: int, chunk_elems: int) -> int:
    """Shrink the chunk to the (128-aligned) stream size for small trees,
    so a scalar state doesn't pad out to a quarter-MiB row."""
    return max(128, min(chunk_elems, n + ((-n) % 128)))


def _segments(leaves: List, seg_elems: int) -> Iterator[jnp.ndarray]:
    """The tree's fp32 stream as <= ``seg_elems``-long segments: leaf
    slices are buffered until a segment fills, so no intermediate ever
    exceeds one segment (plus the source leaf being sliced)."""
    buf: List[jnp.ndarray] = []
    buf_n = 0
    for x in leaves:
        flat = jnp.ravel(x)
        size, off = flat.shape[0], 0
        while off < size:
            take = min(seg_elems - buf_n, size - off)
            buf.append(flat[off : off + take].astype(jnp.float32))
            buf_n += take
            off += take
            if buf_n == seg_elems:
                yield buf[0] if len(buf) == 1 else jnp.concatenate(buf)
                buf, buf_n = [], 0
    if buf_n:
        yield buf[0] if len(buf) == 1 else jnp.concatenate(buf)


def tree_digests(tree: PyTree, *, chunk_elems: int = DIGEST_CHUNK_ELEMS,
                 segment_chunks: int = SEGMENT_CHUNKS) -> np.ndarray:
    """(n_chunks, 2) [abs-sum, sum] digests of the tree's fp32 stream.

    Streams the tree through the kernel ``segment_chunks`` chunks at a
    time; every segment boundary is a chunk boundary, so the result is
    bit-identical for any ``segment_chunks`` (only the transient memory
    differs)."""
    from repro.kernels.checksum_ops import chunk_digests

    leaves = [x for x in jax.tree.leaves(tree) if hasattr(x, "dtype")]
    n = sum(int(np.prod(x.shape)) for x in leaves)
    if n == 0:
        return np.zeros((0, 2), np.float32)
    ce = _chunk_elems(n, chunk_elems)
    assert segment_chunks >= 1, segment_chunks
    parts = [
        chunk_digests(seg, chunk_elems=ce)
        for seg in _segments(leaves, segment_chunks * ce)
    ]
    return np.concatenate([np.asarray(p) for p in parts], axis=0)


def digest_tolerance(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """The relative tolerance the old global checksum used (fp32 reduction
    order may differ between a sharded source and its gathered clone) -
    SYMMETRIC in its arguments: the scale is ``max(|a|, |b|)``, so
    ``verify_tree(src, dst) == verify_tree(dst, src)`` even when one side
    sits just past the other's boundary."""
    return 1e-6 * np.maximum(1.0, np.maximum(np.abs(a), np.abs(b)))


def digests_match(a: np.ndarray, b: np.ndarray) -> bool:
    """Chunk-wise comparison under the symmetric relative tolerance."""
    if a.shape != b.shape:
        return False
    if a.size == 0:
        return True
    return bool(np.all(np.abs(a - b) <= digest_tolerance(a, b)))


def diff_chunks(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Indices of chunks whose digests differ beyond the symmetric
    tolerance (the digest-guided unit of partial restore / vote)."""
    assert a.shape == b.shape, (a.shape, b.shape)
    if a.size == 0:
        return np.zeros((0,), np.int64)
    bad = np.abs(a - b) > digest_tolerance(a, b)
    return np.nonzero(np.any(bad, axis=-1))[0]


def verify_tree(src: PyTree, dst: PyTree, *,
                chunk_elems: int = DIGEST_CHUNK_ELEMS) -> bool:
    """One fused digest pass per tree, compared per chunk."""
    return digests_match(
        tree_digests(src, chunk_elems=chunk_elems),
        tree_digests(dst, chunk_elems=chunk_elems),
    )
