"""On-device state digests via the fused checksum kernel.

Replaces the per-leaf host ``_checksum`` loop: the whole tree is cast to
one fp32 stream (leaf path order) and digested per chunk in a single
fused pass (:func:`repro.kernels.checksum_ops.chunk_digests`). Two
digests are compared chunk-wise, so corruption localized to any chunk is
caught even when the old global abs-sum would have averaged it away."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

#: default digest granularity: 64Ki fp32 = 256 KiB per chunk
DIGEST_CHUNK_ELEMS = 1 << 16


def _chunk_elems(n: int, chunk_elems: int) -> int:
    """Shrink the chunk to the (128-aligned) stream size for small trees,
    so a scalar state doesn't pad out to a quarter-MiB row."""
    return max(128, min(chunk_elems, n + ((-n) % 128)))


def tree_digests(tree: PyTree, *, chunk_elems: int = DIGEST_CHUNK_ELEMS) -> np.ndarray:
    """(n_chunks, 2) [abs-sum, sum] digests of the tree's fp32 stream."""
    from repro.kernels.checksum_ops import chunk_digests

    leaves = [x for x in jax.tree.leaves(tree) if hasattr(x, "dtype")]
    if not leaves:
        return np.zeros((0, 2), np.float32)
    flat = jnp.concatenate([jnp.ravel(x).astype(jnp.float32) for x in leaves])
    out = chunk_digests(flat, chunk_elems=_chunk_elems(flat.shape[0], chunk_elems))
    return np.asarray(out)


def digests_match(a: np.ndarray, b: np.ndarray) -> bool:
    """Chunk-wise comparison with the relative tolerance the old global
    checksum used (fp32 reduction order may differ between a sharded
    source and its gathered clone)."""
    if a.shape != b.shape:
        return False
    if a.size == 0:
        return True
    tol = 1e-6 * np.maximum(1.0, np.abs(a))
    return bool(np.all(np.abs(a - b) <= tol))


def verify_tree(src: PyTree, dst: PyTree, *,
                chunk_elems: int = DIGEST_CHUNK_ELEMS) -> bool:
    """One fused digest pass per tree, compared per chunk."""
    return digests_match(
        tree_digests(src, chunk_elems=chunk_elems),
        tree_digests(dst, chunk_elems=chunk_elems),
    )
