"""Verified-exact delta encoding for chunk streams.

When consecutive submits are close (small optimizer steps, a serving
cache that only appends), most chunks barely change - ReStore's argument
for sub-blocking applies to bytes too. Each chunk is encoded against the
SAME-index chunk of the previous submit:

- byte-identical          -> ``zero``: no payload at all; the holder
  already has the reference bytes (shared host-side by refcount, the
  analogue of "don't resend what the partner holds");
- fp32-delta representable -> ``bf16``/``int8`` payload via the SAME
  codecs the cmp->rep intercomm uses (:mod:`repro.optim.compression`);
- otherwise               -> ``raw`` fallback.

Bit-exact restores are guaranteed *by construction*, not by hope: a delta
chunk is kept only if decoding it here and now reproduces the current
bytes exactly (verified per chunk at encode time); any chunk that fails
the check ships raw. A layout change (ring shrink re-chunking, a new
state shape) resets the reference - the next submit is full."""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.optim.compression import get_codec
from repro.xfer.chunking import Chunk, ChunkedBlob


def _as_f32(b: np.ndarray) -> np.ndarray:
    """Reinterpret raw bytes as fp32 (copying: chunk views can sit at
    unaligned offsets inside a leaf's buffer)."""
    return np.frombuffer(b.tobytes(), dtype=np.float32)


def encode_delta(index: int, cur: np.ndarray, ref: np.ndarray,
                 codec: str) -> Optional[Chunk]:
    """Encode ``cur`` as a codec'd fp32 delta against ``ref``; ``None``
    unless reconstruction is byte-exact (the per-chunk verification)."""
    enc, dec = get_codec(codec)
    delta = _as_f32(cur) - _as_f32(ref)
    payload = jax.tree.map(np.asarray, enc(delta))
    recon = _as_f32(ref) + np.asarray(dec(payload), dtype=np.float32)
    if not np.array_equal(recon.view(np.uint8), cur):
        return None
    return Chunk(index=index, encoding=codec, payload=payload, ref=ref)


def decode_delta(chunk: Chunk) -> np.ndarray:
    """Raw bytes of a bf16/int8 delta chunk (exact: encode verified it)."""
    _, dec = get_codec(chunk.encoding)
    delta = np.asarray(dec(chunk.payload), dtype=np.float32)
    return (_as_f32(chunk.ref) + delta).view(np.uint8)


def payload_parts(chunk: Chunk) -> Tuple[List[np.ndarray], List[str]]:
    """A chunk payload as raw byte parts + dtype tags, for serialization
    to npz (``np.savez`` mangles non-native dtypes like bfloat16 to void -
    ship uint8 views and rebuild with the recorded dtype). Scalars land as
    shape-(1,) parts; :func:`payload_from_parts` restores the codec's
    expected shapes."""
    leaves = jax.tree.leaves(chunk.payload)
    arrs = [np.asarray(l) for l in leaves]
    parts = [
        np.ascontiguousarray(a).reshape(-1).view(np.uint8) for a in arrs
    ]
    return parts, [str(a.dtype) for a in arrs]


def payload_from_parts(encoding: str, parts: Sequence[np.ndarray],
                       dtypes: Sequence[str]):
    """Inverse of :func:`payload_parts` (byte-identical: the parts are
    views, the dtypes round-trip through their registered names)."""
    leaves = [
        np.asarray(p).reshape(-1).view(np.uint8).view(np.dtype(d))
        for p, d in zip(parts, dtypes)
    ]
    if encoding == "int8":
        q, scale = leaves
        return (q, scale.reshape(()))
    assert len(leaves) == 1, (encoding, len(leaves))
    return leaves[0]


class DeltaEncoder:
    """Per-consumer delta state: the previous submit's raw chunk bytes.

    One encoder per chunk-consuming store (its reference lifetime matches
    the store's ring: a re-chunking after the ring changed resets it)."""

    def __init__(self, codec: str = "none"):
        assert codec in ("none", "bf16", "int8"), codec
        self.codec = codec
        self._sig = None
        self._ref: List[np.ndarray] = []
        self._kref: Dict[str, np.ndarray] = {}

    def reset(self) -> None:
        self._sig, self._ref, self._kref = None, [], {}

    def observe(self, cb: ChunkedBlob) -> None:
        """Update the reference WITHOUT encoding: a consumer that decided
        to ship this submit full (e.g. the durable chain-depth cap) still
        needs the next submit to delta against it, and paying the per-chunk
        compare + codec pass for a result it will discard is waste."""
        self._sig = cb.layout_signature()
        self._ref = [c.raw() for c in cb.chunks]
        self._kref = dict(zip(cb.keys, self._ref)) if cb.keys else {}

    def _encode_keyed(self, cb: ChunkedBlob) -> ChunkedBlob:
        """The paged cut's delta: chunks match the previous submit BY KEY,
        so a table that gained tail pages or dropped freed slots still
        zero-encodes every surviving sealed page. Byte-equality (-> zero
        chunk) needs no codec: pages are immutable once sealed, so with
        codec "none" the steady-state submit ships only dirty tail pages."""
        raws = [c.raw() for c in cb.chunks]
        chunks: List[Chunk] = []
        for i, cur in enumerate(raws):
            ref = self._kref.get(cb.keys[i])
            encoded = None
            if ref is not None and ref.nbytes == cur.nbytes:
                if np.array_equal(cur, ref):
                    encoded = Chunk(index=i, encoding="zero", ref=ref)
                    raws[i] = ref  # share forward: zero chains stay zero-copy
                elif self.codec != "none" and cur.nbytes % 4 == 0:
                    encoded = encode_delta(i, cur, ref, self.codec)
            chunks.append(encoded if encoded is not None else cb.chunks[i])
        out = ChunkedBlob(layout=cb.layout, chunk_bytes=cb.chunk_bytes,
                          chunks=chunks, keys=cb.keys)
        self._sig = cb.layout_signature()
        self._ref = raws
        self._kref = dict(zip(cb.keys, raws))
        return out

    def encode(self, cb: ChunkedBlob) -> ChunkedBlob:
        """Delta-encode ``cb`` against the previous submit (a NEW blob:
        ``cb`` may be shared by other consumers via the plane's chunking
        memo); becomes the new reference either way."""
        if cb.keys is not None:
            return self._encode_keyed(cb)
        self._kref = {}
        raws = [c.raw() for c in cb.chunks]
        sig = cb.layout_signature()
        if (
            self.codec != "none"
            and self._sig == sig
            and len(raws) == len(self._ref)
        ):
            chunks: List[Chunk] = []
            for i, cur in enumerate(raws):
                ref = self._ref[i]
                encoded = None
                if np.array_equal(cur, ref):
                    encoded = Chunk(index=i, encoding="zero", ref=ref)
                    raws[i] = ref  # share forward: zero chains stay zero-copy
                elif cur.nbytes % 4 == 0:
                    encoded = encode_delta(i, cur, ref, self.codec)
                chunks.append(encoded if encoded is not None else cb.chunks[i])
            cb = ChunkedBlob(
                layout=cb.layout, chunk_bytes=cb.chunk_bytes, chunks=chunks
            )
        self._sig = sig
        self._ref = raws
        return cb
