"""Deadline-bounded operations (the GASPI-FT timeout pattern).

Every communication that can hang gets a deadline, and exceeding it is a
first-class failure signal that feeds the same recovery path as a crash.
The :class:`Deadline` below tracks a budget in seconds against BOTH real
elapsed time and *charged* virtual cost: the chaos plane injects per-peer
latency as virtual seconds (``Deadline.charge``) instead of sleeping, so
a fail-slow peer deterministically exhausts the budget in tests and
benchmarks without actually wedging the process running them. On a real
deployment the real-elapsed half does the same job against genuine slow
I/O.

``backoff_delays`` is the retry companion: bounded exponential backoff
for the transient-race path (retry as today), distinct from deadline
exhaustion (quarantine the culprit, fall to the next rung).
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Sequence


class DeadlineExceeded(RuntimeError):
    """An operation blew its budget. ``culprits`` names the peers whose
    injected/observed latency consumed the budget, when attributable -
    the quarantine decision needs a name, not just a timeout."""

    def __init__(self, msg: str, culprits: Sequence[int] = ()):
        super().__init__(msg)
        self.culprits = list(culprits)


class Deadline:
    """A spend-down budget: ``budget_s`` seconds of (real + charged
    virtual) time. Strict semantics match the control plane's suspicion
    windows: exactly-at-budget is NOT exceeded, strictly past it is."""

    def __init__(self, budget_s: float,
                 clock: Callable[[], float] = time.perf_counter):
        if not (budget_s > 0):
            raise ValueError(f"deadline budget must be > 0, got {budget_s}")
        self.budget_s = float(budget_s)
        self.clock = clock
        self._t0 = clock()
        self._charged = 0.0

    def charge(self, seconds: float) -> None:
        """Commit virtual cost (injected latency) against the budget."""
        if seconds < 0:
            raise ValueError(f"cannot charge negative time: {seconds}")
        self._charged += seconds

    def elapsed(self) -> float:
        return (self.clock() - self._t0) + self._charged

    def remaining(self) -> float:
        return self.budget_s - self.elapsed()

    def exceeded(self) -> bool:
        return self.elapsed() > self.budget_s

    def would_exceed(self, seconds: float) -> bool:
        """True if committing ``seconds`` more would blow the budget -
        lets a gather abort BEFORE 'sleeping' on a slow peer, keeping the
        uncommitted budget for retries against healthy holders."""
        return self.elapsed() + seconds > self.budget_s


def backoff_delays(attempts: int, base_s: float = 0.001,
                   factor: float = 2.0, cap_s: float = 0.05) -> List[float]:
    """Delays to sleep between retries: base, base*factor, ... capped.
    Length ``attempts - 1`` (no sleep after the last attempt)."""
    if attempts < 1:
        raise ValueError(f"attempts must be >= 1, got {attempts}")
    out = []
    d = base_s
    for _ in range(attempts - 1):
        out.append(min(d, cap_s))
        d *= factor
    return out
